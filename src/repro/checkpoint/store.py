"""Sharded, atomic, mesh-shape-agnostic checkpointing (no orbax offline).

Layout of one checkpoint::

    <dir>/step_000123/
        index.json            # manifest: step, leaf paths, shapes, dtypes,
                              # data cursor, mesh shape, framework version
        host_00000.npz        # this host's shard of every leaf
        COMMIT                # written LAST — a checkpoint without COMMIT is
                              # garbage from a crashed/preempted save

Design decisions (DESIGN.md §4):

* **Atomic commit** — everything is written into ``step_X.tmp/`` and renamed
  to ``step_X/`` after the COMMIT marker lands. A restart can never see a
  half-written checkpoint; ``latest_step`` only returns committed steps.
* **Mesh-shape-agnostic** — each host saves the *full logical value* of the
  leaves it owns addressable data for (single-host: everything). On load the
  arrays are re-sharded to whatever mesh/sharding the restoring job uses, so
  a 512-chip run restores onto 256 chips (elastic re-scale) unchanged.
* **Async save** — ``CheckpointManager.save(..., blocking=False)`` snapshots
  to host memory synchronously (cheap: device→host copy) and writes in a
  background thread, overlapping I/O with the next training steps. ``wait()``
  joins the writer; saves are serialized so at most one writer runs.
* **Retention** — keep the newest ``keep`` checkpoints (never delete an
  uncommitted dir that is still being written).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.utils import tree_paths, unflatten_dict

PyTree = Any

_FORMAT_VERSION = 1


def _host_filename(host: int) -> str:
    return f"host_{host:05d}.npz"


def _is_committed(d: Path) -> bool:
    return (d / "COMMIT").exists()


def latest_step(directory: str | Path) -> int | None:
    """Newest committed step in ``directory`` (None when no checkpoint)."""
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for child in d.iterdir():
        if child.name.startswith("step_") and _is_committed(child):
            try:
                steps.append(int(child.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def save_checkpoint(directory: str | Path, step: int, tree: PyTree,
                    extra: dict | None = None) -> Path:
    """Write one committed checkpoint synchronously. Returns its path."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:09d}"
    tmp = d / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    # device → host snapshot (full logical arrays; resharded on load)
    flat = tree_paths(tree)
    arrays: dict[str, np.ndarray] = {}
    manifest_leaves = {}
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        arrays[path] = arr
        manifest_leaves[path] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}

    host = jax.process_index() if jax.process_count() > 1 else 0
    np.savez(tmp / _host_filename(host), **arrays)
    index = {
        "version": _FORMAT_VERSION,
        "step": step,
        "hosts": jax.process_count(),
        "leaves": manifest_leaves,
        "extra": extra or {},
        "saved_unix": time.time(),
    }
    (tmp / "index.json").write_text(json.dumps(index, indent=2))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(directory: str | Path, step: int | None = None,
                    shardings: PyTree | None = None,
                    ) -> tuple[PyTree, dict]:
    """Load ``step`` (default: latest committed). Returns (tree, extra).

    ``shardings`` — optional pytree of ``jax.sharding.Sharding`` matching the
    saved tree structure; when given, every leaf is placed with
    ``jax.device_put(leaf, sharding)`` → elastic re-shard onto any mesh.
    """
    d = Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {d}")
    cdir = d / f"step_{step:09d}"
    if not _is_committed(cdir):
        raise FileNotFoundError(f"checkpoint {cdir} is not committed")
    index = json.loads((cdir / "index.json").read_text())

    arrays: dict[str, np.ndarray] = {}
    for f in sorted(cdir.glob("host_*.npz")):
        with np.load(f) as z:
            for k in z.files:
                arrays[k] = z[k]
    missing = set(index["leaves"]) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint {cdir} missing leaves: {sorted(missing)[:5]}")

    tree = unflatten_dict(arrays)
    if shardings is not None:
        flat_sh = dict(tree_paths(shardings))
        def place(path, arr):
            sh = flat_sh.get(path)
            return jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
        placed = {p: place(p, a) for p, a in arrays.items()}
        tree = unflatten_dict(placed)
    return tree, index.get("extra", {})


class CheckpointManager:
    """Periodic + preemption-triggered async checkpointing with retention."""

    def __init__(self, directory: str | Path, *, every_steps: int = 100,
                 keep: int = 3):
        self.directory = Path(directory)
        self.every_steps = every_steps
        self.keep = keep
        self._writer: threading.Thread | None = None
        self._last_saved: int | None = None
        self._lock = threading.Lock()

    # -------------------------------------------------- decisions
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0

    # -------------------------------------------------- save paths
    def save(self, step: int, tree: PyTree, extra: dict | None = None,
             blocking: bool = True) -> None:
        if blocking:
            self.wait()
            self._save_now(step, tree, extra)
            return
        # async: snapshot to host synchronously, write in background
        self.wait()
        flat = [(p, np.asarray(jax.device_get(l))) for p, l in tree_paths(tree)]
        snapshot = unflatten_dict(dict(flat))

        def _bg():
            self._save_now(step, snapshot, extra)

        self._writer = threading.Thread(target=_bg, daemon=True)
        self._writer.start()

    def _save_now(self, step: int, tree: PyTree, extra: dict | None) -> None:
        with self._lock:
            save_checkpoint(self.directory, step, tree, extra)
            self._last_saved = step
            self._gc()

    def wait(self) -> None:
        if self._writer is not None and self._writer.is_alive():
            self._writer.join()
        self._writer = None

    # -------------------------------------------------- restore
    def restore(self, shardings: PyTree | None = None,
                step: int | None = None) -> tuple[PyTree, dict] | None:
        try:
            return load_checkpoint(self.directory, step, shardings)
        except FileNotFoundError:
            return None

    @property
    def last_saved(self) -> int | None:
        return self._last_saved

    # -------------------------------------------------- retention
    def _gc(self) -> None:
        steps = sorted(
            int(c.name.split("_")[1])
            for c in self.directory.iterdir()
            if c.name.startswith("step_") and not c.name.endswith(".tmp")
            and _is_committed(c))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
