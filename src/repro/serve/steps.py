"""Serving-step builders: prefill_step (prompt → cache + last logits) and
serve_step (one decode token against a KV/SSM cache). Caches are donated —
decode updates in place.

Serving uses bf16 params (the config is rewritten on entry) and, for the
large archs, 2D weight sharding so weights + cache fit HBM.
"""
from __future__ import annotations

from dataclasses import replace
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, ShapeConfig
from repro.models import encdec, lm
from repro.sharding import rules
from repro.train.steps import param_structs

PyTree = Any


def serve_config(cfg: LMConfig) -> LMConfig:
    """Serving numerics: bf16 params, no remat, dropless MoE.

    capacity_factor = E/K makes the expert capacity cover the worst-case
    routing (every token to one expert), so inference never drops tokens —
    drops are a *training* regularizer; at serving they would make outputs
    depend on batch composition (vLLM/DeepSeek practice: dropless decode).
    """
    kw = dict(param_dtype="bfloat16", remat="none")
    if cfg.n_experts:
        kw["capacity_factor"] = cfg.n_experts / max(cfg.top_k, 1)
    return replace(cfg, **kw)


def cache_structs(cfg: LMConfig, mesh: Mesh, batch: int, max_len: int,
                  enc_len: int | None = None) -> PyTree:
    if cfg.is_encdec:
        shapes = jax.eval_shape(partial(encdec.init_cache, cfg, batch, max_len,
                                        enc_len or max_len))
    else:
        shapes = jax.eval_shape(partial(lm.init_cache, cfg, batch, max_len))
    specs = rules.cache_pspecs(shapes, cfg, mesh, batch)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_serve_step(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh,
                     donate: bool = True):
    """One-token decode step. Returns (jitted, (params_sds, token_sds, pos_sds,
    cache_sds))."""
    cfg = serve_config(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_sds, _ = param_structs(cfg, mesh)
    c_sds = cache_structs(cfg, mesh, B, S, enc_len=S if cfg.is_encdec else None)
    bspec = rules.input_pspecs(cfg, shape, mesh)["tokens"]
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, bspec))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    decode = encdec.decode_step if cfg.is_encdec else lm.decode_step

    def step(params, token, pos, cache):
        logits, cache = decode(params, token, pos, cache, cfg)
        return logits, cache

    cache_shardings = jax.tree.map(
        lambda s: s.sharding, c_sds,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    logits_sharding = NamedSharding(
        mesh, P(bspec[0] if len(bspec) else None, None, "model"))
    jitted = jax.jit(step,
                     donate_argnums=(3,) if donate else (),
                     out_shardings=(logits_sharding, cache_shardings))
    return jitted, (p_sds, tok_sds, pos_sds, c_sds), cfg


def build_prefill_step(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh):
    """Prompt prefill: tokens [B,S] → (last logits, cache)."""
    cfg = serve_config(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_sds, _ = param_structs(cfg, mesh)
    in_specs = rules.input_pspecs(cfg, shape, mesh)
    tok_sds = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                   sharding=NamedSharding(mesh, in_specs["tokens"]))
    extras = {}
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        extras["img_embed"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.vision_dim), cdt,
            sharding=NamedSharding(mesh, in_specs["img_embed"]))
    if cfg.is_encdec:
        extras["frames"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), cdt,
            sharding=NamedSharding(mesh, in_specs["frames"]))

    c_sds = cache_structs(cfg, mesh, B, S, enc_len=S if cfg.is_encdec else None)
    cache_shardings = jax.tree.map(
        lambda s: s.sharding, c_sds,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    logits_sharding = NamedSharding(
        mesh, P(in_specs["tokens"][0] if len(in_specs["tokens"]) else None, "model"))

    if cfg.is_encdec:
        def step(params, tokens, frames):
            return encdec.prefill(params, frames, tokens, cfg)
        args = (p_sds, tok_sds, extras["frames"])
    elif cfg.family == "vlm":
        def step(params, tokens, img_embed):
            return lm.prefill(params, tokens, cfg, img_embed=img_embed)
        args = (p_sds, tok_sds, extras["img_embed"])
    else:
        def step(params, tokens):
            return lm.prefill(params, tokens, cfg)
        args = (p_sds, tok_sds)

    jitted = jax.jit(step, out_shardings=(logits_sharding, cache_shardings))
    return jitted, args, cfg
