"""Continuous-batching slot manager — the model-agnostic core shared by
LM decode serving (repro.launch.serve) and event-stream serving
(repro.stream.engine).

A ``SlotManager`` is a fixed-capacity table of serving lanes. The pattern
both servers follow:

  * a queue of pending work items (LM requests / event streams);
  * ``refill(queue)`` admits items from the queue head into free lanes at
    every batching boundary (decode step / T_INTG window boundary);
  * one jitted step advances every occupied lane at once (the fixed batch
    is what keeps the compiled step shape-stable);
  * finished lanes ``release()`` and the freed capacity is refilled on
    the next boundary — no draining, no recompilation.

The manager only does the bookkeeping (which lane holds what); resetting
per-lane model state (KV rows, charge accumulators, LIF membranes) is the
consumer's job, keyed by the lane index this class hands out.
"""
from __future__ import annotations

from collections import deque
from typing import Generic, Iterator, TypeVar

T = TypeVar("T")


class SlotManager(Generic[T]):
    """Fixed-capacity lane table with admit / release / refill."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._items: list[T | None] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._items)

    @property
    def n_occupied(self) -> int:
        return sum(item is not None for item in self._items)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_occupied

    def is_empty(self) -> bool:
        return self.n_occupied == 0

    def is_full(self) -> bool:
        return self.n_free == 0

    def get(self, slot: int) -> T | None:
        return self._items[slot]

    def occupied(self) -> Iterator[tuple[int, T]]:
        """(lane index, item) pairs for every occupied lane, in lane order
        — the iteration every batched step runs."""
        for i, item in enumerate(self._items):
            if item is not None:
                yield i, item

    def active_mask(self) -> list[bool]:
        """Per-lane occupancy, aligned with the batch axis of the jitted
        step (lane i ↔ batch row i)."""
        return [item is not None for item in self._items]

    def admit(self, item: T) -> int | None:
        """Place ``item`` into the lowest free lane. Returns the lane
        index, or None when every lane is occupied."""
        if item is None:
            raise ValueError("cannot admit None (None marks a free lane)")
        for i, existing in enumerate(self._items):
            if existing is None:
                self._items[i] = item
                return i
        return None

    def release(self, slot: int) -> T:
        """Free ``slot`` and return the item it held."""
        item = self._items[slot]
        if item is None:
            raise ValueError(f"slot {slot} is already free")
        self._items[slot] = None
        return item

    def refill(self, queue: deque[T]) -> list[tuple[int, T]]:
        """Admit items from the head of ``queue`` (in order, popping them
        via ``popleft``) until the queue is empty or every lane is full.
        Returns the (lane, item) placements so the consumer can
        initialize per-lane model state.

        ``queue`` must be a :class:`collections.deque` (or anything with
        ``popleft``): the saturation harness queues thousands of pending
        streams, and popping a Python list's head is O(n) per admit —
        O(n²) over a long backlog."""
        if not hasattr(queue, "popleft"):
            raise TypeError(
                f"refill requires a deque-like queue with popleft "
                f"(got {type(queue).__name__}); list-head pops are "
                f"quadratic over long pending queues")
        placed: list[tuple[int, T]] = []
        while queue and not self.is_full():
            item = queue.popleft()
            slot = self.admit(item)
            assert slot is not None
            placed.append((slot, item))
        return placed
