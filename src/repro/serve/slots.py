"""Continuous-batching slot manager — the model-agnostic core shared by
LM decode serving (repro.launch.serve) and event-stream serving
(repro.stream.engine).

A ``SlotManager`` is a fixed-capacity table of serving lanes. The pattern
both servers follow:

  * a queue of pending work items (LM requests / event streams);
  * ``refill(queue)`` admits items from the queue head into free lanes at
    every batching boundary (decode step / T_INTG window boundary);
  * one jitted step advances every occupied lane at once (the fixed batch
    is what keeps the compiled step shape-stable);
  * finished lanes ``release()`` and the freed capacity is refilled on
    the next boundary — no draining, no recompilation.

The manager only does the bookkeeping (which lane holds what); resetting
per-lane model state (KV rows, charge accumulators, LIF membranes) is the
consumer's job, keyed by the lane index this class hands out.

When the jitted step's batch axis is sharded over a device mesh
(repro.stream.shard), :class:`ShardedSlots` stacks one ``SlotManager``
per mesh shard behind the same surface: a single admission front fills
the lowest free lane across ALL shards, and the global lane index maps
contiguously onto the sharded batch axis.
"""
from __future__ import annotations

from collections import deque
from typing import Generic, Iterator, TypeVar

T = TypeVar("T")


class SlotManager(Generic[T]):
    """Fixed-capacity lane table with admit / release / refill."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._items: list[T | None] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._items)

    @property
    def n_occupied(self) -> int:
        return sum(item is not None for item in self._items)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_occupied

    def is_empty(self) -> bool:
        return self.n_occupied == 0

    def is_full(self) -> bool:
        return self.n_free == 0

    def get(self, slot: int) -> T | None:
        return self._items[slot]

    def occupied(self) -> Iterator[tuple[int, T]]:
        """(lane index, item) pairs for every occupied lane, in lane order
        — the iteration every batched step runs."""
        for i, item in enumerate(self._items):
            if item is not None:
                yield i, item

    def active_mask(self) -> list[bool]:
        """Per-lane occupancy, aligned with the batch axis of the jitted
        step (lane i ↔ batch row i)."""
        return [item is not None for item in self._items]

    def admit(self, item: T) -> int | None:
        """Place ``item`` into the lowest free lane. Returns the lane
        index, or None when every lane is occupied."""
        if item is None:
            raise ValueError("cannot admit None (None marks a free lane)")
        for i, existing in enumerate(self._items):
            if existing is None:
                self._items[i] = item
                return i
        return None

    def release(self, slot: int) -> T:
        """Free ``slot`` and return the item it held."""
        item = self._items[slot]
        if item is None:
            raise ValueError(f"slot {slot} is already free")
        self._items[slot] = None
        return item

    def swap(self, slot: int, item: T) -> T:
        """Replace the item in OCCUPIED lane ``slot`` in place and return
        the old item — rebinding a resident lane (e.g. to a hot-swapped
        registry entry) without ever exposing the lane as free, so no
        concurrent ``admit``/``refill`` can steal it mid-rebind."""
        if item is None:
            raise ValueError("cannot swap in None (None marks a free lane)")
        old = self._items[slot]
        if old is None:
            raise ValueError(f"slot {slot} is free — swap only rebinds "
                             f"occupied lanes (use admit)")
        self._items[slot] = item
        return old

    def refill(self, queue: deque[T]) -> list[tuple[int, T]]:
        """Admit items from the head of ``queue`` (in order, popping them
        via ``popleft``) until the queue is empty or every lane is full.
        Returns the (lane, item) placements so the consumer can
        initialize per-lane model state.

        ``queue`` must be a :class:`collections.deque` (or anything with
        ``popleft``): the saturation harness queues thousands of pending
        streams, and popping a Python list's head is O(n) per admit —
        O(n²) over a long backlog."""
        if not hasattr(queue, "popleft"):
            raise TypeError(
                f"refill requires a deque-like queue with popleft "
                f"(got {type(queue).__name__}); list-head pops are "
                f"quadratic over long pending queues")
        placed: list[tuple[int, T]] = []
        while queue and not self.is_full():
            item = queue.popleft()
            slot = self.admit(item)
            assert slot is not None
            placed.append((slot, item))
        return placed


class ShardedSlots(Generic[T]):
    """Per-shard :class:`SlotManager` table presenting one global lane
    space ``[0, capacity)`` embedded in a padded axis
    ``[0, padded_capacity)``.

    Built for a jitted batch axis sharded over ``devices`` mesh shards
    (repro.stream.shard): shard ``s`` owns the contiguous global lanes
    ``[s·L, (s+1)·L)`` — the block ``shard_map`` places on device ``s``,
    with ``L = padded_capacity / devices`` — of which only the first
    ``capacity`` global lanes are REAL (admittable). The
    ``padded_capacity − capacity`` tail lanes exist solely to make the
    lane axis divide the mesh; they are never admitted, so they run the
    batched step masked inactive. With ``devices=1`` this degenerates to
    exactly one plain ``SlotManager``.

    Admission stays a SINGLE front: ``admit`` fills the lowest free
    global lane across all shards, so a lane freed on any shard can take
    the head of the one pending queue, and sharded placement matches a
    devices=1 ``SlotManager`` lane-for-lane.
    """

    def __init__(self, capacity: int, devices: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        self._capacity = capacity
        self.devices = devices
        self.padded_capacity = -(-capacity // devices) * devices
        self.lanes_per_shard = self.padded_capacity // devices
        # shard s's manager covers its REAL lanes only (None when the
        # shard is pure padding, i.e. capacity <= s·L)
        self._shards: list[SlotManager[T] | None] = []
        for s in range(devices):
            real = min(self.lanes_per_shard,
                       max(0, capacity - s * self.lanes_per_shard))
            self._shards.append(SlotManager(real) if real else None)

    # -- capacity bookkeeping (mirrors the SlotManager surface) ---------
    @property
    def capacity(self) -> int:
        return self._capacity

    @property
    def n_occupied(self) -> int:
        return sum(m.n_occupied for m in self._shards if m is not None)

    @property
    def n_free(self) -> int:
        return self._capacity - self.n_occupied

    def is_empty(self) -> bool:
        return self.n_occupied == 0

    def is_full(self) -> bool:
        return self.n_free == 0

    # -- global-lane addressing -----------------------------------------
    def shard_of(self, lane: int) -> int:
        """The mesh shard (device index) global lane ``lane`` lives on."""
        if not 0 <= lane < self.padded_capacity:
            raise ValueError(f"lane {lane} outside padded capacity "
                             f"{self.padded_capacity}")
        return lane // self.lanes_per_shard

    def admit(self, item: T) -> int | None:
        """Place ``item`` into the lowest free REAL global lane (shards
        scanned in order, so placement matches a single devices=1
        ``SlotManager`` exactly). Returns the global lane index, or None
        when every real lane is occupied."""
        for s, mgr in enumerate(self._shards):
            if mgr is None or mgr.is_full():
                continue
            local = mgr.admit(item)
            assert local is not None
            return s * self.lanes_per_shard + local
        return None

    def release(self, lane: int) -> T:
        """Free global lane ``lane`` and return the item it held."""
        s = self.shard_of(lane)
        mgr = self._shards[s]
        local = lane - s * self.lanes_per_shard
        if mgr is None or local >= mgr.capacity:
            raise ValueError(f"lane {lane} is a padding lane")
        return mgr.release(local)

    def swap(self, lane: int, item: T) -> T:
        """Replace the item in occupied global lane ``lane`` in place and
        return the old item (padding lanes can never hold an item, so
        they reject just like ``release``)."""
        s = self.shard_of(lane)
        mgr = self._shards[s]
        local = lane - s * self.lanes_per_shard
        if mgr is None or local >= mgr.capacity:
            raise ValueError(f"lane {lane} is a padding lane")
        return mgr.swap(local, item)

    def occupied(self) -> Iterator[tuple[int, T]]:
        """(global lane, item) pairs in global lane order — the iteration
        the batched fold/readout masks follow."""
        for s, mgr in enumerate(self._shards):
            if mgr is None:
                continue
            base = s * self.lanes_per_shard
            for local, item in mgr.occupied():
                yield base + local, item

    def active_mask(self) -> list[bool]:
        """Per-lane occupancy over the FULL padded axis (padding lanes
        always False), aligned with the sharded batch axis."""
        mask = [False] * self.padded_capacity
        for lane, _ in self.occupied():
            mask[lane] = True
        return mask

    def per_shard_occupied(self) -> list[int]:
        """Occupied-lane count per shard (the artifact's load-balance
        view of the mesh)."""
        return [0 if m is None else m.n_occupied for m in self._shards]
