"""Sweep launcher.

Default mode — the batched CO-DESIGN sweep (paper Fig 2/4 + Table 1): one
in-process, vmap-batched run over the circuit-VARIANT grid × T_INTG via
repro.core.sweep, emitting ONE structured JSON artifact (schema
"p2m-codesign-sweep/v3", see docs/sweep.md). The variant axes come from
the registry in repro.core.variant_grid: ``--axes`` activates any of
``mismatch`` / ``v-threshold`` / ``sigma`` / ``n-sub`` with its default
value grid, and each axis also has an explicit value flag. ``--devices n``
shards the stacked variant axis over a 1-D device mesh (on CPU force host
devices with XLA_FLAGS=--xla_force_host_platform_device_count=n);
sharded and single-device runs emit identical records. --protocol picks
the phase-2 finetune protocol(s): "frozen" (paper §3 — layer 1 fixed),
"unfrozen" (each circuit variant learns its own layer-1 weights), or
"both" (default: one shared pretrain, records for both protocols in one
artifact so the co-design optimum can be compared). ``--dataset`` picks
the event source (repro.data.sources): the synthetic generators by
default, or the file-backed DVS128-Gesture / N-MNIST loaders with
``--data-root`` pointing at the dataset directory (docs/datasets.md):

  PYTHONPATH=src python -m repro.launch.sweep --grid paper
  PYTHONPATH=src python -m repro.launch.sweep --grid fast --protocol frozen
  PYTHONPATH=src python -m repro.launch.sweep --grid paper \\
      --circuits a c --t-intg 1 10 100 1000 --mismatch 0.02 0.06
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.launch.sweep --grid fast \\
      --axes v-threshold sigma --devices 8
  PYTHONPATH=src python -m repro.launch.sweep --grid fast \\
      --dataset dvs128 --data-root /data/DvsGesture

Legacy mode — the dry-run cell sweep (one subprocess per arch × shape ×
pods cell so XLA state never accumulates across the 60+ compiles;
resumable — cells with an existing 'ok'/'skipped' JSON are not re-run
unless --force):

  PYTHONPATH=src python -m repro.launch.sweep --dryrun-cells --pods 1 2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

# Make the CLI runnable from any cwd: resolve the package root relative to
# THIS file instead of assuming the repo root is the working directory.
# (When repro is pip-installed this resolves inside site-packages, which is
# already importable — the insert is then a harmless no-op entry.)
_SRC = str(Path(__file__).resolve().parents[2])
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


# ---------------------------------------------------------------------------
# co-design grid sweep (default) — built on repro.core.sweep
# ---------------------------------------------------------------------------

def run_codesign_grid(args) -> int:
    from dataclasses import replace

    from repro.core import sweep as engine
    from repro.core import variant_grid
    from repro.core.leakage import CircuitConfig
    from repro.core.sweep_exec import make_executor

    fast = args.grid == "fast"
    try:
        data, model, sweep_cfg, grid = engine.paper_setup(
            fast=fast, hw=args.hw, dataset=args.dataset,
            data_root=args.data_root)
    except (ValueError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    # file-backed datasets: eval on the held-out split so record
    # accuracies are out-of-sample (synthetic streams have no split)
    from repro.data import sources as sources_mod
    eval_data, eval_split = sources_mod.resolve_eval_dataset(
        args.dataset, hw=args.hw, data_root=args.data_root)
    if eval_split == "train":
        print("note: val split of the dataset is empty — evaluating on "
              "the training split", file=sys.stderr)
    if args.circuits:
        grid = replace(grid, circuits=tuple(
            CircuitConfig(c) for c in args.circuits))
    if args.t_intg:
        grid = replace(grid, t_intg_grid_ms=tuple(sorted(args.t_intg)))

    # variant axes: an explicit value flag wins; --axes <name> activates the
    # axis with its registry default grid. null_mismatch keeps its preset
    # default (0.06) when untouched — the PR-1 grid.
    explicit = {"null_mismatch": args.mismatch,
                "v_threshold": args.v_threshold,
                "sigma": args.sigma,
                "n_sub": args.n_sub}
    active = {variant_grid.axis("null-mismatch" if n == "mismatch" else n
                                ).name for n in (args.axes or [])}
    overrides = {}
    for name, vals in explicit.items():
        if vals is None and name in active:
            vals = variant_grid.axis(name).cli_defaults
        if vals is not None:
            try:
                overrides[name] = variant_grid.check_values(name, vals)
            except ValueError as e:
                print(f"error: {e}", file=sys.stderr)
                return 2
    grid = replace(grid, **overrides)
    mismatch_requested = args.mismatch is not None or \
        "null_mismatch" in active
    if mismatch_requested and CircuitConfig.NULLIFIED not in grid.circuits:
        print("note: the mismatch axis only affects circuit (c), which is "
              "not in this grid — values ignored", file=sys.stderr)

    for t in grid.t_intg_grid_ms:
        g = model.coarse_window_ms / t
        if abs(g - round(g)) > 1e-6:
            print(f"error: --t-intg {t:g} must divide the backbone coarse "
                  f"window ({model.coarse_window_ms:g} ms)", file=sys.stderr)
            return 2

    protocols = engine.resolve_protocols(args.protocol)
    try:
        executor = make_executor(args.devices)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    t0 = time.time()
    results = engine.run_protocols(data, model, sweep_cfg, grid,
                                   protocols=protocols, executor=executor,
                                   eval_data=eval_data)
    wall_s = time.time() - t0

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"codesign_grid_{args.grid}.json"
    artifact = engine.protocols_artifact(results, extra_meta={
        "wall_s": wall_s,
        "devices": executor.devices,
        "data": {"name": data.name, "dataset": args.dataset,
                 "data_root": args.data_root, "hw": data.height,
                 "n_classes": data.n_classes,
                 "duration_ms": data.duration_ms,
                 "eval_split": eval_split},
        "sweep": {"batch_size": sweep_cfg.batch_size,
                  "pretrain_steps": sweep_cfg.pretrain_steps,
                  "finetune_steps": sweep_cfg.finetune_steps,
                  "eval_batches": sweep_cfg.eval_batches},
    })
    path.write_text(json.dumps(artifact, indent=2, default=float))

    first = next(iter(results.values()))
    print(f"\n=== co-design grid sweep ({len(first.labels)} circuit cfgs "
          f"× {len(grid.t_intg_grid_ms)} T_INTG × "
          f"{'/'.join(protocols)}, {wall_s:.0f}s) ===")
    print(f"{'protocol':>9} {'config':>10} {'T_INTG':>8} {'acc':>6} "
          f"{'bw':>7} {'energy':>8} {'ret_mV':>8}")
    for proto, result in results.items():
        for r in result.records:
            print(f"{proto:>9} {r['label']:>10} {r['t_intg_ms']:6.0f}ms "
                  f"{r['accuracy']:6.3f} {r['bandwidth_norm']:6.2f}x "
                  f"{r['energy_improvement']:7.2f}x "
                  f"{r['retention_err_v'] * 1e3:8.2f}")
    print(f"artifact: {path}")
    return 0


# ---------------------------------------------------------------------------
# legacy dry-run cell sweep (subprocess per cell)
# ---------------------------------------------------------------------------

def run_dryrun_cells(args) -> int:
    from repro.configs import SHAPES, list_archs

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = args.archs or list_archs()
    shapes = args.shapes or list(SHAPES)

    cells = [(a, s, p) for a in archs for s in shapes for p in args.pods]
    t0 = time.time()
    n_err = 0
    for i, (arch, shape, pods) in enumerate(cells):
        path = out / f"{arch}__{shape}__{pods}pod.json"
        if path.exists() and not args.force:
            try:
                rec = json.loads(path.read_text())
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[{i+1}/{len(cells)}] {arch}×{shape}×{pods}pod cached "
                          f"({rec['status']})", flush=True)
                    continue
            except json.JSONDecodeError:
                pass
        inherited = os.environ.get("PYTHONPATH")
        env = dict(os.environ,
                   PYTHONPATH=(_SRC + os.pathsep + inherited
                               if inherited else _SRC),
                   REPRO_ARTIFACTS=str(out))
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--pods", str(pods),
               "--out", str(out)]
        t1 = time.time()
        try:
            proc = subprocess.run(cmd, env=env, timeout=args.timeout,
                                  capture_output=True, text=True)
            rc = proc.returncode
        except subprocess.TimeoutExpired:
            rc = -9
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "pods": pods,
                "status": "error", "error": f"timeout {args.timeout}s"}))
        status = "?"
        if path.exists():
            try:
                status = json.loads(path.read_text()).get("status", "?")
            except json.JSONDecodeError:
                status = "corrupt"
        if status == "error" or rc != 0:
            n_err += 1
        print(f"[{i+1}/{len(cells)}] {arch}×{shape}×{pods}pod {status} "
              f"rc={rc} {time.time()-t1:.0f}s (total {time.time()-t0:.0f}s)",
              flush=True)
    print(f"sweep done: {n_err} errors, {time.time()-t0:.0f}s", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dryrun-cells", action="store_true",
                    help="legacy arch×shape×pods dry-run sweep "
                         "(subprocess per cell)")
    # co-design grid options
    ap.add_argument("--grid", type=str, default="paper",
                    choices=["paper", "fast"],
                    help="co-design grid preset (default: paper = 3 "
                         "circuits × 4 T_INTG)")
    ap.add_argument("--circuits", type=str, nargs="+", default=None,
                    choices=["a", "b", "c"], help="override circuit configs")
    ap.add_argument("--t-intg", type=float, nargs="+", default=None,
                    help="override T_INTG grid (ms)")
    ap.add_argument("--axes", type=str, nargs="+", default=None,
                    choices=["mismatch", "null-mismatch", "v-threshold",
                             "sigma", "n-sub"],
                    help="activate variant axes with their registry default "
                         "value grids (core/variant_grid.py); explicit "
                         "value flags below override")
    ap.add_argument("--mismatch", type=float, nargs="+", default=None,
                    dest="mismatch",
                    help="nullifier mismatch values for circuit (c)")
    ap.add_argument("--v-threshold", type=float, nargs="+", default=None,
                    help="comparator threshold values (V) — expands every "
                         "circuit")
    ap.add_argument("--sigma", type=float, nargs="+", default=None,
                    help="process-variation sigma values on the leak taus")
    ap.add_argument("--n-sub", type=int, nargs="+", default=None,
                    help="event sub-slots per window (shape-changing: "
                         "outer loop with T_INTG)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the stacked variant axis over this many "
                         "devices (1-D cfg mesh via shard_map); on CPU "
                         "force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--protocol", type=str, default="both",
                    choices=["frozen", "unfrozen", "both"],
                    help="phase-2 finetune protocol(s): frozen layer 1 "
                         "(paper §3), unfrozen joint layer-1+backbone "
                         "training, or both off one shared pretrain")
    ap.add_argument("--dataset", type=str, default="synthetic-gesture",
                    choices=["synthetic-gesture", "synthetic-nmnist",
                             "dvs128", "nmnist"],
                    help="event source (repro.data.sources): synthetic-* "
                         "need no files; dvs128 (AEDAT 3.1) and nmnist "
                         "(.bin) read --data-root (docs/datasets.md)")
    ap.add_argument("--data-root", type=str, default=None,
                    help="dataset directory for the file-backed datasets "
                         "(binned frames are cached under "
                         "<root>/.p2m-frame-cache)")
    ap.add_argument("--hw", type=int, default=16,
                    help="event-frame resolution (synthetic grid size / "
                         "file-backed downscale target)")
    # legacy dry-run options
    ap.add_argument("--pods", type=int, nargs="+", default=None)
    ap.add_argument("--archs", type=str, nargs="+", default=None)
    ap.add_argument("--shapes", type=str, nargs="+", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    if args.dryrun_cells:
        args.pods = args.pods or [1, 2]
        args.out = args.out or "artifacts/dryrun"
        return run_dryrun_cells(args)
    if args.pods or args.archs or args.shapes or args.force:
        print("error: --pods/--archs/--shapes/--force belong to the legacy "
              "cell sweep — pass --dryrun-cells to run it", file=sys.stderr)
        return 2
    args.out = args.out or "artifacts/sweep"
    return run_codesign_grid(args)


if __name__ == "__main__":
    sys.exit(main())
