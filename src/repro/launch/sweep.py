"""Dry-run sweep driver: one subprocess per (arch × shape × pods) cell so XLA
state never accumulates across the 60+ compiles. Resumable: cells with an
existing 'ok'/'skipped' JSON are not re-run unless --force.

  PYTHONPATH=src python -m repro.launch.sweep --pods 1 2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, nargs="+", default=[1, 2])
    ap.add_argument("--archs", type=str, nargs="+", default=None)
    ap.add_argument("--shapes", type=str, nargs="+", default=None)
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    sys.path.insert(0, "src")
    from repro.configs import SHAPES, list_archs

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = args.archs or list_archs()
    shapes = args.shapes or list(SHAPES)

    cells = [(a, s, p) for a in archs for s in shapes for p in args.pods]
    t0 = time.time()
    n_err = 0
    for i, (arch, shape, pods) in enumerate(cells):
        path = out / f"{arch}__{shape}__{pods}pod.json"
        if path.exists() and not args.force:
            try:
                rec = json.loads(path.read_text())
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[{i+1}/{len(cells)}] {arch}×{shape}×{pods}pod cached "
                          f"({rec['status']})", flush=True)
                    continue
            except json.JSONDecodeError:
                pass
        env = dict(os.environ,
                   PYTHONPATH="src",
                   REPRO_ARTIFACTS=str(out))
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--pods", str(pods),
               "--out", str(out)]
        t1 = time.time()
        try:
            proc = subprocess.run(cmd, env=env, timeout=args.timeout,
                                  capture_output=True, text=True)
            rc = proc.returncode
        except subprocess.TimeoutExpired:
            rc = -9
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "pods": pods,
                "status": "error", "error": f"timeout {args.timeout}s"}))
        status = "?"
        if path.exists():
            try:
                status = json.loads(path.read_text()).get("status", "?")
            except json.JSONDecodeError:
                status = "corrupt"
        if status == "error" or rc != 0:
            n_err += 1
        print(f"[{i+1}/{len(cells)}] {arch}×{shape}×{pods}pod {status} "
              f"rc={rc} {time.time()-t1:.0f}s (total {time.time()-t0:.0f}s)",
              flush=True)
    print(f"sweep done: {n_err} errors, {time.time()-t0:.0f}s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
