import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Pipeline-parallel dry-run: lower + compile the PP train step on a
(pipe=4, data=16, model=8) = 512-chip mesh — the beyond-spec growth mode
(DESIGN.md §4). Subprocess-only, like dryrun.py.

  PYTHONPATH=src python -m repro.launch.dryrun_pp [--arch internlm2-1.8b]
"""
import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.roofline.hlo import analyze_hlo
from repro.roofline.model import roofline_terms


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="internlm2-1.8b")
    ap.add_argument("--pipe", type=int, default=4)
    ap.add_argument("--data", type=int, default=16)
    ap.add_argument("--model", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    args = ap.parse_args()

    from repro.train import pipeline as pp

    cfg = get_config(args.arch)
    assert cfg.family == "dense", "PP dry-run covers the dense family"
    assert cfg.n_layers % args.pipe == 0
    mesh = jax.make_mesh((args.pipe, args.data, args.model),
                         ("pipe", "data", "model"))
    t0 = time.perf_counter()
    with mesh:
        shapes = jax.eval_shape(
            lambda k: pp.stage_params(k, cfg, args.pipe), jax.random.PRNGKey(0))
        pspecs = pp.stage_pspecs(shapes, cfg, mesh)
        p_sds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            shapes, pspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        tok = jax.ShapeDtypeStruct(
            (args.batch, args.seq), jnp.int32,
            sharding=NamedSharding(mesh, P("data", None)))
        step = pp.build_pp_train_step(cfg, mesh,
                                      n_microbatches=args.microbatches)
        lowered = step.lower(p_sds, tok, tok)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    parsed = analyze_hlo(compiled.as_text(), pod_stride=256)
    chips = mesh.devices.size
    terms = roofline_terms(parsed.flops * chips, parsed.bytes * chips,
                           parsed.collective_bytes * chips, chips)
    rec = {
        "arch": args.arch, "mode": "pipeline",
        "mesh": {"pipe": args.pipe, "data": args.data, "model": args.model},
        "status": "ok", "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "collectives": parsed.as_dict(), "roofline": terms,
    }
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{args.arch}__pp_train__{chips}c.json").write_text(
        json.dumps(rec, indent=2))
    cp = parsed.coll_count.get("collective-permute", 0)
    print(f"[dryrun-pp] {args.arch} pipe={args.pipe} ok "
          f"compile={rec['compile_s']}s dominant={terms['dominant']} "
          f"bound={terms['roofline_bound_s']:.3f}s "
          f"collective-permutes={cp:.0f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
