import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, record memory/cost analysis + collective schedule (roofline §).

MUST be executed as a module entry (``python -m repro.launch.dryrun``) or
subprocess — the XLA_FLAGS line above runs before any jax import, and device
count is locked at first jax init. Never import this module from tests.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --pods 1
  python -m repro.launch.dryrun --all --pods 1 2   # every applicable cell
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import analyze_hlo
from repro.roofline.model import HW_V5E, model_flops, roofline_terms

ARTIFACT_DIR = Path(os.environ.get("REPRO_ARTIFACTS", "artifacts/dryrun"))


def input_specs(arch: str, shape_name: str, mesh) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the lowered step —
    weak-type-correct, shardable, no device allocation."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        from repro.train.steps import build_train_step
        step, (p, o, b), _ = build_train_step(cfg, shape, mesh)
        return step, {"params": p, "opt_state": o, "batch": b}
    if shape.kind == "prefill":
        from repro.serve.steps import build_prefill_step
        step, args, _ = build_prefill_step(cfg, shape, mesh)
        return step, {"args": args}
    if shape.kind == "decode":
        from repro.serve.steps import build_serve_step
        step, (p, tok, pos, cache), _ = build_serve_step(cfg, shape, mesh)
        return step, {"args": (p, tok, pos, cache)}
    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, pods: int, save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "pods": pods,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(pods == 2))
    chips = mesh.devices.size
    t0 = time.perf_counter()
    rec = {"arch": arch, "shape": shape_name, "pods": pods, "chips": chips,
           "mesh": dict(zip(mesh.axis_names,
                            [int(x) for x in mesh.devices.shape]))}
    try:
        with mesh:
            step, tree = input_specs(arch, shape_name, mesh)
            if "params" in tree:
                lowered = step.lower(tree["params"], tree["opt_state"],
                                     tree["batch"])
            else:
                lowered = step.lower(*tree["args"])
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        # jaxlib < 0.4.x returned [{...}] (one dict per program); newer
        # versions return the dict directly — normalize to a dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}

        # per-device argument bytes, analytic: CPU-backend memory_analysis
        # reports GLOBAL logical buffers for entry args; divide each leaf by
        # its shard count from the attached sharding.
        import numpy as np

        def leaf_bytes_per_device(sds):
            itemsize = np.dtype(sds.dtype).itemsize
            sh = getattr(sds, "sharding", None)
            if sh is not None:
                try:
                    shard_shape = sh.shard_shape(sds.shape)
                    return int(np.prod(shard_shape)) * itemsize
                except Exception:  # noqa: BLE001
                    pass
            return int(np.prod(sds.shape)) * itemsize if sds.shape else itemsize

        arg_leaves = [x for x in jax.tree.leaves(tree)
                      if isinstance(x, jax.ShapeDtypeStruct)]
        per_dev_args = sum(leaf_bytes_per_device(x) for x in arg_leaves)
        hlo = compiled.as_text()
        # loop-aware HLO cost walk — compiled.cost_analysis() counts while
        # bodies once, which undercounts scanned-layer programs by ~n_layers.
        # The SPMD module is the PER-DEVICE program; scale to global by chips.
        parsed = analyze_hlo(hlo, pod_stride=256)

        flops = float(parsed.flops) * chips
        bytes_acc = float(parsed.bytes) * chips
        coll_global = float(parsed.collective_bytes) * chips
        terms = roofline_terms(flops, bytes_acc, coll_global, chips)
        n_tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                         else (shape.seq_len if shape.kind ==
                                               "prefill" else 1))
        n_params = (cfg.active_param_count_est() if cfg.n_experts
                    else cfg.param_count_est())
        mflops = model_flops(n_params, n_tokens,
                             "train" if shape.kind == "train" else "infer")
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "args_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                # CPU memory_analysis counts entry args at GLOBAL logical
                # size; the analytic per-device figure below divides each
                # arg by its shard count (the fits-in-HBM criterion).
                "per_device_args_bytes": int(per_dev_args),
                "xla_global_total": (mem.argument_size_in_bytes +
                                     mem.temp_size_in_bytes),
            },
            "cost": {"flops": flops, "bytes_accessed": bytes_acc,
                     "per_device_flops": float(parsed.flops),
                     "per_device_bytes": float(parsed.bytes),
                     "xla_flops_unscaled": float(cost.get("flops", 0.0)),
                     "xla_bytes_unscaled": float(cost.get("bytes accessed", 0.0))},
            "collectives": parsed.as_dict(),
            "roofline": terms,
            "model_flops": mflops,
            "useful_flops_ratio": (mflops / flops) if flops else 0.0,
        })
        if save_hlo:
            hlo_path = ARTIFACT_DIR / f"{arch}__{shape_name}__{pods}pod.hlo"
            hlo_path.parent.mkdir(parents=True, exist_ok=True)
            hlo_path.write_text(hlo)
        # free compiled artifacts before the next cell
        del compiled, lowered, hlo
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    rec["wall_s"] = round(time.perf_counter() - t0, 2)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--pods", type=int, nargs="+", default=[1])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", type=str, default=str(ARTIFACT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells: list[tuple[str, str, int]] = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            for p in args.pods:
                cells.append((a, s, p))

    n_fail = 0
    for arch, shape_name, pods in cells:
        rec = run_cell(arch, shape_name, pods, save_hlo=args.save_hlo)
        path = out_dir / f"{arch}__{shape_name}__{pods}pod.json"
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        if status == "error":
            n_fail += 1
            print(f"[dryrun] {arch} × {shape_name} × {pods}pod  ERROR "
                  f"{rec['error'][:160]}", flush=True)
        elif status == "skipped":
            print(f"[dryrun] {arch} × {shape_name} × {pods}pod  SKIP "
                  f"({rec['reason'][:60]})", flush=True)
        else:
            r = rec["roofline"]
            print(f"[dryrun] {arch} × {shape_name} × {pods}pod  ok "
                  f"compile={rec['compile_s']}s flops={rec['cost']['flops']:.3g} "
                  f"coll={rec['collectives']['total_bytes']:.3g}B "
                  f"dominant={r['dominant']} bound={r['roofline_bound_s']:.4f}s",
                  flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
