"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 --batch 8 --seq 256

On a real pod each host runs this with the production mesh; on CPU the
``--smoke`` flag swaps in the reduced same-family config and a host mesh so
the full loop (data → step → checkpoint → restart) exercises end-to-end.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import SHAPES, get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.loop import LoopConfig, run


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="internlm2-1.8b")
    ap.add_argument("--shape", type=str, default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        shape = ShapeConfig("smoke", "train", args.seq, args.batch)
        mesh = make_host_mesh()
    else:
        shape = SHAPES[args.shape]
        mesh = (make_production_mesh(multi_pod=args.multi_pod)
                if args.production_mesh else make_host_mesh())

    loop = LoopConfig(total_steps=args.steps, lr=args.lr,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    def extra_batch(batch):
        # modality stubs: precomputed frame/patch embeddings per spec
        import jax.numpy as jnp
        B = batch["tokens"].shape[0]
        if cfg.family == "vlm":
            k = jax.random.PRNGKey(0)
            batch["img_embed"] = jax.random.normal(
                k, (B, cfg.n_image_tokens, cfg.vision_dim),
                jnp.dtype(cfg.compute_dtype))
        if cfg.is_encdec:
            k = jax.random.PRNGKey(1)
            batch["frames"] = jax.random.normal(
                k, (B, shape.seq_len, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return batch

    needs_extra = cfg.family == "vlm" or cfg.is_encdec
    res = run(cfg, shape, mesh, loop,
              extra_batch_fn=extra_batch if needs_extra else None)
    print(f"[train] done at step {res.final_step} "
          f"first_loss={res.losses[0]:.4f} last_loss={res.losses[-1]:.4f} "
          f"stragglers={res.straggler_flags} preempted={res.preempted}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
