"""Online streaming inference launcher: serve live event streams through
a deployed P²M variant with continuous batching (repro.stream).

Deployment handshake (docs/streaming.md): a sweep artifact is the menu,
a serving checkpoint (written by ``repro.stream.deploy``) is the weights.
Three ways in:

  * ``--checkpoint DIR`` serves an existing deployment;
    ``--artifact PATH`` optionally cross-checks it against the sweep
    artifact it was deployed from;
  * no checkpoint: a fast co-design sweep runs in-process
    (``keep_params=True``), deploys the best record for ``--protocol``
    (``--deploy-t-intg`` pins the integration time), and serves it;
  * ``--smoke``: fully self-contained CI path — if the dataset is
    file-backed and no ``--data-root`` is given, a miniature fixture
    dataset is generated first (repro.data.fixtures), then the tiny
    train → deploy → serve pipeline runs end-to-end on CPU;
  * ``--registry CKPT [CKPT ...]`` serves a DEPLOYMENT REGISTRY
    (repro.stream.registry) of several compat-equal checkpoints from one
    engine — entry names are the checkpoint dir basenames, the FIRST
    one is the default entry. ``--variants SPEC [...]`` assigns each
    stream a variant request, cycled round-robin: a SPEC is an entry
    name (``ckpt_frozen``) or a ``k=v[,k=v...]`` metadata matcher
    (``protocol=frozen``), resolved at admission; unresolvable requests
    are rejected and counted.

``--adapt`` turns on per-lane ONLINE ADAPTATION (repro.stream.adapt):
each serving lane learns a private delta on the deployed layer-1
weights/threshold from its own stream's labels at every coarse-window
readout (``--adapt-rule`` picks surrogate-gradient or reward-modulated
three-factor; ``--adapt-lr``/``--adapt-lr-theta`` scale the steps).
``--adapt-export DIR`` harvests every adapted lane into a validated
delta checkpoint (``deploy.save_adapt_delta``) that re-registers beside
its base — the close of the adapt → harvest → re-serve loop.
Incompatible with ``--use-kernel`` (the fused fold has no VJP).

Emits one serving-stats JSON artifact (schema ``p2m-stream-serving/v5``):
per-stream predictions (with their registry-entry binding), p50/p99
readout latency, events/s (total and per-device), the mesh ``sharding``
block, the ``registry`` per-entry breakdown, the ``adaptation`` block,
admission (shed/rejected/deferred) counters and — under ``--paced`` —
deadline-miss accounting (docs/streaming.md).

``--devices N`` shards the lane axis over a 1-D device mesh
(repro.stream.shard) — bit-identical to ``--devices 1``; ``--bin-workers``
sizes the host binning pool (defaults to the device count). On CPU boxes,
force host devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

  PYTHONPATH=src python -m repro.launch.stream --smoke --streams 8
  PYTHONPATH=src python -m repro.launch.stream --dataset dvs128 \\
      --data-root /data/DvsGesture --checkpoint artifacts/stream/ckpt_frozen \\
      --streams 64 --capacity 16 --devices 4 --bin-workers 4 \\
      --paced --offered-rate 32 --max-pending 128
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
from pathlib import Path

# runnable from any cwd (same pattern as launch/sweep.py)
_SRC = str(Path(__file__).resolve().parents[2])
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

FILE_BACKED = ("dvs128", "nmnist")


def _parse_variant_spec(spec: str):
    """CLI variant request → registry request: a bare entry name, or a
    ``k=v[,k=v...]`` metadata matcher (values parsed as JSON scalars
    when possible, e.g. ``t_intg_ms=100.0``)."""
    if "=" not in spec:
        return spec
    matcher = {}
    for kv in spec.split(","):
        k, _, v = kv.partition("=")
        try:
            matcher[k] = json.loads(v)
        except json.JSONDecodeError:
            matcher[k] = v
    return matcher


def _make_fixture(dataset: str, root: Path) -> None:
    from repro.data import fixtures

    if dataset == "dvs128":
        fixtures.make_dvs128_fixture(root, n_recordings=2,
                                     trials_per_recording=6)
    else:
        fixtures.make_nmnist_fixture(root)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", type=str, default=None,
                    choices=["synthetic-gesture", "synthetic-nmnist",
                             "dvs128", "nmnist"],
                    help="event source to stream (default: dvs128 under "
                         "--smoke — served from a generated fixture — "
                         "else synthetic-gesture)")
    ap.add_argument("--data-root", type=str, default=None,
                    help="dataset directory for file-backed datasets")
    ap.add_argument("--artifact", type=str, default=None,
                    help="sweep artifact JSON to cross-check the "
                         "checkpoint against (deployment handshake)")
    ap.add_argument("--checkpoint", type=str, default=None,
                    help="serving checkpoint dir (repro.stream.deploy); "
                         "omitted: a fast sweep trains and deploys one "
                         "in-process")
    ap.add_argument("--registry", type=str, nargs="+", default=None,
                    metavar="CKPT",
                    help="serve a deployment registry built from these "
                         "checkpoint dirs (entry name = dir basename; "
                         "first entry is the default); mutually exclusive "
                         "with --checkpoint")
    ap.add_argument("--variants", type=str, nargs="+", default=None,
                    metavar="SPEC",
                    help="per-stream variant requests, cycled round-robin "
                         "over the streams: an entry name or a k=v[,k=v] "
                         "metadata matcher (requires --registry)")
    ap.add_argument("--max-entries", type=int, default=None,
                    help="registry engine param-table size (max variants "
                         "co-resident on the lanes; default: entries + 1)")
    ap.add_argument("--streams", type=int, default=8,
                    help="number of event streams to serve")
    ap.add_argument("--capacity", type=int, default=4,
                    help="concurrent serving lanes (the jitted batch)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the lane axis over this many devices on a "
                         "1-D mesh (capacity is padded up to a multiple; "
                         "bit-identical to --devices 1). Default: "
                         "unsharded")
    ap.add_argument("--bin-workers", type=int, default=None,
                    help="host binning worker threads, each owning a "
                         "contiguous lane slice (default: one per device)")
    ap.add_argument("--paced", action="store_true",
                    help="real-time replay: hold each T_INTG window to "
                         "its wall-clock boundary and record deadline "
                         "misses (readouts landing after t_admit + "
                         "k*t_intg); predictions stay bit-identical to "
                         "unpaced replay")
    ap.add_argument("--offered-rate", type=float, default=None,
                    help="offered load, streams/s on the replay clock "
                         "(default: offer all streams up front)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound on the pending admission queue; offers "
                         "beyond capacity + max-pending are shed "
                         "(default: unbounded, no shedding)")
    ap.add_argument("--chunks-per-window", type=int, default=None,
                    help="replay chunks per T_INTG window (must divide "
                         "n_sub; default: one chunk per fine sub-slot)")
    ap.add_argument("--adapt", action="store_true",
                    help="per-lane online adaptation: learn a private "
                         "layer-1 weight/threshold delta on each lane "
                         "from its stream's labels at every coarse "
                         "readout (repro.stream.adapt); frozen serving "
                         "is untouched without this flag")
    ap.add_argument("--adapt-rule", type=str, default="surrogate",
                    choices=["surrogate", "reward"],
                    help="local update rule: surrogate-gradient descent "
                         "on the window readout, or reward-modulated "
                         "three-factor (eligibility traces)")
    ap.add_argument("--adapt-lr", type=float, default=5e-3,
                    help="weight-delta learning rate")
    ap.add_argument("--adapt-lr-theta", type=float, default=0.0,
                    help="comparator-threshold learning rate (default 0: "
                         "thresholds stay deployed)")
    ap.add_argument("--adapt-export", type=str, default=None,
                    metavar="DIR",
                    help="harvest every adapted lane into a validated "
                         "delta checkpoint under DIR/lane<N> "
                         "(deploy.save_adapt_delta) for re-registration")
    ap.add_argument("--use-kernel", action="store_true",
                    help="fold sub-slots through the fused Pallas "
                         "stream_fold kernel instead of the XLA scan "
                         "(bit-exact; compiled on TPU, interpreted "
                         "elsewhere — see docs/kernels.md)")
    ap.add_argument("--protocol", type=str, default="frozen",
                    choices=["frozen", "unfrozen"],
                    help="which phase-2 protocol to train+deploy when no "
                         "--checkpoint is given")
    ap.add_argument("--deploy-t-intg", type=float, default=None,
                    help="pin the deployed record's T_INTG (ms); default: "
                         "best accuracy on the trained grid")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: tiny train steps; generates a fixture "
                         "dataset when file-backed data has no --data-root")
    ap.add_argument("--hw", type=int, default=16,
                    help="event-frame resolution")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default="artifacts/stream")
    args = ap.parse_args()

    from repro.data import sources as sources_mod
    from repro.stream import deploy as deploy_mod
    from repro.stream.adapt import AdaptConfig
    from repro.stream.engine import StreamEngine
    from repro.stream.registry import Registry
    from repro.stream.shard import make_lane_executor

    if args.registry is not None and args.checkpoint is not None:
        print("error: --registry and --checkpoint are mutually exclusive",
              file=sys.stderr)
        return 2
    if args.variants is not None and args.registry is None:
        print("error: --variants requires --registry", file=sys.stderr)
        return 2
    if args.adapt_export is not None and not args.adapt:
        print("error: --adapt-export requires --adapt", file=sys.stderr)
        return 2

    dataset = args.dataset or ("dvs128" if args.smoke
                               else "synthetic-gesture")
    data_root = args.data_root
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    fixture_tmp = None
    if dataset in FILE_BACKED and data_root is None:
        if not args.smoke:
            print(f"error: dataset {dataset!r} is file-backed: pass "
                  f"--data-root (or --smoke to generate a fixture)",
                  file=sys.stderr)
            return 2
        fixture_tmp = tempfile.mkdtemp(prefix=f"p2m-{dataset}-fixture-")
        data_root = fixture_tmp
        print(f"[stream] generating {dataset} fixture under {data_root}")
        _make_fixture(dataset, Path(data_root))

    try:
        default_entry = None
        if args.registry is not None:
            # entry names = checkpoint dir basenames; first = default
            reg = Registry()
            for d in args.registry:
                entry = reg.register_checkpoint(Path(d).name, d,
                                                artifact=args.artifact)
                print(f"[registry] {entry.name}#{entry.uid} "
                      f"({entry.meta.get('label')}/"
                      f"{entry.meta.get('protocol')} "
                      f"T={entry.meta.get('t_intg_ms'):g}ms, compat "
                      f"{entry.compat_digest})")
            target = reg
            default_entry = reg.names()[0]
        elif args.checkpoint is not None:
            target = deploy_mod.load_deployment(args.checkpoint,
                                                args.artifact)
        else:
            # no weights on disk: train + deploy in-process (fast grid)
            smoke_t = (100.0, 1000.0) if args.smoke else None
            bundle = deploy_mod.train_and_deploy(
                out / "deploy", dataset=dataset, data_root=data_root,
                hw=args.hw, protocols=(args.protocol,), smoke=args.smoke,
                t_intg_grid_ms=smoke_t,
                deploy_t_intg_ms=(args.deploy_t_intg if args.deploy_t_intg
                                  is not None else
                                  (100.0 if args.smoke else None)))
            target = deploy_mod.load_deployment(
                bundle["checkpoints"][args.protocol], bundle["artifact"])
        source = sources_mod.resolve_dataset(dataset, hw=args.hw,
                                             data_root=data_root,
                                             split="all")
        adapt = (AdaptConfig(rule=args.adapt_rule, lr_w=args.adapt_lr,
                             lr_theta=args.adapt_lr_theta)
                 if args.adapt else None)
        engine = StreamEngine(target, capacity=args.capacity,
                              chunks_per_window=args.chunks_per_window,
                              use_kernel=args.use_kernel,
                              executor=make_lane_executor(args.devices),
                              bin_workers=args.bin_workers,
                              max_entries=args.max_entries,
                              default_entry=default_entry,
                              adapt=adapt)
        variants = None
        if args.variants is not None:
            reqs = [_parse_variant_spec(s) for s in args.variants]
            variants = lambda sid: reqs[sid % len(reqs)]  # noqa: E731
        report = engine.serve(source, args.streams, seed=args.seed,
                              paced=args.paced,
                              offered_rate=args.offered_rate,
                              max_pending=args.max_pending,
                              variants=variants, log=print)
    except (ValueError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    finally:
        if fixture_tmp is not None:
            shutil.rmtree(fixture_tmp, ignore_errors=True)

    art = report.to_artifact()
    art["data"] = {"dataset": dataset, "data_root": data_root,
                   "hw": args.hw, "n_classes": source.n_classes,
                   "duration_ms": source.duration_ms}
    path = out / f"stream_serving_{dataset}.json"
    path.write_text(json.dumps(art, indent=2, default=float))

    lat, thr = art["latency_ms"], art["throughput"]
    adm, ddl = art["admission"], art["deadlines"]
    print(f"\n=== stream serving ({art['n_streams']} streams, "
          f"{report.capacity} lanes, T_INTG={art['t_intg_ms']:g}ms, "
          f"variant {art['deployed']['label']}/{art['deployed']['protocol']}"
          f"{', paced' if art['paced'] else ''}) ===")
    print(f"accuracy       {art['accuracy']:.3f}")
    print(f"readout p50    {lat['readout_p50']:.2f} ms   "
          f"p99 {lat['readout_p99']:.2f} ms")
    print(f"throughput     {thr['events_per_s']:.0f} events/s   "
          f"{thr['readouts_per_s']:.1f} readouts/s   "
          f"{thr['streams_per_s']:.2f} streams/s")
    sh = art["sharding"]
    print(f"sharding       {sh['devices']} device(s) x "
          f"{sh['lanes_per_shard']} lanes  (padded capacity "
          f"{sh['padded_capacity']}, {sh['bin_workers']} bin worker(s))   "
          f"{thr['events_per_s_per_device']:.0f} events/s/device")
    print(f"admission      offered {adm['n_offered']}  admitted "
          f"{adm['n_admitted']}  shed {adm['n_shed']}  rejected "
          f"{adm['n_rejected']}  deferred {adm['n_deferred']}  max open "
          f"{adm['max_open_streams']}")
    if args.registry is not None:
        for row in art["registry"]["entries"]:
            print(f"variant        {row['name']}#{row['uid']}  admitted "
                  f"{row['n_admitted']}  finished {row['n_finished']}  "
                  f"acc {row['accuracy']:.3f}  misses {row['n_misses']}  "
                  f"{row['events_per_s']:.0f} events/s")
    if art["paced"]:
        mg = ddl["margin_ms"]
        print(f"deadlines      {ddl['n_misses']}/{ddl['n_deadlines']} "
              f"missed ({ddl['miss_rate']:.2%})   margin p50 "
              f"{mg['p50']:.2f} ms  p99 {mg['p99']:.2f} ms  max "
              f"{mg['max']:.2f} ms")
    ad = art["adaptation"]
    if ad["enabled"]:
        fmt = lambda a: "-" if a is None else f"{a:.3f}"  # noqa: E731
        print(f"adaptation     {ad['rule']}  lr_w {ad['lr_w']:g}  "
              f"{ad['n_updates']} updates on {len(ad['lanes'])} lane(s)   "
              f"acc pre {fmt(ad['accuracy_pre'])} -> "
              f"post {fmt(ad['accuracy_post'])}")
        if args.adapt_export is not None:
            exp = Path(args.adapt_export)
            for row in ad["lanes"]:
                h = engine.harvest(row["lane"])
                d = exp / f"lane{row['lane']}"
                deploy_mod.save_adapt_delta(
                    d, h["base"], dw=h["dw"], dtheta=h["dtheta"],
                    base_name=h["base_name"], base_uid=h["base_uid"],
                    lane=h["lane"], n_updates=h["n_updates"],
                    rule=args.adapt_rule, meta={"dataset": dataset})
                print(f"[adapt] lane {row['lane']}: {h['n_updates']} "
                      f"updates on base {h['base_name']}#{h['base_uid']} "
                      f"-> {d}")
    print(f"artifact: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
