"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """Mesh over whatever devices exist (tests / examples / elastic restart)."""
    n = len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // m, m), ("data", "model"))
