import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Compressed cross-pod gradient-reduce dry-run: proves the int8+error-
feedback all-reduce (distributed/compression.py) lowers and compiles on the
2-pod 512-chip mesh, and reports the cross-pod byte cut vs fp32.

  PYTHONPATH=src python -m repro.launch.dryrun_compression
"""
import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.compression import compressed_allreduce
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import analyze_hlo


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--grad-mb", type=int, default=64,
                    help="per-device gradient MiB to reduce cross-pod")
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=True)      # (2, 16, 16)
    n = args.grad_mb * 2**20 // 4

    def reduce_compressed(g, ef):
        out, new_ef = compressed_allreduce(g, ef, "pod")
        return out, new_ef

    def reduce_fp32(g):
        return jax.lax.pmean(g, "pod")

    g_sds = jax.ShapeDtypeStruct(
        (2 * n,), jnp.float32,
        sharding=NamedSharding(mesh, P("pod")))      # per-pod shard = n

    t0 = time.perf_counter()
    with mesh:
        fc = jax.jit(shard_map(reduce_compressed, mesh=mesh,
                               in_specs=(P("pod"), P("pod")),
                               out_specs=(P("pod"), P("pod")),
                               check_rep=False))
        cc = fc.lower(g_sds, g_sds).compile()
        ff = jax.jit(shard_map(reduce_fp32, mesh=mesh,
                               in_specs=P("pod"), out_specs=P("pod")))
        cf = ff.lower(g_sds).compile()
    comp = analyze_hlo(cc.as_text(), pod_stride=256)
    base = analyze_hlo(cf.as_text(), pod_stride=256)
    rec = {
        "status": "ok", "mode": "compressed_crosspod_allreduce",
        "mesh": {"pod": 2, "data": 16, "model": 16},
        "compile_s": round(time.perf_counter() - t0, 2),
        "payload_bytes_fp32": float(base.collective_bytes),
        "payload_bytes_int8ef": float(comp.collective_bytes),
        "cut": float(base.collective_bytes /
                     max(comp.collective_bytes, 1.0)),
    }
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "compression__crosspod__512c.json").write_text(
        json.dumps(rec, indent=2))
    print(f"[dryrun-compression] ok fp32={rec['payload_bytes_fp32']:.3g}B "
          f"int8+ef={rec['payload_bytes_int8ef']:.3g}B "
          f"cut={rec['cut']:.2f}x compile={rec['compile_s']}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
