"""Serving driver: batched prefill → decode loop with a continuous-batching
slot manager.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --smoke --requests 8 --prompt-len 64 --gen 32

The slot lifecycle (admit / step / release / refill) is the shared,
model-agnostic ``repro.serve.slots.SlotManager`` — the same table the
event-stream server (repro.stream.engine) batches on; this module is the
LM-decode consumer: finished sequences free their slot for queued
requests (prefill refills the KV rows). On CPU/smoke it demonstrates the
full request lifecycle with the reduced config.
"""
from __future__ import annotations

import argparse
import sys
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.serve.slots import SlotManager
from repro.serve.steps import serve_config


@dataclass
class Request:
    rid: int
    prompt: jax.Array            # [S] i32
    max_new: int
    generated: list = field(default_factory=list)
    done: bool = False


class SlotServer:
    """Fixed-batch continuous decoding over a shared KV cache.

    Slot bookkeeping is the shared :class:`repro.serve.slots.SlotManager`;
    this class owns only the LM-specific lane state (KV rows, per-row
    positions, prefill/decode steps).
    """

    def __init__(self, cfg, mesh, batch: int, max_len: int):
        self.cfg = serve_config(cfg)
        self.mesh = mesh
        self.batch = batch
        self.max_len = max_len
        self.cache = lm.init_cache(self.cfg, batch, max_len)
        self.params = None
        self.slots: SlotManager[Request] = SlotManager(batch)
        self.pos = jnp.zeros((batch,), jnp.int32)

        def one_decode(params, token, pos, cache):
            return lm.decode_step(params, token, pos, cache, self.cfg)
        self._decode = jax.jit(one_decode, donate_argnums=(3,))

    def load(self, params):
        self.params = params

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot. Returns False when full."""
        slot = self.slots.admit(req)
        if slot is None:
            return False
        try:
            # prefill this prompt alone (batch-1), then scatter kv into
            # the slot
            logits, cache1 = lm.prefill(self.params, req.prompt[None, :],
                                        self.cfg, max_len=self.max_len)
        except Exception:
            # a failed prefill must not leak the lane
            self.slots.release(slot)
            raise
        def put(big, small):
            return big.at[:, slot:slot + 1].set(small)
        self.cache = jax.tree.map(put, self.cache, cache1)
        tok = jnp.argmax(logits[0]).astype(jnp.int32)
        req.generated.append(int(tok))
        self.pos = self.pos.at[slot].set(req.prompt.shape[0])
        return True

    def step(self) -> list[Request]:
        """One decode step for every occupied slot. Returns finished reqs."""
        tokens = jnp.array(
            [[r.generated[-1] if r is not None else 0]
             for r in (self.slots.get(i) for i in range(self.batch))],
            jnp.int32)
        # per-row positions: every slot decodes at its own sequence length
        # (continuous batching); rope, cache writes, and kv masking are all
        # row-local in decode_attention
        logits, self.cache = self._decode(self.params, tokens,
                                          self.pos, self.cache)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1)
        finished = []
        for i, r in list(self.slots.occupied()):
            r.generated.append(int(nxt[i]))
            self.pos = self.pos.at[i].add(1)
            if len(r.generated) >= r.max_new:
                r.done = True
                finished.append(r)
                self.slots.release(i)
        return finished


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen + 8

    with mesh:
        server = SlotServer(cfg, mesh, args.batch, max_len)
        scfg = server.cfg
        params = lm.init_params(jax.random.PRNGKey(0), scfg)
        server.load(params)

        key = jax.random.PRNGKey(1)
        queue = deque(
            Request(i, jax.random.randint(jax.random.fold_in(key, i),
                                          (args.prompt_len,), 0,
                                          scfg.vocab_size),
                    max_new=args.gen)
            for i in range(args.requests))
        done: list[Request] = []
        t0 = time.perf_counter()
        steps = 0
        while len(done) < args.requests:
            while queue and server.admit(queue[0]):
                queue.popleft()
            done.extend(server.step())
            steps += 1
            if steps > args.requests * args.gen + 64:
                raise RuntimeError("serve loop did not converge")
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.generated) for r in done)
        print(f"[serve] {len(done)} requests, {total_tokens} tokens, "
              f"{steps} decode steps, {dt:.2f}s "
              f"({total_tokens / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
