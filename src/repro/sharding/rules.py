"""Path-regex → PartitionSpec rules for every param/cache/input tree.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Batch always shards over all batch axes (pod+data); weights shard over
"model" (TP) and — in "2d" mode — additionally over "data" (FSDP-style),
which is mandatory for the >8B archs whose optimizer state cannot replicate
across the data axis.

Rules are ordered; first match wins. A rule maps to a *logical* spec whose
axis names are resolved against the mesh (absent axes dropped) and whose
dims are divisibility-checked against the actual leaf shape — a dim that
does not divide evenly falls back to replication (with the physical-padding
machinery in configs/base.py this should never fire for the production
archs; an assert hook surfaces violations in tests).
"""
from __future__ import annotations

import re
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, ShapeConfig
from repro.utils import tree_map_with_path

PyTree = Any

BATCH = "__batch__"      # placeholder resolved to ("pod","data") / ("data",)
FSDP = "__fsdp__"        # placeholder: "data" in 2d mode, None in tp mode


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
# (regex, spec-without-stack-axis). Stacked leaves (blocks/...) get leading
# None axes prepended automatically based on ndim difference.

_PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings
    (r"embed/embedding$",            ("model", FSDP)),
    (r"embed/unembed$",              (FSDP, "model")),
    # norms and small vectors — replicate
    (r"(ln\d?|lnx|final_norm|enc_norm|q_norm|k_norm)$", None),
    (r"(A_log|D_skip|dt_bias)$",     ("model",)),
    (r"ssm/norm$",                   ("model",)),
    # attention
    (r"(attn|xattn)/wq$",            (FSDP, "model")),
    (r"(attn|xattn)/wk$",            (FSDP, "model")),
    (r"(attn|xattn)/wv$",            (FSDP, "model")),
    (r"(attn|xattn)/wo$",            ("model", FSDP)),
    # dense mlp
    (r"mlp/wg$",                     (FSDP, "model")),
    (r"mlp/wu$",                     (FSDP, "model")),
    (r"mlp/wd$",                     ("model", FSDP)),
    # moe — EP over the expert axis, or TP inside experts (chosen per-config)
    (r"moe/router$",                 None),
    (r"moe/w[gu]$__EP",              ("model", None, FSDP)),
    (r"moe/wd$__EP",                 ("model", FSDP, None)),
    (r"moe/w[gu]$__TP",              (None, FSDP, "model")),
    (r"moe/wd$__TP",                 (None, "model", FSDP)),
    # ssm projections
    (r"ssm/wz$",                     (FSDP, "model")),
    (r"ssm/wx$",                     (FSDP, "model")),
    (r"ssm/wbc$",                    (FSDP, None)),
    (r"ssm/wdt$",                    (FSDP, "model")),
    (r"ssm/conv_wx$",                (None, "model")),
    (r"ssm/conv_bx$",                ("model",)),
    (r"ssm/conv_wbc$",               None),
    (r"ssm/conv_bbc$",               None),
    (r"ssm/out_proj$",               ("model", FSDP)),
]


def _moe_mode(cfg: LMConfig) -> str:
    tp = cfg.tp_multiple
    return "EP" if cfg.n_experts and cfg.n_experts % tp == 0 else "TP"


def _resolve(spec: tuple | None, mesh: Mesh, fsdp_on: bool,
             shape: tuple[int, ...]) -> P:
    if spec is None:
        return P()
    axes = []
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in enumerate(spec):
        if ax == FSDP:
            ax = "data" if fsdp_on else None
        if ax == BATCH:
            ax = batch_axes(mesh)
        if ax is None:
            axes.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        names = tuple(n for n in names if n in mesh.axis_names)
        if not names:
            axes.append(None)
            continue
        total = int(np.prod([mesh_shape[n] for n in names]))
        if shape[dim] % total != 0:
            axes.append(None)           # fallback: replicate this dim
            continue
        axes.append(names if len(names) > 1 else names[0])
    return P(*axes)


def param_pspecs(params: PyTree, cfg: LMConfig, mesh: Mesh) -> PyTree:
    """PartitionSpec tree matching the param tree."""
    fsdp_on = cfg.effective_weight_sharding() == "2d"
    moe_suffix = _moe_mode(cfg)

    def rule_for(path: str, leaf) -> P:
        for pat, spec in _PARAM_RULES:
            if "__" in pat:
                pat_base, mode = pat.split("__")
                if mode != moe_suffix:
                    continue
                pat = pat_base
            if re.search(pat, path):
                if spec is None:
                    return P()
                # prepend stack axes (scan-stacked params have extra leading dims)
                extra = leaf.ndim - len(spec)
                full = (None,) * extra + tuple(spec)
                return _resolve(full, mesh, fsdp_on, leaf.shape)
        return P()   # default: replicate

    return tree_map_with_path(rule_for, params)


def zero1_pspecs(param_specs: PyTree, params: PyTree, mesh: Mesh,
                 cfg: LMConfig) -> PyTree:
    """Optimizer-moment specs: param spec + shard one free dim over "data".

    ZeRO-1: moments never need replication across the data axis; we pick the
    first unsharded dim whose size divides the data-axis size. (In 2d mode
    params already consume "data"; specs pass through unchanged.)
    """
    if not cfg.zero1 or cfg.effective_weight_sharding() == "2d":
        return param_specs
    if "data" not in mesh.axis_names:
        return param_specs
    dsize = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]

    def shard_one(spec: P, leaf) -> P:
        axes = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, ax in enumerate(axes):
            if ax is None and leaf.shape[i] % dsize == 0 and leaf.shape[i] > 1:
                axes[i] = "data"
                return P(*axes)
        return spec

    return jax.tree.map(shard_one, param_specs, params,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def activation_pspec(mesh: Mesh, *trailing) -> P:
    return P(batch_axes(mesh), *trailing)


def shard_batch(x: jax.Array, *trailing) -> jax.Array:
    """Pin the leading (batch) axis of an activation to ("pod","data").

    GSPMD's sharding propagation gives up on while-loop carries surprisingly
    often — a scan-over-layers body whose carry resolves to `replicated`
    silently runs the FULL batch on every device (16-32x redundant compute
    and memory). Pinning h at each layer boundary keeps the whole loop body
    batch-sharded. No-op outside a mesh context or when the batch does not
    divide (long_500k's B=1).
    """
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        return x
    axes = batch_axes(mesh)
    if not axes:
        return x
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = int(np.prod([mesh_shape[a] for a in axes]))
    if n <= 1 or x.shape[0] % n != 0:
        return x
    spec = P(axes if len(axes) > 1 else axes[0],
             *trailing[:x.ndim - 1])
    return jax.lax.with_sharding_constraint(x, spec)


def input_pspecs(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh) -> dict[str, P]:
    """Specs for the data batch (tokens/labels/frames/img_embed)."""
    b = batch_axes(mesh)
    nb = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in b])) \
        if b else 1
    bspec = b if shape.global_batch % max(nb, 1) == 0 else ()
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.family == "vlm":
        out["img_embed"] = P(bspec, None, None)
    if cfg.is_encdec:
        out["frames"] = P(bspec, None, None)
    return out


def cache_pspecs(cache: PyTree, cfg: LMConfig, mesh: Mesh,
                 global_batch: int) -> PyTree:
    """KV/SSM cache specs. Batch shards over (pod, data) when divisible;
    otherwise (long_500k, B=1) the *sequence* axis of attention caches
    shards over "data" and SSM states replicate across data."""
    b = batch_axes(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    nb = int(np.prod([mesh_shape[a] for a in b])) if b else 1
    batch_ok = global_batch % max(nb, 1) == 0

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        if path in ("k", "v") or path.endswith("/k") or path.endswith("/v"):
            # [*stack, B, S, KV, hd]
            extra = leaf.ndim - 4
            bspec = b if batch_ok else None
            sspec = None
            if not batch_ok and shape[extra + 1] % mesh_shape.get("data", 1) == 0:
                sspec = "data"
            kvspec = "model" if shape[extra + 2] % mesh_shape.get("model", 1) == 0 \
                else None
            return P(*((None,) * extra), bspec, sspec, kvspec, None)
        if path.endswith("state"):       # [*stack, B, nh, hp, N]
            extra = leaf.ndim - 4
            bspec = b if batch_ok else None
            return P(*((None,) * extra), bspec, "model"
                     if shape[extra + 1] % mesh_shape.get("model", 1) == 0 else None,
                     None, None)
        if "conv_x" in path:             # [*stack, B, K-1, di]
            extra = leaf.ndim - 3
            bspec = b if batch_ok else None
            return P(*((None,) * extra), bspec, None, "model"
                     if shape[extra + 2] % mesh_shape.get("model", 1) == 0 else None)
        if "conv_bc" in path:
            extra = leaf.ndim - 3
            return P(*((None,) * extra), b if batch_ok else None, None, None)
        return P()

    return tree_map_with_path(spec_for, cache)


def named_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
