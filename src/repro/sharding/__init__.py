from repro.sharding.rules import (  # noqa: F401
    batch_axes, param_pspecs, zero1_pspecs, activation_pspec, cache_pspecs,
    input_pspecs, named_shardings,
)
