"""Binary event-file formats: AEDAT 3.1 and the N-MNIST ``.bin`` encoding.

Both parsers are CHUNKED generators — they yield bounded
:class:`EventChunk` batches in file order instead of materializing the
full (t, x, y, p) stream, so the slot-binner (repro.data.binning) can
fold arbitrarily long recordings into event frames with O(chunk) memory.
Both formats also have WRITERS so CI can synthesize fixture files and
assert bit-exact round trips with no network access (docs/datasets.md).

AEDAT 3.1 (DVS128-Gesture distribution format)
    ASCII header lines starting with ``#`` (first line ``#!AER-DAT3.1``),
    then a sequence of little-endian binary packets. Each packet: a
    28-byte header (eventType i16, eventSource i16, eventSize i32,
    eventTSOffset i32, eventTSOverflow i32, eventCapacity i32,
    eventNumber i32, eventValid i32) followed by ``eventNumber`` events
    of ``eventSize`` bytes. Polarity events (type 1) are 8 bytes: a u32
    data word (bit 0 valid, bit 1 polarity, bits 2–16 y, bits 17–31 x)
    and an i32 timestamp in µs; bit 31 of the full timestamp comes from
    the header's ``eventTSOverflow`` counter.

N-MNIST ``.bin`` (ATIS "Garrick Orchard" encoding)
    A flat stream of 5-byte big-endian records: byte 0 x, byte 1 y,
    byte 2 = polarity (bit 7) | timestamp bits 22–16, bytes 3–4 =
    timestamp bits 15–0, timestamp in µs.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from pathlib import Path
from typing import BinaryIO, Iterable, Iterator

import numpy as np

AEDAT31_MAGIC = b"#!AER-DAT3.1"
_PACKET_HEADER = struct.Struct("<hhiiiiii")
POLARITY_EVENT = 1          # AEDAT 3.1 eventType for DVS polarity events
_POLARITY_EVENT_SIZE = 8    # u32 data word + i32 timestamp

NMNIST_EVENT_BYTES = 5
NMNIST_SENSOR_HW = (34, 34)
DVS128_SENSOR_HW = (128, 128)


@dataclass(frozen=True)
class EventChunk:
    """One bounded batch of decoded events, in stream order.

    ``t`` µs int64, ``x``/``y`` int32 sensor coordinates, ``p`` int8
    polarity (1 = ON / brightness increase, 0 = OFF).
    """
    t: np.ndarray
    x: np.ndarray
    y: np.ndarray
    p: np.ndarray

    def __len__(self) -> int:
        return len(self.t)


def concat_chunks(chunks: Iterable[EventChunk]) -> EventChunk:
    """Materialize a chunk stream (tests / small files only)."""
    cs = list(chunks)
    if not cs:
        z = np.zeros(0)
        return EventChunk(z.astype(np.int64), z.astype(np.int32),
                          z.astype(np.int32), z.astype(np.int8))
    return EventChunk(*(np.concatenate([getattr(c, f) for c in cs])
                        for f in ("t", "x", "y", "p")))


# ---------------------------------------------------------------------------
# AEDAT 3.1
# ---------------------------------------------------------------------------

def _read_aedat31_header(f: BinaryIO) -> None:
    """Consume the ASCII ``#``-comment header, leaving ``f`` at the first
    binary packet."""
    first = f.readline()
    if not first.startswith(AEDAT31_MAGIC):
        raise ValueError(
            f"not an AEDAT 3.1 file (header {first[:16]!r}, expected "
            f"{AEDAT31_MAGIC!r}); AEDAT 2.0 is not supported")
    while True:
        pos = f.tell()
        line = f.readline()
        if not line.startswith(b"#"):
            f.seek(pos)
            return


def read_aedat31(path: str | Path, *, t_stop_us: int | None = None
                 ) -> Iterator[EventChunk]:
    """Yield one :class:`EventChunk` per polarity-event packet.

    Invalid events (data-word bit 0 clear) are dropped; non-polarity
    packets (IMU, frames, special events) are skipped. ``t_stop_us``
    stops reading once a packet's first timestamp passes it — packets
    are time-ordered, so a time-windowed caller (e.g. one DVS128-Gesture
    trial) never decodes the tail of a long recording.
    """
    with open(path, "rb") as f:
        _read_aedat31_header(f)
        while True:
            hdr = f.read(_PACKET_HEADER.size)
            if len(hdr) < _PACKET_HEADER.size:
                return
            (etype, _src, esize, _tsoff, overflow, _cap, num,
             _valid) = _PACKET_HEADER.unpack(hdr)
            body = f.read(esize * num)
            if len(body) < esize * num:
                return          # truncated trailing packet
            if etype != POLARITY_EVENT or esize != _POLARITY_EVENT_SIZE:
                continue
            raw = np.frombuffer(body, dtype="<u4").reshape(num, 2)
            data, ts = raw[:, 0], raw[:, 1].astype(np.int64)
            ts = ts + (np.int64(overflow) << 31)
            ok = (data & 1).astype(bool)
            chunk = EventChunk(
                t=ts[ok],
                x=((data[ok] >> 17) & 0x7FFF).astype(np.int32),
                y=((data[ok] >> 2) & 0x7FFF).astype(np.int32),
                p=((data[ok] >> 1) & 1).astype(np.int8))
            if len(chunk):
                if t_stop_us is not None and int(chunk.t[0]) >= t_stop_us:
                    return
                yield chunk


def write_aedat31(path: str | Path, events: EventChunk, *,
                  events_per_packet: int = 4096,
                  comment: str = "synthetic fixture") -> None:
    """Write polarity events as a valid AEDAT 3.1 file (inverse of
    :func:`read_aedat31` — round-trips bit-exactly for in-range values:
    x/y < 2^15, 0 <= t < 2^31)."""
    t = np.asarray(events.t, dtype=np.int64)
    x = np.asarray(events.x, dtype=np.int64)
    y = np.asarray(events.y, dtype=np.int64)
    p = np.asarray(events.p, dtype=np.int64)
    if len(t) and (x.max() >= 1 << 15 or y.max() >= 1 << 15
                   or t.min() < 0 or t.max() >= 1 << 31):
        raise ValueError("event fields out of AEDAT 3.1 range")
    with open(path, "wb") as f:
        f.write(AEDAT31_MAGIC + b"\r\n")
        f.write(b"# " + comment.encode() + b"\r\n")
        for lo in range(0, max(len(t), 1), events_per_packet):
            n = min(events_per_packet, len(t) - lo)
            if n <= 0:
                break
            f.write(_PACKET_HEADER.pack(POLARITY_EVENT, 0,
                                        _POLARITY_EVENT_SIZE, 4, 0, n, n, n))
            sl = slice(lo, lo + n)
            data = (1 | (p[sl] << 1) | (y[sl] << 2) | (x[sl] << 17))
            raw = np.empty((n, 2), dtype="<u4")
            raw[:, 0] = data
            raw[:, 1] = t[sl]
            f.write(raw.tobytes())


# ---------------------------------------------------------------------------
# N-MNIST .bin
# ---------------------------------------------------------------------------

def read_nmnist_bin(path: str | Path, *, chunk_events: int = 65536
                    ) -> Iterator[EventChunk]:
    """Yield chunks from an N-MNIST ``.bin`` (ATIS 40-bit) event file."""
    with open(path, "rb") as f:
        while True:
            buf = f.read(NMNIST_EVENT_BYTES * chunk_events)
            if not buf:
                return
            n = len(buf) // NMNIST_EVENT_BYTES
            raw = np.frombuffer(buf[:n * NMNIST_EVENT_BYTES],
                                dtype=np.uint8).reshape(n, 5).astype(np.int64)
            t = ((raw[:, 2] & 0x7F) << 16) | (raw[:, 3] << 8) | raw[:, 4]
            yield EventChunk(t=t,
                             x=raw[:, 0].astype(np.int32),
                             y=raw[:, 1].astype(np.int32),
                             p=(raw[:, 2] >> 7).astype(np.int8))


def write_nmnist_bin(path: str | Path, events: EventChunk) -> None:
    """Inverse of :func:`read_nmnist_bin` (bit-exact for x/y < 2^8,
    0 <= t < 2^23)."""
    t = np.asarray(events.t, dtype=np.int64)
    x = np.asarray(events.x, dtype=np.int64)
    y = np.asarray(events.y, dtype=np.int64)
    p = np.asarray(events.p, dtype=np.int64)
    if len(t) and (x.max() >= 1 << 8 or y.max() >= 1 << 8
                   or t.min() < 0 or t.max() >= 1 << 23):
        raise ValueError("event fields out of N-MNIST .bin range")
    raw = np.empty((len(t), 5), dtype=np.uint8)
    raw[:, 0] = x
    raw[:, 1] = y
    raw[:, 2] = (p << 7) | ((t >> 16) & 0x7F)
    raw[:, 3] = (t >> 8) & 0xFF
    raw[:, 4] = t & 0xFF
    Path(path).write_bytes(raw.tobytes())
