"""Streaming slot-binning: raw (t, x, y, p) event records → the engine's
per-slot event-frame format.

The sweep engine consumes ``[B, n_slots, n_sub, H, W, 2]`` float32 count
frames (ON/OFF on the last axis) at an arbitrary integration time T_INTG.
:func:`bin_chunks` folds a chunked event stream (repro.data.formats) into
a single recording's ``[n_total, H, W, 2]`` fine-slot histogram — one
``np.add.at`` scatter per chunk, never materializing the full event list
— with integer spatial downscaling from the sensor resolution to the
model resolution. Cache layout and keying live in repro.data.cache.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

from repro.data.formats import EventChunk


def slot_us_for(t_intg_ms: float, n_sub: int) -> int:
    """Fine-slot width in µs for an integration time split into ``n_sub``
    sub-slots. Must be integral µs so file timestamps bin exactly."""
    us = t_intg_ms * 1000.0 / n_sub
    if abs(us - round(us)) > 1e-6 or round(us) <= 0:
        raise ValueError(
            f"t_intg_ms={t_intg_ms} / n_sub={n_sub} is not a whole number "
            f"of microseconds — file-backed binning needs integral slots")
    return int(round(us))


def bin_chunks(chunks: Iterable[EventChunk], *, n_total: int, slot_us: int,
               sensor_hw: tuple[int, int], out_hw: tuple[int, int],
               t0_us: int = 0, t_stop_us: int | None = None) -> np.ndarray:
    """Accumulate an event-chunk stream into ``[n_total, H, W, 2]``
    float32 counts (channel 0 = ON, channel 1 = OFF, matching the
    synthetic generator). Events before ``t0_us``, past the last slot, or
    at/after ``t_stop_us`` (a labeled window's end — events beyond it
    belong to the NEXT sample, not this one) are dropped; coordinates are
    downscaled ``sensor → out`` by integer scaling (x * W_out // W_sensor).
    """
    sh, sw = sensor_hw
    oh, ow = out_hw
    frames = np.zeros((n_total, oh, ow, 2), dtype=np.float32)
    for c in chunks:
        if not len(c):
            continue
        slot = (c.t - t0_us) // slot_us
        ok = (slot >= 0) & (slot < n_total)
        if t_stop_us is not None:
            ok &= c.t < t_stop_us
        if not ok.any():
            continue
        slot = slot[ok].astype(np.int64)
        y = (c.y[ok].astype(np.int64) * oh) // sh
        x = (c.x[ok].astype(np.int64) * ow) // sw
        ok2 = (y >= 0) & (y < oh) & (x >= 0) & (x < ow)
        slot, y, x = slot[ok2], y[ok2], x[ok2]
        pol = 1 - c.p[ok][ok2].astype(np.int64)   # p=1 (ON) → channel 0
        np.add.at(frames, (slot, y, x, pol), 1.0)
    return frames


def frames_to_events(frames: np.ndarray, slot_us: int, *,
                     rng: np.random.Generator | None = None) -> EventChunk:
    """Expand a ``[n_total, H, W, 2]`` count histogram into discrete
    (t, x, y, p) records — the inverse direction of :func:`bin_chunks`,
    used by the fixture writers (repro.data.fixtures) to synthesize
    AEDAT / ``.bin`` files from the analytic generator's frames.

    Each count of ``c`` at (slot, y, x, pol) becomes ``c`` events with
    timestamps spread inside the slot (evenly, or uniformly when ``rng``
    is given), so re-binning at the same slot width recovers ``frames``
    exactly.
    """
    n_total = frames.shape[0]
    counts = np.rint(np.asarray(frames)).astype(np.int64)
    slot, y, x, pol = np.nonzero(counts)
    reps = counts[slot, y, x, pol]
    slot = np.repeat(slot, reps)
    y = np.repeat(y, reps)
    x = np.repeat(x, reps)
    pol = np.repeat(pol, reps)
    n = len(slot)
    if rng is None:
        # even spread: the k-th duplicate of a (slot, y, x, pol) cell with
        # count c offsets by k * slot_us // c — deterministic, and
        # re-binning at slot_us recovers the histogram exactly
        rank = np.zeros(n, dtype=np.int64)
        if n:
            # np.repeat keeps cell order, so within-cell rank is the
            # position minus the cell's start offset in the flat stream
            starts = np.repeat(np.cumsum(reps) - reps, reps)
            rank = np.arange(n) - starts
        cell_count = np.repeat(reps, reps)
        off = np.minimum(rank * slot_us // np.maximum(cell_count, 1),
                         slot_us - 1)
    else:
        off = rng.integers(0, slot_us, size=n)
    t = slot * slot_us + off
    order = np.argsort(t, kind="stable")
    return EventChunk(t=t[order].astype(np.int64),
                      x=x[order].astype(np.int32),
                      y=y[order].astype(np.int32),
                      p=(1 - pol[order]).astype(np.int8))
