"""Deterministic synthetic LM token pipeline with restart skip-ahead.

Real corpora are unavailable offline; training the LM-family archs uses a
synthetic but *learnable* stream: a tiny order-k Markov source over the
vocab, seeded per (stream seed, step) — so

  * batches are **deterministic in the step index**: restarting from a
    checkpoint at step N regenerates exactly the batches N+1, N+2, ... that
    the crashed run would have seen (the "data cursor" is just the step);
  * the distribution has real structure (bigram statistics), so loss curves
    actually descend and overfitting/underfitting is visible in examples;
  * per-host sharding slices the global batch by process index, matching
    the input_pspecs batch sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_temp: float = 0.6     # lower = more predictable stream
    n_states: int = 16           # latent states of the source (fewer = more
                                 # visible bigram structure to learn)


def _transition_logits(cfg: TokenStreamConfig) -> jax.Array:
    """Fixed [n_states, vocab] emission + [n_states, n_states] transition."""
    k = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(k)
    emit = jax.random.normal(k1, (cfg.n_states, cfg.vocab_size)) / cfg.markov_temp
    trans = jax.random.normal(k2, (cfg.n_states, cfg.n_states)) / cfg.markov_temp
    return emit, trans


@partial(jax.jit, static_argnames=("cfg",))
def sample_batch(cfg: TokenStreamConfig, step: jax.Array) -> dict:
    """Global batch for ``step``: {'tokens': [B, S] i32, 'labels': [B, S]}.

    labels[i, t] = tokens[i, t+1] (next-token prediction); the final label
    wraps to the first token (cheap; masked losses are unnecessary for the
    synthetic stream).
    """
    emit, trans = _transition_logits(cfg)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0x5EED), step)
    B, S = cfg.global_batch, cfg.seq_len
    ks, ke = jax.random.split(key)
    s0 = jax.random.randint(ks, (B,), 0, cfg.n_states)

    def step_fn(state, k):
        knext, kemit = jax.random.split(k)
        tok = jax.random.categorical(kemit, emit[state])
        state = jax.random.categorical(knext, trans[state])
        return state, tok

    keys = jax.random.split(ke, S)
    _, toks = jax.lax.scan(lambda st, k: step_fn(st, k), s0, keys)
    tokens = jnp.moveaxis(toks, 0, 1).astype(jnp.int32)      # [B, S]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels}


def host_slice(batch: dict, process_index: int, process_count: int) -> dict:
    """Slice the global batch to this host's shard (batch-axis sharding)."""
    if process_count == 1:
        return batch
    def sl(x):
        per = x.shape[0] // process_count
        return x[process_index * per:(process_index + 1) * per]
    return jax.tree.map(sl, batch)


class TokenLoader:
    """Stateful cursor wrapper: ``next()`` yields (step, batch); ``seek(n)``
    implements restart skip-ahead in O(1) (generation is step-keyed)."""

    def __init__(self, cfg: TokenStreamConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step

    def seek(self, step: int) -> None:
        self._step = step

    @property
    def step(self) -> int:
        return self._step

    def __iter__(self):
        return self

    def __next__(self) -> tuple[int, dict]:
        s = self._step
        batch = sample_batch(self.cfg, jnp.asarray(s))
        self._step += 1
        return s, batch
