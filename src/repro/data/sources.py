"""Event sources: one contract over synthetic and file-backed DVS streams.

The sweep engine touches event data through exactly one seam:

    events, labels = source.sample_batch(key, batch_size, t_intg_ms, n_sub)

with ``events`` float32 ``[B, n_slots, n_sub, H, W, 2]`` (ON/OFF counts on
the last axis) and ``n_slots = round(duration_ms / t_intg_ms)``. This
module defines that contract (:class:`EventSource`), the adapter that
keeps the analytic generator working unchanged (:class:`SyntheticSource`
over ``repro.data.events``), and the file-backed sources for the paper's
real workloads: DVS128-Gesture (AEDAT 3.1 trials sliced by the
``*_labels.csv`` gesture windows) and N-MNIST (per-digit ``.bin`` files).

File-backed sources stream each recording through the chunked parsers
(repro.data.formats), fold it into fine-slot frames with the streaming
binner (repro.data.binning) at the requested T_INTG, and memoize the
result in the on-disk frame cache (repro.data.cache) keyed by
(dataset, slot width, resolution). Train/val membership is a
deterministic hash of each sample's identity — stable across runs,
machines, and directory enumeration order. See docs/datasets.md.
"""
from __future__ import annotations

import csv
import hashlib
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import events as events_mod
from repro.data.binning import bin_chunks, frames_to_events, slot_us_for
from repro.data.cache import CACHE_DIRNAME, FrameCache
from repro.data.formats import (
    DVS128_SENSOR_HW, EventChunk, NMNIST_SENSOR_HW, concat_chunks,
    read_aedat31, read_nmnist_bin,
)

DATASETS = ("synthetic-gesture", "synthetic-nmnist", "dvs128", "nmnist")
SPLITS = ("train", "val", "all")
VAL_PERCENT = 20                     # deterministic hash-split fraction

# default stream duration per dataset (resolve_dataset(duration_ms=None)):
# DVS128-Gesture trials run ~6 s (we crop a 2 s window, matching the
# synthetic generator); real N-MNIST recordings are 3 saccades ≈ 300 µs·1e3
# — spanning 2 s would make ~85% of the slots empty padding.
DATASET_DURATIONS_MS = {"synthetic-gesture": 2000.0,
                        "synthetic-nmnist": 2000.0,
                        "dvs128": 2000.0,
                        "nmnist": 300.0}


class EventSource:
    """The engine-facing event-stream contract (see module docstring).

    Concrete sources expose ``name``, ``height``, ``width``,
    ``n_classes``, ``duration_ms`` and ``sensor_hw`` plus the two batch
    samplers and the replay entry point
    (:meth:`iter_event_chunks` — one labeled sample as a timestamped
    live stream, the seam the online serving engine in ``repro.stream``
    consumes). Everything downstream of the seam (sweep engine, codesign
    harness, streaming engine, examples, benchmarks) is source-agnostic.
    """
    name: str
    height: int
    width: int
    n_classes: int
    duration_ms: float
    # native coordinate grid of replayed (t, x, y, p) chunks: the file
    # sensor resolution for file-backed sources, the generator grid for
    # synthetic ones. Consumers bin replayed chunks FROM this grid down
    # to (height, width) — the same downscale the offline binner applies.
    sensor_hw: tuple[int, int]

    def n_slots(self, t_intg_ms: float) -> int:
        n = self.duration_ms / t_intg_ms
        if abs(n - round(n)) > 1e-6:
            raise ValueError(f"T_INTG {t_intg_ms} ms does not divide the "
                             f"stream duration {self.duration_ms} ms")
        return int(round(n))

    def sample_batch(self, key: jax.Array, batch_size: int,
                     t_intg_ms: float, n_sub: int = 1
                     ) -> tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def sample_batch_with_labels(self, key: jax.Array, labels: jax.Array,
                                 t_intg_ms: float, n_sub: int = 1
                                 ) -> tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def iter_event_chunks(self, key: jax.Array, *, chunk_us: int,
                          slot_us: int | None = None
                          ) -> tuple[int, Iterator[EventChunk]]:
        """Replay one labeled sample as a timestamped live stream.

        Returns ``(label, chunks)`` where chunk ``i`` carries the raw
        ``(t, x, y, p)`` records of the window
        ``[i·chunk_us, (i+1)·chunk_us)`` in µs relative to stream start,
        at the source's ``sensor_hw`` resolution. EMPTY chunks are
        yielded too, so a replay consumer's clock advances through event
        gaps (the capacitor keeps leaking while nothing arrives). The
        stream spans exactly ``duration_ms``, i.e.
        ``duration_ms·1000 / chunk_us`` chunks. ``slot_us`` is the fine
        time grid synthetic sources generate events on (ignored by
        file-backed sources, whose recordings carry real timestamps).
        """
        raise NotImplementedError


def _replay_chunk_count(duration_ms: float, chunk_us: int) -> int:
    n = duration_ms * 1000.0 / chunk_us
    if abs(n - round(n)) > 1e-6 or round(n) < 1:
        raise ValueError(f"chunk_us={chunk_us} does not divide the stream "
                         f"duration {duration_ms} ms")
    return int(round(n))


def rechunk_events(ev: EventChunk, chunk_us: int, n_chunks: int
                   ) -> Iterator[EventChunk]:
    """Slice one event record (timestamps relative to stream start) into
    ``n_chunks`` fixed-width timestamped chunks — the replay shape behind
    :meth:`EventSource.iter_event_chunks`. Events at/after the stream end
    are dropped; gaps yield empty chunks."""
    order = np.argsort(ev.t, kind="stable")
    t, x, y, p = ev.t[order], ev.x[order], ev.y[order], ev.p[order]
    bounds = np.searchsorted(t, np.arange(n_chunks + 1, dtype=np.int64)
                             * chunk_us)
    for i in range(n_chunks):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        yield EventChunk(t=t[lo:hi], x=x[lo:hi], y=y[lo:hi], p=p[lo:hi])


class SyntheticSource(EventSource):
    """Adapter: the analytic generator (repro.data.events) behind the
    :class:`EventSource` contract — the offline fallback for every
    file-backed dataset."""

    def __init__(self, cfg: events_mod.EventStreamConfig):
        self.cfg = cfg
        self.name = cfg.name
        self.height, self.width = cfg.height, cfg.width
        self.sensor_hw = (cfg.height, cfg.width)
        self.n_classes = cfg.n_classes
        self.duration_ms = cfg.duration_ms

    def sample_batch(self, key, batch_size, t_intg_ms, n_sub=1):
        return events_mod.sample_batch(key, self.cfg, batch_size,
                                       t_intg_ms, n_sub=n_sub)

    def sample_batch_with_labels(self, key, labels, t_intg_ms, n_sub=1):
        return events_mod.sample_batch_with_labels(key, self.cfg, labels,
                                                   t_intg_ms, n_sub=n_sub)

    def iter_event_chunks(self, key, *, chunk_us, slot_us=None,
                          label: int | None = None):
        """Replay one synthetic sample: frames on the ``slot_us`` fine
        grid (default: one slot per chunk) expanded into discrete events
        (``binning.frames_to_events`` — deterministic within-slot spread,
        so re-binning at ``slot_us`` recovers the frames exactly), then
        sliced into ``chunk_us`` replay chunks."""
        slot_us = chunk_us if slot_us is None else slot_us
        if chunk_us % slot_us:
            raise ValueError(f"chunk_us={chunk_us} must be a multiple of "
                             f"the generation grid slot_us={slot_us}")
        n_chunks = _replay_chunk_count(self.duration_ms, chunk_us)
        n_total = n_chunks * (chunk_us // slot_us)
        kl, ke = jax.random.split(key)
        if label is None:
            label = int(jax.random.randint(kl, (), 0, self.n_classes))

        def lazy(lab=label):
            # events materialize on first next(): a queued-but-not-yet-
            # admitted stream costs nothing (see StreamEngine.serve)
            frames = events_mod.sample_events(ke, self.cfg,
                                              jnp.asarray([lab]), n_total, 1)
            ev = frames_to_events(np.asarray(frames[0, :, 0]), slot_us)
            yield from rechunk_events(ev, chunk_us, n_chunks)

        return label, lazy()


def as_source(data) -> EventSource:
    """Normalize the engine's ``data_cfg`` argument: an
    :class:`EventSource` passes through, a bare
    :class:`~repro.data.events.EventStreamConfig` (every pre-dataset
    caller) is wrapped in :class:`SyntheticSource`."""
    if isinstance(data, EventSource):
        return data
    if isinstance(data, events_mod.EventStreamConfig):
        return SyntheticSource(data)
    raise TypeError(f"expected EventSource or EventStreamConfig, "
                    f"got {type(data).__name__}")


# ---------------------------------------------------------------------------
# file-backed sources
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FileSample:
    """One labeled recording window: ``chunks()`` streams its events
    (already time-limited where the format allows), ``t0_us`` is the
    window start subtracted at binning time. ``split_id`` is the identity
    the train/val hash runs on — defaults to ``sample_id``; recordings
    holding many windows set it to the recording path so every window
    lands in the same split (no leakage across splits)."""
    sample_id: str
    label: int
    chunks: Callable[[], Iterator[EventChunk]] = field(compare=False)
    t0_us: int = 0
    # labeled window end (absolute µs): events at/after it belong to the
    # next sample and are clipped out even when the source duration spans
    # further. None → unbounded (whole-recording samples like N-MNIST).
    t1_us: int | None = None
    split_id: str | None = None


def split_of(sample_id: str, val_percent: int = VAL_PERCENT) -> str:
    """Deterministic train/val membership: a stable hash of the sample's
    identity (NOT of enumeration order or absolute paths), so the split
    is reproducible across runs and machines."""
    h = int.from_bytes(hashlib.sha1(sample_id.encode()).digest()[:4], "big")
    return "val" if h % 100 < val_percent else "train"


class FileEventSource(EventSource):
    """Shared machinery of the file-backed sources: deterministic split
    filtering, per-sample cached binning, and the two samplers."""

    def __init__(self, name: str, samples: list[FileSample], *,
                 sensor_hw: tuple[int, int], hw: int, n_classes: int,
                 duration_ms: float, split: str = "train",
                 cache: FrameCache | None = None):
        if split not in SPLITS:
            raise ValueError(f"split {split!r} not in {SPLITS}")
        if not samples:
            raise ValueError(f"dataset {name!r}: no samples found")
        self.name = name
        self.sensor_hw = sensor_hw
        self.height = self.width = hw
        self.n_classes = n_classes
        self.duration_ms = duration_ms
        self.split = split
        self.cache = cache
        self.samples = sorted(
            (s for s in samples
             if split == "all"
             or split_of(s.split_id or s.sample_id) == split),
            key=lambda s: s.sample_id)
        if not self.samples:
            raise ValueError(f"dataset {name!r}: split {split!r} is empty "
                             f"({len(samples)} samples total)")
        self._by_class: dict[int, list[int]] = {}
        for i, s in enumerate(self.samples):
            self._by_class.setdefault(s.label, []).append(i)

    def _sample_frames(self, i: int, slot_us: int, n_total: int
                       ) -> np.ndarray:
        s = self.samples[i]
        build = lambda: bin_chunks(          # noqa: E731
            s.chunks(), n_total=n_total, slot_us=slot_us,
            sensor_hw=self.sensor_hw, out_hw=(self.height, self.width),
            t0_us=s.t0_us, t_stop_us=s.t1_us)
        if self.cache is None:
            return build()
        return self.cache.get_or_build(
            s.sample_id, build, slot_us=slot_us,
            out_hw=(self.height, self.width), n_total=n_total)

    def _gather(self, idx: np.ndarray, t_intg_ms: float, n_sub: int
                ) -> tuple[jax.Array, jax.Array]:
        n_slots = self.n_slots(t_intg_ms)
        slot_us = slot_us_for(t_intg_ms, n_sub)
        n_total = n_slots * n_sub
        frames = np.stack([self._sample_frames(int(i), slot_us, n_total)
                           for i in idx])
        ev = frames.reshape((len(idx), n_slots, n_sub,
                             self.height, self.width, 2))
        labels = np.asarray([self.samples[int(i)].label for i in idx],
                            dtype=np.int32)
        return jnp.asarray(ev), jnp.asarray(labels)

    def sample_batch(self, key, batch_size, t_intg_ms, n_sub=1):
        idx = np.asarray(jax.random.randint(key, (batch_size,), 0,
                                            len(self.samples)))
        return self._gather(idx, t_intg_ms, n_sub)

    def sample_batch_with_labels(self, key, labels, t_intg_ms, n_sub=1):
        labels = np.asarray(labels)
        keys = jax.random.split(key, len(labels))
        idx = []
        for lab, k in zip(labels, keys):
            pool = self._by_class.get(int(lab))
            if not pool:
                raise ValueError(f"dataset {self.name!r}: no {self.split} "
                                 f"samples for class {int(lab)}")
            j = int(jax.random.randint(k, (), 0, len(pool)))
            idx.append(pool[j])
        ev, _ = self._gather(np.asarray(idx), t_intg_ms, n_sub)
        return ev, jnp.asarray(labels.astype(np.int32))

    def iter_event_chunks(self, key, *, chunk_us, slot_us=None,
                          index: int | None = None):
        """Replay one recording window as a live stream: its events
        (window-clipped, timestamps shifted to stream-relative µs) sliced
        into ``chunk_us`` chunks. ``index`` pins the sample (tests /
        deterministic replay); default draws it from ``key``. ``slot_us``
        is ignored — file recordings carry real timestamps."""
        del slot_us
        n_chunks = _replay_chunk_count(self.duration_ms, chunk_us)
        if index is None:
            index = int(jax.random.randint(key, (), 0, len(self.samples)))
        s = self.samples[index]

        def lazy(i=index):
            # file I/O + the record's arrays materialize on first next(),
            # so a queued-but-not-yet-admitted stream holds no event data
            yield from rechunk_events(self.sample_events(i), chunk_us,
                                      n_chunks)

        return s.label, lazy()

    def sample_events(self, index: int) -> EventChunk:
        """One sample's full event record, window-clipped and shifted to
        stream-relative timestamps (the record :func:`rechunk_events`
        replays and the offline binner consumes)."""
        s = self.samples[index]
        ev = concat_chunks(s.chunks())
        keep = ev.t >= s.t0_us
        if s.t1_us is not None:
            keep &= ev.t < s.t1_us
        return EventChunk(t=ev.t[keep] - s.t0_us, x=ev.x[keep],
                          y=ev.y[keep], p=ev.p[keep])


def _make_cache(root: Path, dataset: str,
                cache_root: str | Path | None) -> FrameCache:
    return FrameCache(cache_root if cache_root is not None
                      else root / CACHE_DIRNAME, dataset)


class DVSGestureSource(FileEventSource):
    """DVS128-Gesture: AEDAT 3.1 recordings plus companion
    ``<name>_labels.csv`` files (``class,startTime_usec,endTime_usec``
    rows, classes 1-indexed); each labeled window is one sample, cropped
    to the source ``duration_ms``. If the IBM distribution's
    ``trials_to_train.txt`` / ``trials_to_test.txt`` are present they
    define the split; otherwise a per-recording hash does (all windows
    of one recording land in the same split — no subject leakage)."""

    N_CLASSES = 11

    def __init__(self, root: str | Path, *, hw: int = 16,
                 duration_ms: float = 2000.0, split: str = "train",
                 cache_root: str | Path | None = None):
        root = Path(root)
        listed = self._listed_trials(root)
        samples = []
        for aedat in sorted(root.rglob("*.aedat")):
            csv_path = aedat.with_name(aedat.stem + "_labels.csv")
            if not csv_path.exists():
                continue
            rel = aedat.relative_to(root).as_posix()
            for k, (cls, t0, t1) in enumerate(self._read_labels(csv_path)):
                samples.append(FileSample(
                    sample_id=f"{rel}#{k}", label=cls - 1,
                    chunks=(lambda p=aedat, stop=t1:
                            read_aedat31(p, t_stop_us=stop)),
                    t0_us=t0, t1_us=t1, split_id=rel))
        if listed is not None:
            want = listed["train" if split != "val" else "test"]
            if split != "all":
                samples = [s for s in samples
                           if s.sample_id.split("#")[0].split("/")[-1]
                           in want]
            split_eff = "all"
        else:
            split_eff = split
        super().__init__("dvs128", samples, sensor_hw=DVS128_SENSOR_HW,
                         hw=hw, n_classes=self.N_CLASSES,
                         duration_ms=duration_ms, split=split_eff,
                         cache=_make_cache(root, "dvs128", cache_root))


    @staticmethod
    def _listed_trials(root: Path) -> dict[str, set[str]] | None:
        tr, te = root / "trials_to_train.txt", root / "trials_to_test.txt"
        if not (tr.exists() and te.exists()):
            return None
        return {"train": {ln.strip() for ln in tr.read_text().splitlines()
                          if ln.strip()},
                "test": {ln.strip() for ln in te.read_text().splitlines()
                         if ln.strip()}}

    @staticmethod
    def _read_labels(path: Path) -> list[tuple[int, int, int]]:
        rows = []
        with open(path, newline="") as f:
            for row in csv.reader(f):
                if not row or not row[0].strip().isdigit():
                    continue    # header / blank lines
                rows.append((int(row[0]), int(row[1]), int(row[2])))
        return rows


class NMNISTSource(FileEventSource):
    """N-MNIST: ``<root>/(Train|Test)/<digit>/*.bin`` (the released
    layout) or a flat ``<root>/<digit>/*.bin``. With Train/Test present,
    ``split="train"``/``"val"`` map onto them; otherwise the
    deterministic hash split applies per file."""

    N_CLASSES = 10

    def __init__(self, root: str | Path, *, hw: int = 16,
                 duration_ms: float = 2000.0, split: str = "train",
                 cache_root: str | Path | None = None):
        root = Path(root)
        has_dirs = (root / "Train").is_dir()
        if has_dirs:
            bases = ([root / "Train", root / "Test"] if split == "all"
                     else [root / ("Train" if split == "train" else "Test")])
            split_eff = "all"
        else:
            bases = [root]
            split_eff = split
        samples = []
        for base in bases:
            for b in sorted(base.rglob("*.bin")):
                try:
                    label = int(b.parent.name)
                except ValueError:
                    continue
                if not 0 <= label < self.N_CLASSES:
                    continue
                samples.append(FileSample(
                    sample_id=b.relative_to(root).as_posix(), label=label,
                    chunks=lambda p=b: read_nmnist_bin(p)))
        super().__init__("nmnist", samples, sensor_hw=NMNIST_SENSOR_HW,
                         hw=hw, n_classes=self.N_CLASSES,
                         duration_ms=duration_ms, split=split_eff,
                         cache=_make_cache(root, "nmnist", cache_root))



# ---------------------------------------------------------------------------
# dataset registry
# ---------------------------------------------------------------------------

def resolve_dataset(name: str, *, hw: int = 16, data_root: str | None = None,
                    duration_ms: float | None = None, split: str = "train",
                    cache_root: str | Path | None = None) -> EventSource:
    """CLI/`SweepConfig` dataset name → an :class:`EventSource`.

    ``synthetic-*`` names need no files (the analytic generator);
    ``dvs128`` / ``nmnist`` need ``data_root`` pointing at the dataset
    directory (see docs/datasets.md for the expected layouts).
    ``duration_ms=None`` picks the dataset's natural default
    (:data:`DATASET_DURATIONS_MS` — note real N-MNIST recordings only
    span ~300 ms).
    """
    if duration_ms is None:
        if name not in DATASET_DURATIONS_MS:
            raise ValueError(f"unknown dataset {name!r} (expected one of "
                             f"{DATASETS})")
        duration_ms = DATASET_DURATIONS_MS[name]
    if name == "synthetic-gesture":
        return SyntheticSource(replace(events_mod.dvs_gesture_like(hw),
                                       duration_ms=duration_ms))
    if name == "synthetic-nmnist":
        return SyntheticSource(replace(events_mod.nmnist_like(hw),
                                       duration_ms=duration_ms))
    if name in ("dvs128", "nmnist"):
        if data_root is None:
            raise ValueError(f"dataset {name!r} is file-backed: pass "
                             f"--data-root (or data_root=) pointing at it, "
                             f"or use its synthetic-* fallback")
        cls = DVSGestureSource if name == "dvs128" else NMNISTSource
        return cls(data_root, hw=hw, duration_ms=duration_ms, split=split,
                   cache_root=cache_root)
    raise ValueError(f"unknown dataset {name!r} (expected one of "
                     f"{DATASETS})")


def resolve_eval_dataset(name: str, **kwargs
                         ) -> tuple[EventSource | None, str | None]:
    """Held-out eval source for a file-backed dataset: ``(val-split
    source, "val")`` when the split is non-empty, ``(None, "train")``
    when it is (tiny fixtures — the engine then evals on the training
    stream), ``(None, None)`` for synthetic datasets (one generative
    stream, no split notion). Callers feed the source to
    ``run_grid(eval_data=...)`` and record the split name in artifact
    metadata."""
    if name not in ("dvs128", "nmnist"):
        return None, None
    try:
        return resolve_dataset(name, split="val", **kwargs), "val"
    except ValueError:
        return None, "train"
