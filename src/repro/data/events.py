"""Synthetic DVS event streams — the offline fallback event source.

Class-conditioned analytic scenes with DVS statistics standing in for
DVS128-Gesture (``gesture`` family) and N-MNIST (``nmnist`` family); the
full generative model and its statistics are documented in
docs/datasets.md ("The synthetic fallback"). The file-backed real-dataset
loaders and the :class:`~repro.data.sources.EventSource` seam that
unifies them with this generator live in ``repro.data.sources``.

Generation scans over integration slots so memory stays bounded at any
temporal resolution (T_INTG = 1 ms ⇒ thousands of slots).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class EventStreamConfig:
    name: str = "gesture"            # "gesture" | "nmnist"
    height: int = 24
    width: int = 24
    n_classes: int = 11
    duration_ms: float = 2000.0
    contrast_gain: float = 18.0      # expected events per unit intensity change
    oversample: int = 3              # intensity samples per slot (anti-alias)
    blob_sigma: float = 0.12         # in units of min(H, W)
    seed_jitter: bool = True         # per-sample phase/position jitter


def dvs_gesture_like(hw: int = 24) -> EventStreamConfig:
    return EventStreamConfig(name="gesture", height=hw, width=hw, n_classes=11)


def nmnist_like(hw: int = 20) -> EventStreamConfig:
    return EventStreamConfig(name="nmnist", height=hw, width=hw, n_classes=10,
                             duration_ms=1200.0, blob_sigma=0.08)


def _grid(cfg: EventStreamConfig) -> tuple[jax.Array, jax.Array]:
    ys = jnp.linspace(-1.0, 1.0, cfg.height)
    xs = jnp.linspace(-1.0, 1.0, cfg.width)
    return jnp.meshgrid(ys, xs, indexing="ij")


def _gesture_centers(t: jax.Array, label: jax.Array, jit_phase: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """Blob center path for gesture-like classes. t in [0,1]."""
    c = label.astype(jnp.float32)
    # class factorization: rotation direction in {-1,0,+1}, axis angle, speed
    rot = (jnp.mod(c, 3.0) - 1.0)                    # -1, 0, +1
    axis = 2.0 * math.pi * jnp.floor(c / 3.0) / 4.0  # 4 axis groups
    speed = 1.0 + 0.5 * jnp.mod(jnp.floor(c / 3.0), 2.0)
    phase = jit_phase
    ang = 2.0 * math.pi * speed * t + phase
    # rotating classes orbit; rot==0 classes oscillate along `axis`
    r = 0.55
    osc = r * jnp.sin(ang)
    px = jnp.where(rot == 0.0, osc * jnp.cos(axis), r * jnp.cos(rot * ang + axis))
    py = jnp.where(rot == 0.0, osc * jnp.sin(axis), r * jnp.sin(rot * ang + axis))
    return px, py


def _nmnist_glyph_params(label: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Two bar angles per digit class."""
    c = label.astype(jnp.float32)
    a1 = math.pi * c / 10.0
    a2 = math.pi * (0.5 + jnp.mod(c * 3.0, 10.0) / 10.0)
    return a1, a2


def _saccade(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """NMNIST 3-saccade triangle path. t in [0,1]."""
    seg = jnp.clip(jnp.floor(t * 3.0), 0, 2)
    u = t * 3.0 - seg
    amp = 0.25
    # triangle vertices
    vx = jnp.array([-amp, amp, 0.0, -amp])
    vy = jnp.array([-amp, -amp, amp, -amp])
    x = vx[seg.astype(jnp.int32)] * (1 - u) + vx[seg.astype(jnp.int32) + 1] * u
    y = vy[seg.astype(jnp.int32)] * (1 - u) + vy[seg.astype(jnp.int32) + 1] * u
    return x, y


def _intensity(t: jax.Array, label: jax.Array, jit_phase: jax.Array,
               cfg: EventStreamConfig) -> jax.Array:
    """Scene intensity at normalized time t (scalar) → [H, W]."""
    yy, xx = _grid(cfg)
    sig = cfg.blob_sigma * 2.0
    if cfg.name == "gesture":
        px, py = _gesture_centers(t, label, jit_phase)
        d2 = (xx - px) ** 2 + (yy - py) ** 2
        return jnp.exp(-d2 / (2 * sig**2))
    elif cfg.name == "nmnist":
        a1, a2 = _nmnist_glyph_params(label)
        sx, sy = _saccade(t)
        out = jnp.zeros_like(xx)
        for a in (a1, a2):
            # oriented bar through (sx, sy)
            ux, uy = jnp.cos(a), jnp.sin(a)
            # distance to line, bounded extent along the bar
            dx, dy = xx - sx, yy - sy
            along = dx * ux + dy * uy
            perp = -dx * uy + dy * ux
            out = out + jnp.exp(-(perp**2) / (2 * (sig * 0.4) ** 2)) * \
                jnp.exp(-(along**2) / (2 * (0.45) ** 2))
        return out
    raise ValueError(cfg.name)


@partial(jax.jit, static_argnames=("cfg", "n_slots", "n_sub"))
def sample_events(key: jax.Array, cfg: EventStreamConfig, labels: jax.Array,
                  n_slots: int, n_sub: int = 1) -> jax.Array:
    """Generate event counts.

    Returns float32 [B, n_slots, n_sub, H, W, 2] where the last axis is
    (ON, OFF) polarity. Total slot count n_slots*n_sub spans
    cfg.duration_ms.
    """
    B = labels.shape[0]
    total = n_slots * n_sub
    kj, kp = jax.random.split(key)
    jit_phase = (jax.random.uniform(kj, (B,)) * 2 * math.pi
                 if cfg.seed_jitter else jnp.zeros((B,)))

    m = cfg.oversample
    dt = 1.0 / (total * m)

    def slot(carry, idx):
        pk = carry
        pk, sk = jax.random.split(pk)
        # intensity samples bounding this fine slot: m+1 points
        t0 = idx.astype(jnp.float32) / total
        ts = t0 + dt * jnp.arange(m + 1)

        def one(b_label, b_phase):
            frames = jax.vmap(lambda t: _intensity(t, b_label, b_phase, cfg))(ts)
            d = jnp.diff(frames, axis=0)                     # [m, H, W]
            pos = jnp.sum(jnp.maximum(d, 0.0), axis=0)
            neg = jnp.sum(jnp.maximum(-d, 0.0), axis=0)
            return jnp.stack([pos, neg], axis=-1)            # [H, W, 2]

        rates = jax.vmap(one)(labels, jit_phase) * cfg.contrast_gain
        counts = jax.random.poisson(sk, rates).astype(jnp.float32)
        return pk, counts

    _, ev = lax.scan(slot, kp, jnp.arange(total))
    # [total, B, H, W, 2] → [B, n_slots, n_sub, H, W, 2]
    ev = jnp.moveaxis(ev, 0, 1)
    return ev.reshape((B, n_slots, n_sub, cfg.height, cfg.width, 2))


def sample_batch(key: jax.Array, cfg: EventStreamConfig, batch_size: int,
                 t_intg_ms: float, n_sub: int = 1
                 ) -> tuple[jax.Array, jax.Array]:
    """Sample (events, labels) at a given first-layer integration time."""
    kl, ke = jax.random.split(key)
    labels = jax.random.randint(kl, (batch_size,), 0, cfg.n_classes)
    n_slots = int(round(cfg.duration_ms / t_intg_ms))
    events = sample_events(ke, cfg, labels, n_slots, n_sub)
    return events, labels


def sample_batch_with_labels(key: jax.Array, cfg: EventStreamConfig,
                             labels: jax.Array, t_intg_ms: float,
                             n_sub: int = 1) -> tuple[jax.Array, jax.Array]:
    """Sample events for *given* labels (class-conditional analysis)."""
    n_slots = int(round(cfg.duration_ms / t_intg_ms))
    events = sample_events(key, cfg, labels, n_slots, n_sub)
    return events, labels


def events_to_frames(events: jax.Array) -> jax.Array:
    """Collapse sub-slots: [B, T, n_sub, H, W, 2] → [B, T, H, W, 2] counts."""
    return events.sum(axis=2)


def refine_slots(events: jax.Array, factor: int) -> jax.Array:
    """Re-bin [B, T, n_sub, ...] onto a coarser T grid: T → T//factor.

    Event-count conserving (property-tested): the same stream integrated at
    a longer T_INTG.
    """
    B, T, n_sub = events.shape[:3]
    assert T % factor == 0
    x = events.reshape((B, T // factor, factor * n_sub) + events.shape[3:])
    return x
