"""Synthetic on-disk dataset fixtures: real file layouts, no network.

CI (and any offline machine) can exercise the full file-backed path —
AEDAT 3.1 / ``.bin`` parsing, labels CSVs, slot-binning, caching, the
``--dataset dvs128`` CLI — by writing a miniature dataset with the
released layouts, populated from the analytic generator
(repro.data.events): we sample its count frames and expand them into
discrete (t, x, y, p) records (repro.data.binning.frames_to_events), so
the files carry class-conditioned DVS statistics, not noise.
"""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import events as events_mod
from repro.data.binning import frames_to_events
from repro.data.formats import (
    DVS128_SENSOR_HW, EventChunk, NMNIST_SENSOR_HW, write_aedat31,
    write_nmnist_bin,
)


def _sample_events(key: jax.Array, cfg, label: int, duration_ms: float,
                   slot_us: int, sensor_hw: tuple[int, int],
                   t0_us: int = 0) -> EventChunk:
    """One labeled recording as discrete events at the sensor resolution."""
    n_total = int(round(duration_ms * 1000 / slot_us))
    frames = events_mod.sample_events(key, cfg, jnp.asarray([label]),
                                      n_total, 1)        # [1,n,1,H,W,2]
    frames = np.asarray(frames[0, :, 0])                 # [n, h, w, 2]
    # upscale generator grid → sensor grid by block repetition. When the
    # sensor dimension is an exact multiple of the generator grid (128/16
    # for DVS128, 34/17 for N-MNIST) the binner's integer downscale maps
    # each block straight back onto its generator pixel; otherwise blocks
    # land approximately (counts are still conserved).
    sh, sw = sensor_hw
    ry, rx = sh // frames.shape[1], sw // frames.shape[2]
    frames = np.repeat(np.repeat(frames, ry, axis=1), rx, axis=2)
    ev = frames_to_events(frames, slot_us)
    return EventChunk(t=ev.t + t0_us, x=ev.x, y=ev.y, p=ev.p)


def make_dvs128_fixture(root: str | Path, *, n_recordings: int = 2,
                        trials_per_recording: int = 11,
                        duration_ms: float = 2000.0, gen_hw: int = 16,
                        slot_us: int = 50_000, seed: int = 0,
                        gap_us: int = 100_000) -> Path:
    """Write a miniature DVS128-Gesture tree: ``fixture_userNN.aedat``
    recordings (each a concatenation of ``trials_per_recording`` gesture
    windows cycling through the 11 classes) with companion
    ``*_labels.csv`` files (1-indexed class, start/end µs)."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    cfg = events_mod.dvs_gesture_like(gen_hw)
    key = jax.random.PRNGKey(seed)
    trial_us = int(duration_ms * 1000)
    for r in range(n_recordings):
        chunks, rows = [], []
        t0 = 0
        for k in range(trials_per_recording):
            label = k % cfg.n_classes
            key, ks = jax.random.split(key)
            ev = _sample_events(ks, cfg, label, duration_ms, slot_us,
                                DVS128_SENSOR_HW, t0_us=t0)
            chunks.append(ev)
            rows.append((label + 1, t0, t0 + trial_us))
            t0 += trial_us + gap_us
        all_ev = EventChunk(*(np.concatenate([getattr(c, f) for c in chunks])
                              for f in ("t", "x", "y", "p")))
        stem = f"fixture_user{r:02d}"
        write_aedat31(root / f"{stem}.aedat", all_ev,
                      comment="synthetic DVS128-Gesture fixture")
        lines = ["class,startTime_usec,endTime_usec"]
        lines += [f"{c},{a},{b}" for c, a, b in rows]
        (root / f"{stem}_labels.csv").write_text("\n".join(lines) + "\n")
    return root


def make_nmnist_fixture(root: str | Path, *, n_per_class: int = 2,
                        duration_ms: float = 300.0, gen_hw: int = 17,
                        slot_us: int = 10_000, seed: int = 0,
                        train_test_dirs: bool = False) -> Path:
    """Write a miniature N-MNIST tree: ``<root>/<digit>/NNNNN.bin`` (or
    the released ``Train``/``Test`` layout with ``train_test_dirs``).
    ``gen_hw=17`` divides the 34×34 ATIS sensor exactly, so the written
    events carry the generator's class glyphs pixel-faithfully."""
    root = Path(root)
    cfg = events_mod.nmnist_like(gen_hw)
    key = jax.random.PRNGKey(seed)
    tops = ([root / "Train", root / "Test"] if train_test_dirs else [root])
    for top in tops:
        for digit in range(cfg.n_classes):
            d = top / str(digit)
            d.mkdir(parents=True, exist_ok=True)
            for i in range(n_per_class):
                key, ks = jax.random.split(key)
                ev = _sample_events(ks, cfg, digit, duration_ms, slot_us,
                                    NMNIST_SENSOR_HW)
                write_nmnist_bin(d / f"{i:05d}.bin", ev)
    return root
