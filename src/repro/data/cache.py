"""On-disk binned-frame cache for file-backed event sources.

Binning a long recording is the expensive part of file-backed sampling
(parse + scatter over millions of events); the binned fine-slot histogram
is tiny. The cache stores one ``.npy`` per (sample, binning) under

    <cache_root>/<dataset>/t<slot_us>us_<H>x<W>_n<slots>/<sample_id>.npy

so the key is exactly (dataset, T_INTG split into fine slots, target
resolution, slot count) — a second sweep at the same T_INTG/resolution
never re-parses a file, and two different T_INTG values coexist side by
side. ``sample_id`` is a sanitized, hash-suffixed form of the sample's
logical id (relative path + trial index), collision-safe across layouts.

The default cache root is ``<data_root>/.p2m-frame-cache`` (gitignored).
"""
from __future__ import annotations

import hashlib
import re
from pathlib import Path

import numpy as np

CACHE_DIRNAME = ".p2m-frame-cache"


def _safe_id(sample_id: str) -> str:
    tag = hashlib.sha1(sample_id.encode()).hexdigest()[:12]
    stem = re.sub(r"[^A-Za-z0-9_.-]+", "_", sample_id)[-48:]
    return f"{stem}__{tag}"


class FrameCache:
    """Tiny get-or-build cache of per-sample binned frames."""

    def __init__(self, root: str | Path, dataset: str):
        self.root = Path(root)
        self.dataset = dataset

    def path(self, sample_id: str, *, slot_us: int, out_hw: tuple[int, int],
             n_total: int) -> Path:
        h, w = out_hw
        d = self.root / self.dataset / f"t{slot_us}us_{h}x{w}_n{n_total}"
        return d / f"{_safe_id(sample_id)}.npy"

    def get_or_build(self, sample_id: str, build, *, slot_us: int,
                     out_hw: tuple[int, int], n_total: int) -> np.ndarray:
        """Return the cached ``[n_total, H, W, 2]`` frames for a sample,
        calling ``build()`` (→ float32 ndarray) on a miss. Writes are
        atomic-enough for single-process sweeps (tmp + rename)."""
        p = self.path(sample_id, slot_us=slot_us, out_hw=out_hw,
                      n_total=n_total)
        if p.exists():
            return np.load(p)
        frames = np.asarray(build(), dtype=np.float32)
        assert frames.shape == (n_total, *out_hw, 2), frames.shape
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp.npy")
        np.save(tmp, frames)
        tmp.replace(p)
        return frames
