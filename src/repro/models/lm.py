"""Decoder-LM family covering all assigned architectures:

  dense  — phi4-mini / gemma-7b / qwen3-32b / internlm2
  moe    — granite-moe / grok-1
  ssm    — mamba2 (attention-free)
  hybrid — zamba2 (Mamba-2 stack + one *shared* attention block every k)
  vlm    — llama-3.2-vision (cross-attention to image tokens every k layers)

Layers are **scanned** with stacked params (HLO size independent of depth —
required for 100-layer archs × 512-way SPMD on a 1-core compile host).
Heterogeneous stacks (hybrid/vlm) scan over *groups* with homogeneous
sub-structure. Encoder-decoder (seamless) lives in models/encdec.py and
reuses these blocks.

API:
  init_params(key, cfg)                      → params pytree
  forward(params, tokens, cfg, ...)          → logits  (train path)
  loss_fn(params, batch, cfg)                → scalar loss, metrics
  prefill(params, tokens, cfg)               → (last_logits, cache)
  init_cache(cfg, batch, max_len)            → cache pytree
  decode_step(params, token, pos, cache, cfg)→ (logits, new cache)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.nn import layers as L
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.sharding.rules import shard_batch

Params = dict


# ---------------------------------------------------------------------------
# remat policy
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# block initializers (single layer; stacked with vmap)
# ---------------------------------------------------------------------------

def _dense_block_init(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "attn": L.attn_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def _ssm_block_init(key, cfg: LMConfig) -> Params:
    return {
        "ln": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "ssm": ssm_mod.ssm_init(key, cfg),
    }


def _cross_block_init(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "xattn": L.attn_init(k1, cfg, cross=True),
        "ln2": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "mlp": L.mlp_init(k2, cfg),
    }


def _stack_init(init_one, key, n: int, cfg: LMConfig) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_one(k, cfg))(keys)


# ---------------------------------------------------------------------------
# block forwards (single layer)
# ---------------------------------------------------------------------------

def _dense_block_fwd(h: jax.Array, bp: Params, cfg: LMConfig,
                     positions: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Returns (h, moe_aux_loss)."""
    a = L.self_attention(bp["attn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps),
                         cfg, causal=True, positions=positions)
    h = h + a
    x = L.rmsnorm(h, bp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = moe_mod.moe_apply(bp["moe"], x, cfg)
        lb = aux["lb_loss"]
    else:
        y = L.mlp_apply(bp["mlp"], x, cfg)
        lb = jnp.zeros((), jnp.float32)
    return h + y, lb


def _ssm_block_fwd(h: jax.Array, bp: Params, cfg: LMConfig) -> jax.Array:
    return h + ssm_mod.ssm_block_apply(
        bp["ssm"], L.rmsnorm(h, bp["ln"], cfg.norm_eps), cfg)


def _cross_block_fwd(h: jax.Array, bp: Params, memory: jax.Array,
                     cfg: LMConfig) -> jax.Array:
    a = L.cross_attention(bp["xattn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps),
                          memory, cfg)
    h = h + a
    y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
    return h + y


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: LMConfig) -> Params:
    ke, kb, ks = jax.random.split(key, 3)
    params: Params = {"embed": L.embed_init(ke, cfg),
                      "final_norm": L.rmsnorm_init(cfg.d_model, L.pdt(cfg))}
    fam = cfg.family
    if fam in ("dense", "moe"):
        params["blocks"] = _stack_init(_dense_block_init, kb, cfg.n_layers, cfg)
    elif fam == "ssm":
        params["blocks"] = _stack_init(_ssm_block_init, kb, cfg.n_layers, cfg)
    elif fam == "hybrid":
        k = cfg.attn_every
        n_groups = cfg.n_layers // k
        # scanned groups of k ssm blocks each  +  ONE shared attention block
        stacked = _stack_init(_ssm_block_init, kb, n_groups * k, cfg)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape((n_groups, k) + x.shape[1:]), stacked)
        params["shared"] = _dense_block_init(ks, cfg)
    elif fam == "vlm":
        k = cfg.cross_every
        n_groups = cfg.n_layers // k
        n_self = n_groups * (k - 1)
        stacked = _stack_init(_dense_block_init, kb, n_self, cfg)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape((n_groups, k - 1) + x.shape[1:]), stacked)
        params["cross_blocks"] = _stack_init(_cross_block_init, ks, n_groups, cfg)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# forward (train / full-sequence)
# ---------------------------------------------------------------------------

def backbone(params: Params, h: jax.Array, cfg: LMConfig,
             positions: jax.Array | None = None,
             img_embed: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Run the layer stack. Returns (hidden, total moe aux loss)."""
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(carry, bp):
            h, lb = carry
            h = shard_batch(h)   # pin the loop carry: see rules.shard_batch
            h, lb_i = _dense_block_fwd(h, bp, cfg, positions)
            return (shard_batch(h), lb + lb_i), None
        (h, lb), _ = lax.scan(_maybe_remat(body, cfg), (h, jnp.zeros((), jnp.float32)),
                              params["blocks"])
        return h, lb

    if fam == "ssm":
        def body(h, bp):
            h = shard_batch(h)
            return shard_batch(_ssm_block_fwd(h, bp, cfg)), None
        h, _ = lax.scan(_maybe_remat(body, cfg), h, params["blocks"])
        return h, jnp.zeros((), jnp.float32)

    if fam == "hybrid":
        shared = params["shared"]

        def group(h, gp):
            h = shard_batch(h)
            def inner(hh, bp):
                return shard_batch(_ssm_block_fwd(hh, bp, cfg)), None
            h, _ = lax.scan(inner, h, gp)
            h, _ = _dense_block_fwd(h, shared, cfg, positions)
            return shard_batch(h), None
        h, _ = lax.scan(_maybe_remat(group, cfg), h, params["blocks"])
        return h, jnp.zeros((), jnp.float32)

    if fam == "vlm":
        assert img_embed is not None, "vlm needs image embeddings"

        def group(h, gp):
            h = shard_batch(h)
            sp, xp = gp
            def inner(hh, bp):
                hh, _ = _dense_block_fwd(hh, bp, cfg, positions)
                return shard_batch(hh), None
            h, _ = lax.scan(inner, h, sp)
            h = _cross_block_fwd(h, xp, img_embed, cfg)
            return shard_batch(h), None
        h, _ = lax.scan(_maybe_remat(group, cfg), h,
                        (params["blocks"], params["cross_blocks"]))
        return h, jnp.zeros((), jnp.float32)

    raise ValueError(fam)


def forward(params: Params, tokens: jax.Array, cfg: LMConfig,
            img_embed: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] → (logits [B, S, Vp], moe aux loss)."""
    h = L.embed_apply(params["embed"], tokens, cfg)
    h, lb = backbone(params, h, cfg, img_embed=img_embed)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg), lb


def loss_fn(params: Params, batch: dict, cfg: LMConfig,
            lb_coef: float = 0.01) -> tuple[jax.Array, dict]:
    """Training loss. Uses the chunked CE (no [B,S,V] logits materialized)."""
    h = L.embed_apply(params["embed"], batch["tokens"], cfg)
    h, lb = backbone(params, h, cfg, img_embed=batch.get("img_embed"))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    ce = L.chunked_cross_entropy(params["embed"], h, batch["labels"], cfg)
    loss = ce + lb_coef * lb
    return loss, {"ce": ce, "lb": lb}


# ---------------------------------------------------------------------------
# KV cache / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or L.cdt(cfg)
    KV, hd = cfg.phys_kv_heads, cfg.head_dim
    fam = cfg.family

    def attn_cache(n, length):
        return {"k": jnp.zeros((n, batch, length, KV, hd), dtype),
                "v": jnp.zeros((n, batch, length, KV, hd), dtype)}

    if fam in ("dense", "moe"):
        return attn_cache(cfg.n_layers, max_len)
    if fam == "ssm":
        c = ssm_mod.ssm_init_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c)
    if fam == "hybrid":
        k = cfg.attn_every
        n_groups = cfg.n_layers // k
        c = ssm_mod.ssm_init_cache(cfg, batch, dtype)
        ssm_c = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups, k) + x.shape).copy(), c)
        return {"ssm": ssm_c, "attn": attn_cache(n_groups, max_len)}
    if fam == "vlm":
        k = cfg.cross_every
        n_groups = cfg.n_layers // k
        self_c = jax.tree.map(
            lambda x: x.reshape((n_groups, k - 1) + x.shape[1:]),
            attn_cache(n_groups * (k - 1), max_len))
        cross_c = attn_cache(n_groups, cfg.n_image_tokens)
        return {"self": self_c, "cross": cross_c}
    raise ValueError(fam)


def _attn_block_decode(h, bp, ck, cv, pos, cfg) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    a, ck, cv = L.decode_attention(bp["attn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps),
                                   ck, cv, pos, cfg)
    h = h + a
    x = L.rmsnorm(h, bp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, _ = moe_mod.moe_apply(bp["moe"], x, cfg)
    else:
        y = L.mlp_apply(bp["mlp"], x, cfg)
    return h + y, ck, cv


def _cross_block_decode(h, bp, ck, cv, cfg, kv_len=None):
    """Cross-attn block during decode: kv cache precomputed at prefill."""
    x = L.rmsnorm(h, bp["ln1"], cfg.norm_eps)
    q = L.project_q(bp["xattn"], x, cfg)
    o = L.attention_core(q, ck.astype(q.dtype), cv.astype(q.dtype),
                         causal=False, chunk=cfg.attn_chunk, kv_len=kv_len)
    h = h + L.attn_out(bp["xattn"], o, cfg)
    y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
    return h + y


def decode_step(params: Params, token: jax.Array, pos: jax.Array, cache: dict,
                cfg: LMConfig) -> tuple[jax.Array, dict]:
    """token [B, 1] → (logits [B, 1, Vp], new cache). One decode step."""
    h = L.embed_apply(params["embed"], token, cfg)
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(h, inp):
            bp, ck, cv = inp
            h, ck, cv = _attn_block_decode(shard_batch(h), bp, ck, cv, pos, cfg)
            return shard_batch(h), (ck, cv)
        h, (nk, nv) = lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}

    elif fam == "ssm":
        def body(h, inp):
            bp, c = inp
            y, c = ssm_mod.ssm_block_decode(
                bp["ssm"], L.rmsnorm(shard_batch(h), bp["ln"], cfg.norm_eps),
                c, cfg)
            return shard_batch(h + y), c
        h, new_cache = lax.scan(body, h, (params["blocks"], cache))

    elif fam == "hybrid":
        shared = params["shared"]

        def group(h, inp):
            gp, ssm_c, ck, cv = inp
            def inner(hh, i2):
                bp, c = i2
                y, c = ssm_mod.ssm_block_decode(
                    bp["ssm"], L.rmsnorm(hh, bp["ln"], cfg.norm_eps), c, cfg)
                return hh + y, c
            h, ssm_c = lax.scan(inner, h, (gp, ssm_c))
            h, ck, cv = _attn_block_decode(h, shared, ck, cv, pos, cfg)
            return h, (ssm_c, ck, cv)
        h, (ssm_c, nk, nv) = lax.scan(
            group, h, (params["blocks"], cache["ssm"],
                       cache["attn"]["k"], cache["attn"]["v"]))
        new_cache = {"ssm": ssm_c, "attn": {"k": nk, "v": nv}}

    elif fam == "vlm":
        def group(h, inp):
            sp, xp, ck, cv, xck, xcv = inp
            def inner(hh, i2):
                bp, k_, v_ = i2
                hh, k_, v_ = _attn_block_decode(hh, bp, k_, v_, pos, cfg)
                return hh, (k_, v_)
            h, (ck, cv) = lax.scan(inner, h, (sp, ck, cv))
            h = _cross_block_decode(h, xp, xck, xcv, cfg)
            return h, (ck, cv)
        h, (nk, nv) = lax.scan(
            group, h, (params["blocks"], params["cross_blocks"],
                       cache["self"]["k"], cache["self"]["v"],
                       cache["cross"]["k"], cache["cross"]["v"]))
        new_cache = {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
    else:
        raise ValueError(fam)

    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg), new_cache


# ---------------------------------------------------------------------------
# prefill — build the cache for a prompt, return last-token logits
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens: jax.Array, cfg: LMConfig,
            img_embed: jax.Array | None = None,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    """tokens [B, S] → (last logits [B, Vp], cache with S entries)."""
    B, S = tokens.shape
    max_len = max_len or S
    h = L.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.arange(S)[None, :]
    fam = cfg.family

    def attn_prefill(bp, x):
        """Self-attn block that also emits its k/v for the cache."""
        xn = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = L.project_qkv(bp["attn"], xn, xn, cfg, positions, positions)
        o = L.attention_core(q, k, v, causal=True, chunk=cfg.attn_chunk)
        x = x + L.attn_out(bp["attn"], o, cfg)
        xm = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            y, _ = moe_mod.moe_apply(bp["moe"], xm, cfg)
        else:
            y = L.mlp_apply(bp["mlp"], xm, cfg)
        # pin the emitted cache rows to their final layout ([B@batch, S,
        # KV@model, hd]) — otherwise GSPMD re-shards the stacked scan
        # output with a full fp32 all-gather at the epilogue
        k = shard_batch(k, None, "model", None)
        v = shard_batch(v, None, "model", None)
        return x + y, k, v

    def pad_kv(k):
        if max_len == S:
            return k
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    def ssm_prefill_one(hh, bp):
        xn = L.rmsnorm(hh, bp["ln"], cfg.norm_eps)
        y, st, tails = ssm_mod._ssm_block_full(bp["ssm"], xn, cfg)
        return hh + y, {"state": st, "conv_x": tails["x"], "conv_bc": tails["bc"]}

    if fam in ("dense", "moe"):
        def body(h, bp):
            h = shard_batch(h)
            h, k, v = attn_prefill(bp, h)
            return shard_batch(h), (pad_kv(k), pad_kv(v))
        h, (ks, vs) = lax.scan(_maybe_remat(body, cfg), h, params["blocks"])
        cache = {"k": ks, "v": vs}

    elif fam == "ssm":
        def ssm_body(h, bp):
            h, c = ssm_prefill_one(shard_batch(h), bp)
            return shard_batch(h), c
        h, cache = lax.scan(ssm_body, h, params["blocks"])

    elif fam == "hybrid":
        shared = params["shared"]

        def group(h, gp):
            h = shard_batch(h)
            h, ssm_c = lax.scan(ssm_prefill_one, h, gp)
            h, k, v = attn_prefill(shared, h)
            return shard_batch(h), (ssm_c, pad_kv(k), pad_kv(v))
        h, (ssm_c, ks, vs) = lax.scan(group, h, params["blocks"])
        cache = {"ssm": ssm_c, "attn": {"k": ks, "v": vs}}

    elif fam == "vlm":
        assert img_embed is not None

        def group(h, gp):
            h = shard_batch(h)
            sp, xp = gp
            def inner(hh, bp):
                hh, k, v = attn_prefill(bp, hh)
                return shard_batch(hh), (pad_kv(k), pad_kv(v))
            h, (ck, cv) = lax.scan(inner, h, sp)
            # cross: cache image k/v for decode reuse
            xn = L.rmsnorm(h, xp["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(xp["xattn"], xn, img_embed, cfg, None, None,
                                    use_rope=False)
            o = L.attention_core(q, k, v, causal=False, chunk=cfg.attn_chunk)
            h = h + L.attn_out(xp["xattn"], o, cfg)
            y = L.mlp_apply(xp["mlp"], L.rmsnorm(h, xp["ln2"], cfg.norm_eps), cfg)
            return h + y, (ck, cv, k, v)
        h, (ck, cv, xk, xv) = lax.scan(
            group, h, (params["blocks"], params["cross_blocks"]))
        cache = {"self": {"k": ck, "v": cv}, "cross": {"k": xk, "v": xv}}
    else:
        raise ValueError(fam)

    h = L.rmsnorm(h[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg)[:, 0], cache
