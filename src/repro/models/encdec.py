"""Encoder-decoder transformer (seamless-m4t-large-v2 backbone).

The audio/text modality frontend is a STUB per spec: the encoder consumes
precomputed frame embeddings [B, S_enc, d_model]. Encoder = bidirectional
self-attention blocks; decoder blocks = causal self-attn + cross-attn to the
encoder output + GLU MLP. All stacks scanned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.nn import layers as L
from repro.sharding.rules import shard_batch
from repro.models.lm import (_dense_block_init, _cross_block_init, _maybe_remat,
                             _stack_init)

Params = dict


def _dec_block_init(key, cfg: LMConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "attn": L.attn_init(k1, cfg),
        "lnx": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "xattn": L.attn_init(k2, cfg, cross=True),
        "ln2": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "mlp": L.mlp_init(k3, cfg),
    }


def init_params(key: jax.Array, cfg: LMConfig) -> Params:
    ke, kb, kd = jax.random.split(key, 3)
    return {
        "embed": L.embed_init(ke, cfg),
        "enc_blocks": _stack_init(_dense_block_init, kb, cfg.encoder_layers, cfg),
        "enc_norm": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
        "dec_blocks": _stack_init(_dec_block_init, kd, cfg.n_layers, cfg),
        "final_norm": L.rmsnorm_init(cfg.d_model, L.pdt(cfg)),
    }


def encode(params: Params, frames: jax.Array, cfg: LMConfig) -> jax.Array:
    """frames: [B, S_enc, D] (stub frontend embeddings) → encoder states."""
    h = frames.astype(L.cdt(cfg))
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, bp):
        a = L.self_attention(bp["attn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps),
                             cfg, causal=False, positions=positions)
        h = h + a
        y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
        return shard_batch(h + y), None

    h, _ = lax.scan(_maybe_remat(body, cfg), shard_batch(h), params["enc_blocks"])
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block_fwd(h, bp, memory, cfg, positions):
    a = L.self_attention(bp["attn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps),
                         cfg, causal=True, positions=positions)
    h = h + a
    x = L.cross_attention(bp["xattn"], L.rmsnorm(h, bp["lnx"], cfg.norm_eps),
                          memory, cfg)
    h = h + x
    y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
    return h + y


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: LMConfig) -> jax.Array:
    """(frames [B,S_enc,D], tokens [B,S_dec]) → logits [B, S_dec, Vp]."""
    memory = encode(params, frames, cfg)
    h = L.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, bp):
        return shard_batch(_dec_block_fwd(h, bp, memory, cfg, positions)), None

    h, _ = lax.scan(_maybe_remat(body, cfg), shard_batch(h), params["dec_blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg)


def loss_fn(params: Params, batch: dict, cfg: LMConfig) -> tuple[jax.Array, dict]:
    memory = encode(params, batch["frames"], cfg)
    h = L.embed_apply(params["embed"], batch["tokens"], cfg)
    positions = jnp.arange(batch["tokens"].shape[1])[None, :]

    def body(h, bp):
        return shard_batch(_dec_block_fwd(h, bp, memory, cfg, positions)), None

    h, _ = lax.scan(_maybe_remat(body, cfg), shard_batch(h), params["dec_blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    ce = L.chunked_cross_entropy(params["embed"], h, batch["labels"], cfg)
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# serving: cross-kv cached at prefill; decoder self-cache grows
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, enc_len: int,
               dtype=None) -> dict:
    dtype = dtype or L.cdt(cfg)
    KV, hd = cfg.phys_kv_heads, cfg.head_dim
    Ld = cfg.n_layers
    return {
        "self": {"k": jnp.zeros((Ld, batch, max_len, KV, hd), dtype),
                 "v": jnp.zeros((Ld, batch, max_len, KV, hd), dtype)},
        "cross": {"k": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype),
                  "v": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype)},
    }


def prefill(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: LMConfig, max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Encode + run decoder prompt, building both caches."""
    memory = encode(params, frames, cfg)
    B, S = tokens.shape
    max_len = max_len or S
    h = L.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.arange(S)[None, :]

    def pad_kv(k):
        if max_len == S:
            return k
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    def body(h, bp):
        xn = L.rmsnorm(h, bp["ln1"], cfg.norm_eps)
        q, k, v = L.project_qkv(bp["attn"], xn, xn, cfg, positions, positions)
        o = L.attention_core(q, k, v, causal=True, chunk=cfg.attn_chunk)
        h = h + L.attn_out(bp["attn"], o, cfg)
        xn = L.rmsnorm(h, bp["lnx"], cfg.norm_eps)
        qx, kx, vx = L.project_qkv(bp["xattn"], xn, memory, cfg, None, None,
                                   use_rope=False)
        o = L.attention_core(qx, kx, vx, causal=False, chunk=cfg.attn_chunk)
        h = h + L.attn_out(bp["xattn"], o, cfg)
        y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
        return shard_batch(h + y), (pad_kv(k), pad_kv(v), kx, vx)

    h, (ks, vs, kxs, vxs) = lax.scan(_maybe_remat(body, cfg), shard_batch(h),
                                     params["dec_blocks"])
    cache = {"self": {"k": ks, "v": vs}, "cross": {"k": kxs, "v": vxs}}
    h = L.rmsnorm(h[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg)[:, 0], cache


def decode_step(params: Params, token: jax.Array, pos: jax.Array, cache: dict,
                cfg: LMConfig) -> tuple[jax.Array, dict]:
    h = L.embed_apply(params["embed"], token, cfg)

    def body(h, inp):
        bp, ck, cv, xck, xcv = inp
        a, ck, cv = L.decode_attention(
            bp["attn"], L.rmsnorm(h, bp["ln1"], cfg.norm_eps), ck, cv, pos, cfg)
        h = h + a
        xn = L.rmsnorm(h, bp["lnx"], cfg.norm_eps)
        q, _, _ = L.project_qkv(bp["xattn"], xn, xn, cfg, None, None,
                                use_rope=False)
        o = L.attention_core(q, xck.astype(q.dtype), xcv.astype(q.dtype),
                             causal=False, chunk=cfg.attn_chunk)
        h = h + L.attn_out(bp["xattn"], o, cfg)
        y = L.mlp_apply(bp["mlp"], L.rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
        return h + y, (ck, cv)

    h, (nk, nv) = lax.scan(body, h, (params["dec_blocks"],
                                     cache["self"]["k"], cache["self"]["v"],
                                     cache["cross"]["k"], cache["cross"]["v"]))
    new_cache = {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg), new_cache
