"""Pallas TPU kernel for the P²M in-pixel analog convolution (paper §2/§4).

TPU-native mapping of the in-pixel dataflow (docs/kernels.md): the per-filter
capacitor state lives in **VMEM** for the whole integration window — exactly
like charge stays on C_K in the pixel — while event patches stream
HBM→VMEM one sub-slot at a time. One fused pass computes

    conv step (MXU)  →  leak decay  →  step non-linearity  →  rail clamp

per sub-slot, then the threshold comparator; only binary spikes leave the
"array". Avoids materializing per-sub-slot conv outputs in HBM
([T·n_sub, P, F] tensors), which is what the pure-XLA path does.

Layout: im2col patches [T_out, n_sub, P, K] (P = B·H'·W' sites, K = receptive
field), weights [K, F]. The grid carries a **circuit-config axis** in front:
grid = (n_cfg, T_out, P tiles), with the per-config leak linearization
``(v_inf, decay)`` AND the per-config comparator threshold ``theta`` (the
variant grid's v_threshold axis) stored as [n_cfg, F] tensors indexed by
the config grid dimension. Patches and weights are config-independent, so
the same event tile is revisited once per config with only new [1, F]
leak/threshold tiles loaded —
this is what lets the co-design sweep engine (core/sweep.py) evaluate all
three MAC circuit configs (and nullifier-mismatch variants) in ONE
pallas_call instead of one compile per circuit. The n_sub loop runs inside
the kernel with the voltage tile VMEM-resident per config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.backend import lane_pad, resolve_interpret


def _p2m_kernel(patches_ref, w_ref, vinf_ref, decay_ref, theta_ref,
                pvg_ref, pvo_ref, spikes_ref, vpre_ref, *,
                dv_unit: float, half_swing: float, v_lo: float, v_hi: float,
                nonlinear: bool):
    n_sub = patches_ref.shape[1]
    bp = patches_ref.shape[2]
    F = w_ref.shape[1]
    vinf = vinf_ref[0, :]                      # [F] — this grid step's config
    decay = decay_ref[0, :]
    theta = theta_ref[0, :]                    # per-config comparator level
    pvg = pvg_ref[0, :]
    pvo = pvo_ref[0, :]

    def sub_step(i, v):
        # leak between events: V ← V_inf + (V - V_inf)·e^{-dt/τ}
        v = vinf + (v - vinf) * decay
        patch = patches_ref[0, i, :, :]        # [bp, K]
        ideal = jnp.dot(patch, w_ref[...],
                        preferred_element_type=jnp.float32) * dv_unit
        if nonlinear:
            g = jnp.clip(1.0 - (v / half_swing) ** 2, 0.05, 1.0)
        else:
            g = 1.0
        v = jnp.clip(v + ideal * g * pvg, v_lo, v_hi)
        return v

    v0 = jnp.zeros((bp, F), jnp.float32)
    v = lax.fori_loop(0, n_sub, sub_step, v0)
    v = v + pvo
    vpre_ref[0, 0, :, :] = v
    spikes_ref[0, 0, :, :] = (v > theta).astype(spikes_ref.dtype)


def p2m_conv_multi_pallas(patches: jax.Array, w: jax.Array, v_inf: jax.Array,
                          decay: jax.Array, theta: jax.Array,
                          pv_gain: jax.Array, pv_offset: jax.Array, *,
                          dv_unit: float, half_swing: float, v_lo: float,
                          v_hi: float, nonlinear: bool = True,
                          block_p: int = 256, interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Multi-circuit-config P²M conv.

    patches: [T_out, n_sub, P, K] f32; w: [K, F];
    v_inf/decay/theta: [n_cfg, F] per-config leak linearizations and
    comparator thresholds (the circuit grid axis — theta rides the same
    [1, F] per-config tile stream as the leak legs, so threshold variants
    cost no extra patch traffic). Returns (spikes, v_pre), both
    [n_cfg, T_out, P, F] f32.

    ``interpret=None`` autodetects the backend (compiled on TPU,
    interpreted elsewhere). Compiled mode pads the K and F lane axes to
    the TPU lane width with zero weights / inert leak legs and crops the
    outputs — zero-filled filters integrate nothing and never spike.
    """
    T, n_sub, P, K = patches.shape
    F = w.shape[1]
    n_cfg = v_inf.shape[0]
    assert decay.shape == (n_cfg, F), (decay.shape, (n_cfg, F))
    assert theta.shape == (n_cfg, F), (theta.shape, (n_cfg, F))
    interpret = resolve_interpret(interpret)
    Fp, Kp = lane_pad(F, interpret), lane_pad(K, interpret)
    if Kp != K:
        patches = jnp.pad(patches, ((0, 0), (0, 0), (0, 0), (0, Kp - K)))
        w = jnp.pad(w, ((0, Kp - K), (0, 0)))
    if Fp != F:
        w = jnp.pad(w, ((0, 0), (0, Fp - F)))
        cfgpad = ((0, 0), (0, Fp - F))
        v_inf = jnp.pad(v_inf, cfgpad)
        decay = jnp.pad(decay, cfgpad)
        theta = jnp.pad(theta, cfgpad)
        pv_gain = jnp.pad(pv_gain, (0, Fp - F))
        pv_offset = jnp.pad(pv_offset, (0, Fp - F))
    block_p = min(block_p, P)
    if P % block_p != 0:
        pad = block_p - P % block_p
        patches = jnp.pad(patches, ((0, 0), (0, 0), (0, pad), (0, 0)))
        P = patches.shape[2]
    grid = (n_cfg, T, P // block_p)

    kernel = functools.partial(
        _p2m_kernel, dv_unit=dv_unit, half_swing=half_swing, v_lo=v_lo,
        v_hi=v_hi, nonlinear=nonlinear)

    spikes, vpre = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_sub, block_p, Kp),
                         lambda c, t, p: (t, 0, p, 0)),
            pl.BlockSpec((Kp, Fp), lambda c, t, p: (0, 0)),
            pl.BlockSpec((1, Fp), lambda c, t, p: (c, 0)),
            pl.BlockSpec((1, Fp), lambda c, t, p: (c, 0)),
            pl.BlockSpec((1, Fp), lambda c, t, p: (c, 0)),
            pl.BlockSpec((1, Fp), lambda c, t, p: (0, 0)),
            pl.BlockSpec((1, Fp), lambda c, t, p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_p, Fp), lambda c, t, p: (c, t, p, 0)),
            pl.BlockSpec((1, 1, block_p, Fp), lambda c, t, p: (c, t, p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_cfg, T, P, Fp), jnp.float32),
            jax.ShapeDtypeStruct((n_cfg, T, P, Fp), jnp.float32),
        ],
        interpret=interpret,
    )(patches, w, v_inf, decay, theta, pv_gain[None, :], pv_offset[None, :])
    return spikes[..., :F], vpre[..., :F]


def p2m_conv_pallas(patches: jax.Array, w: jax.Array, v_inf: jax.Array,
                    decay: jax.Array, theta: jax.Array,
                    pv_gain: jax.Array, pv_offset: jax.Array,
                    *, dv_unit: float, half_swing: float, v_lo: float,
                    v_hi: float, nonlinear: bool = True,
                    block_p: int = 256, interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Single-config wrapper over the multi-config kernel.

    patches: [T_out, n_sub, P, K] f32; w: [K, F]; v_inf/decay/theta: [F].
    Returns (spikes, v_pre) both [T_out, P, F] f32.
    """
    spikes, vpre = p2m_conv_multi_pallas(
        patches, w, v_inf[None, :], decay[None, :], theta[None, :],
        pv_gain, pv_offset,
        dv_unit=dv_unit, half_swing=half_swing, v_lo=v_lo, v_hi=v_hi,
        nonlinear=nonlinear, block_p=block_p, interpret=interpret)
    return spikes[0], vpre[0]
