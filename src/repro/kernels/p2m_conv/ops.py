"""Jit'd wrapper: events → im2col patches → P²M Pallas kernel → spike maps.

``p2m_conv(params, events, cfg)`` is a drop-in for
``repro.core.p2m_layer.p2m_forward_scan`` (mode="kernel").

``p2m_conv_multi(params, events, cfg, leak_cfgs)`` evaluates the SAME events
under several circuit configs in one pallas_call — the kernel grid carries a
leading config axis and the [n_cfg, F] leak tiles are indexed by it (see
p2m_conv.py). This is the fused path the co-design sweep engine uses.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import analog, leakage
from repro.kernels.p2m_conv.p2m_conv import (
    p2m_conv_multi_pallas, p2m_conv_pallas,
)
from repro.kernels.p2m_conv.ref import p2m_conv_multi_ref, p2m_conv_ref


def _extract_patches(frames: jax.Array, k: int, stride: int) -> jax.Array:
    """frames [N, H, W, C] → patches [N, H'out·W'out, k·k·C] (SAME padding)."""
    N, H, W, C = frames.shape
    patches = lax.conv_general_dilated_patches(
        frames, (k, k), (stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # output feature dim is C*k*k ordered (C, kh, kw) per lax docs; weights
    # are (kh, kw, C, F) — we reorder the patch dim to (kh, kw, C).
    Ho, Wo = patches.shape[1], patches.shape[2]
    patches = patches.reshape(N, Ho * Wo, C, k, k)
    patches = jnp.moveaxis(patches, 2, -1)          # [N, P, kh, kw, C]
    return patches.reshape(N, Ho * Wo, k * k * C), (Ho, Wo)


def _prepare(params, events, cfg, leak_cfgs=None):
    """Shared im2col + leak-linearization prep.

    With ``leak_cfgs=None`` the leak/threshold tensors come out [F]
    (single config, from ``cfg.leak``); with a tuple of LeakageConfigs
    they come out [n_cfg, F] (the kernel's circuit grid axis). The
    comparator threshold travels as a tensor alongside the leak legs —
    each variant may override the model-level ``cfg.v_threshold``.
    """
    B, T, n_sub, H, W, Cin = events.shape
    k = cfg.kernel_size
    F = cfg.out_channels
    w_q = analog.quantize_weights(params["w"], cfg.analog)   # [k,k,Cin,F]
    if leak_cfgs is None:
        lk = leakage.kernel_leak_params(w_q, cfg.leak)
        theta = jnp.full((F,), leakage.resolve_v_threshold(
            cfg.leak, cfg.v_threshold), jnp.float32)
    else:
        lk = leakage.stacked_leak_params(w_q, leak_cfgs)
        per = [leakage.resolve_v_threshold(lc, cfg.v_threshold)
               for lc in leak_cfgs]
        theta = jnp.broadcast_to(
            jnp.asarray(per, jnp.float32)[:, None], (len(leak_cfgs), F))
    decay = leakage.decay_factor(lk.tau_ms, cfg.dt_ms)
    frames = events.reshape(B * T * n_sub, H, W, Cin)
    patches, (Ho, Wo) = _extract_patches(frames, k, cfg.stride)
    P = B * Ho * Wo
    # [B,T,n_sub,HoWo,K] → [T, n_sub, B·HoWo, K]
    patches = patches.reshape(B, T, n_sub, Ho * Wo, k * k * Cin)
    patches = jnp.moveaxis(patches, 0, 2).reshape(T, n_sub, P, k * k * Cin)
    w2 = w_q.reshape(k * k * Cin, cfg.out_channels)
    consts = dict(dv_unit=cfg.analog.dv_unit,
                  half_swing=cfg.analog.vdd / 2.0,
                  v_lo=-cfg.analog.v_precharge,
                  v_hi=cfg.analog.vdd - cfg.analog.v_precharge,
                  nonlinear=cfg.analog.enable_nonlinearity)
    return patches, w2, lk.v_inf, decay, theta, params, consts, (B, T, Ho, Wo)


@partial(jax.jit, static_argnames=("cfg", "use_ref"))
def p2m_conv(params: dict, events: jax.Array, cfg, use_ref: bool = False
             ) -> tuple[jax.Array, jax.Array]:
    """events [B, T, n_sub, H, W, Cin] → (spikes, v_pre) [B, T, H', W', F]."""
    patches, w2, v_inf, decay, theta, params, consts, dims = _prepare(
        params, events, cfg)
    B, T, Ho, Wo = dims
    fn = p2m_conv_ref if use_ref else p2m_conv_pallas
    spikes, vpre = fn(patches, w2, v_inf, decay, theta, params["pv_gain"],
                      params["pv_offset"], **consts)
    spikes = spikes[:, :B * Ho * Wo]   # crop tile padding
    vpre = vpre[:, :B * Ho * Wo]

    def back(x):
        x = x.reshape(T, B, Ho, Wo, cfg.out_channels)
        return jnp.moveaxis(x, 0, 1)
    return back(spikes), back(vpre)


@partial(jax.jit, static_argnames=("cfg", "leak_cfgs", "use_ref"))
def p2m_conv_multi(params: dict, events: jax.Array, cfg,
                   leak_cfgs: tuple, use_ref: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """Batched multi-circuit path: one fused kernel launch for all configs.

    events [B, T, n_sub, H, W, Cin] → (spikes, v_pre), both
    [n_cfg, B, T, H', W', F]. ``leak_cfgs`` is a (hashable) tuple of
    LeakageConfig — the circuit axis of the sweep grid.
    """
    patches, w2, v_inf, decay, theta, params, consts, dims = _prepare(
        params, events, cfg, leak_cfgs=leak_cfgs)
    B, T, Ho, Wo = dims
    fn = p2m_conv_multi_ref if use_ref else p2m_conv_multi_pallas
    spikes, vpre = fn(patches, w2, v_inf, decay, theta, params["pv_gain"],
                      params["pv_offset"], **consts)
    spikes = spikes[:, :, :B * Ho * Wo]   # crop tile padding
    vpre = vpre[:, :, :B * Ho * Wo]

    def back(x):
        n_cfg = x.shape[0]
        x = x.reshape(n_cfg, T, B, Ho, Wo, cfg.out_channels)
        return jnp.moveaxis(x, 1, 2)      # [n_cfg, B, T, H', W', F]
    return back(spikes), back(vpre)
