"""Pure-jnp oracle for the P²M conv kernel (same patch-space math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def p2m_conv_ref(patches: jax.Array, w: jax.Array, v_inf: jax.Array,
                 decay: jax.Array, theta: jax.Array,
                 pv_gain: jax.Array, pv_offset: jax.Array,
                 *, dv_unit: float, half_swing: float, v_lo: float,
                 v_hi: float, nonlinear: bool = True
                 ) -> tuple[jax.Array, jax.Array]:
    """patches [T, n_sub, P, K], w [K, F], theta [F] (per-filter comparator
    threshold) → (spikes, v_pre) [T, P, F]."""
    T, n_sub, P, K = patches.shape
    F = w.shape[1]

    def window(ev_win):                         # [n_sub, P, K]
        def sub_step(v, patch):
            v = v_inf + (v - v_inf) * decay
            ideal = (patch.astype(jnp.float32) @ w.astype(jnp.float32)) * dv_unit
            g = jnp.clip(1.0 - (v / half_swing) ** 2, 0.05, 1.0) if nonlinear \
                else 1.0
            v = jnp.clip(v + ideal * g * pv_gain, v_lo, v_hi)
            return v, None

        v0 = jnp.zeros((P, F), jnp.float32)
        v, _ = lax.scan(sub_step, v0, ev_win)
        return v + pv_offset

    v_pre = jax.vmap(window)(patches)
    spikes = (v_pre > theta).astype(jnp.float32)
    return spikes, v_pre


def p2m_conv_multi_ref(patches: jax.Array, w: jax.Array, v_inf: jax.Array,
                       decay: jax.Array, theta: jax.Array,
                       pv_gain: jax.Array, pv_offset: jax.Array, **consts
                       ) -> tuple[jax.Array, jax.Array]:
    """Multi-config oracle: vmap the single-config ref over the leading
    circuit axis of (v_inf, decay, theta) [n_cfg, F] → (spikes, v_pre)
    [n_cfg, T, P, F]."""
    def one(vi, de, th):
        return p2m_conv_ref(patches, w, vi, de, th, pv_gain, pv_offset,
                            **consts)

    return jax.vmap(one)(v_inf, decay, theta)
