"""Pure-jnp oracles for the streaming-fold kernel (the XLA sub-slot scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def stream_fold_ref(x0: jax.Array, deposits: jax.Array, a: jax.Array
                    ) -> jax.Array:
    """``lax.scan`` fold of ``x ← x·a + deposits[s]`` — the exact op
    sequence the kernel fuses, so parity is bit-for-bit.

    x0 [N, F]; deposits [S, N, F]; a [F] → [N, F].
    """
    def step(x, dep):
        return x * a + dep, None

    x, _ = lax.scan(step, x0, deposits)
    return x


def stream_fold_mac_ref(x0: jax.Array, patches: jax.Array, w: jax.Array,
                        a: jax.Array, *, dv_unit: float) -> jax.Array:
    """Patch-space oracle for the MAC variant (same matmul math).

    x0 [N, F]; patches [S, N, K]; w [K, F]; a [F] → [N, F].
    """
    def step(x, patch):
        dep = (patch.astype(jnp.float32) @ w.astype(jnp.float32)) * dv_unit
        return x * a + dep, None

    x, _ = lax.scan(step, x0, patches)
    return x
