"""Pallas TPU kernel: fused streaming leak-fold over the fine sub-slots.

The online serving hot path (repro.stream.accumulator) advances each
lane's standing charge through one replay chunk as

    x ← x·a + c_k,      c_k = conv(events_k) · dv_unit,   k = 0..S−1

— the in-pixel analogue of Neuromorphic-P2M's fused MAC+leak
accumulation. The XLA path runs this as ``lax.scan`` over the S fine
sub-slots, round-tripping the [N, F] charge state through HBM every
step. This kernel fuses the whole sub-slot scan into ONE launch per
coarse slot: the charge tile stays VMEM-resident across all S steps
(exactly like charge staying on the pixel capacitor C_K for the whole
integration window) and only the final state leaves the array.

Two fusion levels, same grid layout (tiles over the flattened
lane·site axis N; the filter axis F is the TPU lane axis, padded to
lane width in compiled mode):

* :func:`stream_fold_pallas` — the serving default. Consumes
  PRE-COMPUTED per-sub-slot deposits ``c_k`` [S, N, F] and fuses the
  fold. Because the deposit stream is produced by the very same conv
  the XLA fold runs, the result is **bit-exact** with the ``lax.scan``
  reference on every backend — the property the streaming parity suite
  (tests/test_streaming.py) pins.
* :func:`stream_fold_mac_pallas` — full fusion: the conv itself runs
  in-kernel as an im2col matmul on the MXU (``patches[s] @ w``), so the
  [S, N, F] deposit tensor is never materialized in HBM. Float-exact
  up to matmul summation order vs the conv path (parity-tested to
  1e-5), which is why serving keeps the deposit variant as the
  bit-exactness oracle's twin.

HBM traffic per chunk drops from the scan's ~3·S·N·F (read x, read c,
write x per step) to (S+1)·N·F reads + N·F writes (deposit variant) or
S·N·K + N·(K·F + 2F) (MAC variant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.backend import lane_pad, resolve_interpret


def _fold_kernel(x0_ref, dep_ref, a_ref, out_ref):
    S = dep_ref.shape[0]
    a = a_ref[0, :]                     # [F] per-filter sub-slot decay

    def step(s, x):
        return x * a + dep_ref[s, :, :]

    out_ref[:, :] = lax.fori_loop(0, S, step, x0_ref[:, :])


def _fold_mac_kernel(x0_ref, patches_ref, w_ref, a_ref, out_ref, *,
                     dv_unit: float):
    S = patches_ref.shape[0]
    a = a_ref[0, :]

    def step(s, x):
        dep = jnp.dot(patches_ref[s, :, :], w_ref[...],
                      preferred_element_type=jnp.float32) * dv_unit
        return x * a + dep

    out_ref[:, :] = lax.fori_loop(0, S, step, x0_ref[:, :])


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def stream_fold_pallas(x0: jax.Array, deposits: jax.Array, a: jax.Array, *,
                       block_n: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Fold ``x ← x·a + deposits[s]`` over all S sub-slots in one launch.

    x0 [N, F] f32 charge carry; deposits [S, N, F]; a [F] per-filter
    decay. Returns the folded state [N, F], bit-exact with
    ``ref.stream_fold_ref`` (the ``lax.scan`` fold).
    """
    S, N, F = deposits.shape
    assert x0.shape == (N, F), (x0.shape, (N, F))
    interpret = resolve_interpret(interpret)
    Fp = lane_pad(F, interpret)
    block_n = min(block_n, N)
    Np = -(-N // block_n) * block_n
    x0 = _pad_axis(_pad_axis(x0, 1, Fp), 0, Np)
    deposits = _pad_axis(_pad_axis(deposits, 2, Fp), 1, Np)
    a = _pad_axis(a, 0, Fp)

    out = pl.pallas_call(
        _fold_kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, Fp), lambda i: (i, 0)),
            pl.BlockSpec((S, block_n, Fp), lambda i: (0, i, 0)),
            pl.BlockSpec((1, Fp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Fp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), jnp.float32),
        interpret=interpret,
    )(x0, deposits, a[None, :])
    return out[:N, :F]


def stream_fold_mac_pallas(x0: jax.Array, patches: jax.Array, w: jax.Array,
                           a: jax.Array, *, dv_unit: float,
                           block_n: int = 256,
                           interpret: bool | None = None) -> jax.Array:
    """Fully-fused variant: deposits computed in-kernel on the MXU.

    x0 [N, F]; patches [S, N, K] (im2col event patches per sub-slot);
    w [K, F]; a [F]. Returns the folded state [N, F]. Matches the
    deposit path to matmul-vs-conv summation order (≤1e-5), not bitwise.
    """
    S, N, K = patches.shape
    F = w.shape[1]
    assert x0.shape == (N, F), (x0.shape, (N, F))
    assert w.shape[0] == K, (w.shape, K)
    interpret = resolve_interpret(interpret)
    Fp = lane_pad(F, interpret)
    Kp = lane_pad(K, interpret)
    block_n = min(block_n, N)
    Np = -(-N // block_n) * block_n
    x0 = _pad_axis(_pad_axis(x0, 1, Fp), 0, Np)
    patches = _pad_axis(_pad_axis(patches, 2, Kp), 1, Np)
    w = _pad_axis(_pad_axis(w, 0, Kp), 1, Fp)
    a = _pad_axis(a, 0, Fp)

    kernel = functools.partial(_fold_mac_kernel, dv_unit=dv_unit)
    out = pl.pallas_call(
        kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, Fp), lambda i: (i, 0)),
            pl.BlockSpec((S, block_n, Kp), lambda i: (0, i, 0)),
            pl.BlockSpec((Kp, Fp), lambda i: (0, 0)),
            pl.BlockSpec((1, Fp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Fp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), jnp.float32),
        interpret=interpret,
    )(x0, patches, w, a[None, :])
    return out[:N, :F]
