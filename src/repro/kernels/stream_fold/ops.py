"""Serving-shaped wrapper: replay-chunk frames → fused Pallas sub-slot fold.

``fold_chunk(x, frames, w_q, a, ...)`` is a drop-in for the XLA
``lax.scan`` fold inside ``repro.stream.accumulator.make_stream_fns``
(the ``use_kernel=True`` switch): it advances every lane's standing
charge through one replay chunk's S fine sub-slots in ONE kernel launch.

``mode="deposit"`` (default) computes the per-sub-slot conv deposits
with the SAME ``repro.core.p2m_layer._conv`` the XLA fold runs — one
conv per sub-slot, identical shapes — then fuses the fold in-kernel.
That makes the result bit-exact with the scan on every backend, which is
the contract serving relies on. ``mode="mac"`` pushes the conv itself
into the kernel as an im2col matmul (full fusion, no deposit tensor in
HBM) at the cost of matmul-vs-conv summation-order drift (≤1e-5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# the SAME conv the XLA fold and the offline curvefit forward run —
# bit-exactness of mode="deposit" depends on it being imported, not copied
from repro.core.p2m_layer import _conv
from repro.kernels.p2m_conv.ops import _extract_patches
from repro.kernels.stream_fold.ref import stream_fold_mac_ref, stream_fold_ref
from repro.kernels.stream_fold.stream_fold import (
    stream_fold_mac_pallas, stream_fold_pallas,
)


def fold_chunk(x: jax.Array, frames: jax.Array, w_q: jax.Array,
               a: jax.Array, *, stride: int, dv_unit: float,
               mode: str = "deposit", block_n: int = 256,
               interpret: bool | None = None,
               use_ref: bool = False) -> jax.Array:
    """One fused launch of ``x ← x·a + conv(ev_s)·dv_unit`` over S sub-slots.

    x [B, Ho, Wo, F] per-lane charge carry (conv OUTPUT resolution);
    frames [B, S, H, W, Cin] the chunk's events on the fine sub-slot
    grid; w_q [k, k, Cin, F] quantized weights; a [F] per-filter decay.
    Returns the advanced charge, same shape as ``x``.
    """
    B, S, H, W, Cin = frames.shape
    F = w_q.shape[-1]
    N = x.shape[0] * x.shape[1] * x.shape[2]
    x_flat = x.reshape(N, F)

    if mode == "deposit":
        # one conv per sub-slot at the lane-batched shape [B, H, W, Cin] —
        # exactly the op sequence of the XLA scan fold, minus the fold
        dep = lax.map(lambda ev: _conv(ev, w_q, stride) * dv_unit,
                      jnp.moveaxis(frames, 1, 0))       # [S, B, Ho, Wo, F]
        dep = dep.reshape(S, N, F)
        fn = stream_fold_ref if use_ref else stream_fold_pallas
        kw = {} if use_ref else {"block_n": block_n, "interpret": interpret}
        out = fn(x_flat, dep, a, **kw)
    elif mode == "mac":
        k = w_q.shape[0]
        patches, _ = _extract_patches(
            frames.reshape(B * S, H, W, Cin), k, stride)  # [B·S, P, K]
        P = patches.shape[1]
        patches = patches.reshape(B, S, P, k * k * Cin)
        patches = jnp.moveaxis(patches, 1, 0).reshape(S, B * P, k * k * Cin)
        w2 = w_q.reshape(k * k * Cin, F)
        fn = stream_fold_mac_ref if use_ref else stream_fold_mac_pallas
        kw = {} if use_ref else {"block_n": block_n, "interpret": interpret}
        out = fn(x_flat, patches, w2, a, dv_unit=dv_unit, **kw)
    else:
        raise ValueError(f"unknown stream_fold mode {mode!r} "
                         f"(expected 'deposit' or 'mac')")
    return out.reshape(x.shape)
