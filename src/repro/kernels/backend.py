"""Shared backend plumbing for the Pallas kernels.

Every kernel wrapper takes ``interpret: bool | None = None``:

  * ``None``  — autodetect: compile on a real TPU backend, fall back to
    Pallas interpret mode everywhere else (CPU CI, GPU containers).
    This is what lets the SAME call sites run compiled on hardware
    without plumbing a flag through every layer.
  * ``True``/``False`` — explicit override (tests pin ``True``; a TPU
    soak run may pin ``False`` to fail loudly if Mosaic rejects the
    kernel instead of silently interpreting).

Compiled TPU kernels also need hardware-aligned tiles: the last (lane)
axis must be a multiple of 128 and the second-to-last (sublane) axis a
multiple of 8 for f32 (see the Pallas TPU guide). ``lane_pad`` /
``sublane_pad`` return the padded extent — identity in interpret mode,
where padding would only burn emulation time.
"""
from __future__ import annotations

import jax

LANE = 128      # TPU lane width (last axis), f32
SUBLANE = 8     # TPU sublane width (second-to-last axis), f32


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → interpret everywhere except a real TPU backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def lane_pad(n: int, interpret: bool) -> int:
    """Padded lane-axis extent: next multiple of 128 when compiled."""
    return n if interpret else _round_up(n, LANE)


def sublane_pad(n: int, interpret: bool) -> int:
    """Padded sublane-axis extent: next multiple of 8 when compiled."""
    return n if interpret else _round_up(n, SUBLANE)
