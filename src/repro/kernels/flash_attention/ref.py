"""Pure-jnp oracle: naive full-materialization attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, kv_len: int | None = None) -> jax.Array:
    """q [BH, Sq, d], k/v [BH, Skv, d] → o [BH, Sq, d]. fp32 softmax."""
    BH, Sq, d = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = jnp.tril(mask)
    if kv_len is not None:
        mask = mask & (jnp.arange(Skv)[None, :] < kv_len)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
