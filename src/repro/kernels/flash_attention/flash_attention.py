"""Pallas TPU flash-attention (forward): online-softmax with q/kv tiling.

Grid = (batch·heads, q blocks, kv blocks); the kv axis is innermost and
sequential on TPU, so the (m, l, acc) accumulators live in VMEM scratch
across kv steps. Causal masking is applied in-tile; fully-masked kv blocks
for a causal q block are skipped structurally by clamping the kv extent.

Used for serving/prefill (forward). Training uses the chunked-jnp reference
(ref.py) which autodiffs; a fused bwd kernel is future work — noted in
docs/kernels.md.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, :]                                   # [bq, d]
    k = k_ref[0, :, :]                                   # [bk, d]
    v = v_ref[0, :, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask = mask & (q_pos >= k_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[0, :, :] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, kv_len: int | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """q [BH, Sq, d], k/v [BH, Skv, d] → o [BH, Sq, d].

    GQA handled by the caller (repeat kv heads / reshape). ``kv_len`` masks
    the cache tail during decode.
    """
    BH, Sq, d = q.shape
    interpret = resolve_interpret(interpret)
    Skv = k.shape[1]
    kv_len = Skv if kv_len is None else kv_len
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sqp, Skvp = q.shape[1], k.shape[1]
    grid = (BH, Sqp // block_q, Skvp // block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               kv_len=min(kv_len, Skv))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq, :]
