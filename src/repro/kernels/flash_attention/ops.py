"""Jit'd wrapper matching nn.layers.attention_core's GQA signature."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "use_ref", "kv_len"))
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, kv_len: int | None = None,
                  use_ref: bool = False) -> jax.Array:
    """q [B, Sq, H, hd]; k/v [B, Skv, KV, hd], H % KV == 0 → [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    # repeat kv across the group dim and flatten (B, H)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    fn = attention_ref if use_ref else flash_attention_pallas
    o = fn(qf, kf, vf, causal=causal, kv_len=kv_len)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
