"""Pure-jnp oracle for the SSD kernel: the sequential (non-chunked) scan.

Independent of both the Pallas kernel AND nn/ssm.ssd_chunked (which is
itself chunked); this is the O(s·n·p) literal recurrence

    state_t = exp(dt_t · A) · state_{t-1} + dt_t · B_t xᵀ_t
    y_t     = C_t · state_t

so it cross-checks both implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [b,s,h,p]; dt [b,s,h]; A [h]; B,C [b,s,g,n]. Returns
    (y [b,s,h,p], state [b,h,p,n]). fp32 math."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hr = h // g
    f32 = jnp.float32
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    Bf = jnp.repeat(B.astype(f32), hr, axis=2)          # [b,s,h,n]
    Cf = jnp.repeat(C.astype(f32), hr, axis=2)

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp                       # [b,h,p],[b,h],[b,h,n]
        decay = jnp.exp(dt_t * A)                       # [b,h]
        upd = jnp.einsum("bhn,bh,bhp->bhpn", B_t, dt_t, x_t)
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bhn,bhpn->bhp", C_t, state)
        return state, y_t

    s0 = jnp.zeros((b, h, p, n), f32)
    final, ys = lax.scan(
        step, s0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)          # [b,s,h,p]
    return y, final
