"""Pallas TPU kernel for the Mamba-2 SSD scan (arXiv:2405.21060).

TPU mapping (docs/kernels.md): the running SSM state [p, n] per (batch, head)
stays **resident in VMEM scratch** across the whole sequence, exactly like
the recurrent state never leaves the register file in the CUDA version —
only inputs stream in per chunk and only y leaves. The chunk axis is the
innermost (sequential) grid dimension, so the state scratch carries across
chunk steps; each chunk does three MXU matmuls:

    G = C · Bᵀ           [L, L]   (intra-chunk attention-like scores)
    y = (G ∘ decay ∘ dt) · x  +  exp(a⁺) ∘ (C · stateᵀ)
    state ← exp(a_L) · state + xᵀ · (B ∘ dt ∘ decay_end)

All statistics in fp32. L (chunk) defaults to 128 — MXU-aligned and the
[L, L] decay tile stays tiny in VMEM.

Grid = (B·H, S/L); per-(b,h) parameters index via closure-computed maps so
grouped B/C (g < h) are never materialized per-head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                state_scr, *, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # [L, p]
    dt = dt_ref[0].astype(jnp.float32)        # [L, 1]... stored [1, L]
    dt = dt.reshape(-1)                       # [L]
    A = a_ref[0, 0]                           # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)         # [L, n]
    Cm = c_ref[0].astype(jnp.float32)         # [L, n]
    L = x.shape[0]

    a = dt * A                                # [L], ≤ 0
    a_cs = jnp.cumsum(a)                      # [L]

    # ---- inter-chunk: contribution of the carried state ----------------
    state = state_scr[...]                    # [p, n]
    y_inter = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # [L, p]
    y_inter = y_inter * jnp.exp(a_cs)[:, None]

    # ---- intra-chunk (quadratic in L) -----------------------------------
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    seg = a_cs[:, None] - a_cs[None, :]
    ii = lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    W = G * decay * dt[None, :]
    y_intra = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update ----------------------------------------------------
    decay_end = jnp.exp(a_cs[-1] - a_cs)      # [L]
    Bw = Bm * (dt * decay_end)[:, None]       # [L, n]
    upd = jax.lax.dot_general(x, Bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [p, n]
    state = state * jnp.exp(a_cs[-1]) + upd
    state_scr[...] = state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_ref[0] = state_scr[...]


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, chunk: int = 128, interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """SSD scan, Pallas grid over (batch·heads, seq chunks).

    x [b,s,h,p]; dt [b,s,h] post-softplus; A [h] negative; B, C [b,s,g,n]
    with h % g == 0. Returns (y [b,s,h,p], final state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    interpret = resolve_interpret(interpret)
    g, n = B.shape[2], B.shape[3]
    hr = h // g
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with zeros → a=0, decay=1, no state contribution
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    # layouts: head-major rows so per-(b,h) rows are contiguous
    x2 = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dt2 = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp, 1)
    a2 = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)
    B2 = jnp.moveaxis(B, 2, 1).reshape(b * g, sp, n)
    C2 = jnp.moveaxis(C, 2, 1).reshape(b * g, sp, n)

    def bc_map(bh, c):
        return (bh // h) * g + (bh % h) // hr, c, 0

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y2, state2 = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, chunk, n), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, p, n), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x2, dt2, a2, B2, C2)

    y = jnp.moveaxis(y2.reshape(b, h, sp, p), 1, 2)[:, :s]
    state = state2.reshape(b, h, p, n)
    return y, state
