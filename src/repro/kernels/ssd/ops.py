"""Jit'd wrapper for the SSD Pallas kernel — drop-in for nn/ssm.ssd_chunked
on the forward path (custom_vjp falls back to the chunked-jnp backward)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.ssd import ssd_pallas
from repro.nn.ssm import ssd_chunked


@partial(jax.jit, static_argnames=("chunk", "use_ref"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, chunk: int = 128, use_ref: bool = False
        ) -> tuple[jax.Array, jax.Array]:
    """SSD scan: x [b,s,h,p], dt [b,s,h], A [h], B/C [b,s,g,n] →
    (y [b,s,h,p], state [b,h,p,n])."""
    fn = ssd_ref if use_ref else partial(ssd_pallas, chunk=chunk)
    return fn(x, dt, A, B, C)


@jax.custom_vjp
def ssd_trainable(x, dt, A, B, C):
    """Forward via the Pallas kernel, backward via the differentiable
    chunked-jnp path (standard interpret-mode pairing; a fused bwd kernel is
    listed as future work in docs/kernels.md)."""
    y, _ = ssd_pallas(x, dt, A, B, C)
    return y


def _fwd(x, dt, A, B, C):
    return ssd_trainable(x, dt, A, B, C), (x, dt, A, B, C)


def _bwd(res, gy):
    x, dt, A, B, C = res
    def f(x, dt, A, B, C):
        y, _ = ssd_chunked(x, dt, A, B, C, chunk=128)
        return y
    _, vjp = jax.vjp(f, x, dt, A, B, C)
    return vjp(gy)


ssd_trainable.defvjp(_fwd, _bwd)
