"""Pure-jnp oracle for the LIF kernel — identical math via lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def lif_ref(x: jax.Array, *, tau: float = 2.0, v_th: float = 1.0,
            soft_reset: bool = True) -> jax.Array:
    """x: [T, N] → spikes [T, N]."""
    def step(v, x_t):
        v = v + (x_t - v) / tau
        s = (v > v_th).astype(x.dtype)
        v = v - s * v_th if soft_reset else v * (1.0 - s)
        return v, s

    _, s = lax.scan(step, jnp.zeros_like(x[0]), x)
    return s
