"""Pallas TPU kernel: fused LIF neuron scan over time.

The LIF update is memory-bound (3 elementwise ops per element per step); the
XLA scan materializes membrane state to HBM every timestep. This kernel keeps
the membrane tile resident in VMEM across the whole time loop: traffic drops
from ~4·T·N (x, v in, v out, s) to (T+1)·N reads + T·N writes.

Layout: x [T, N] (N = flattened batch·features). Grid over N tiles; the time
loop runs inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.backend import lane_pad, resolve_interpret


def _lif_kernel(x_ref, out_ref, *, tau: float, v_th: float, soft_reset: bool):
    T = x_ref.shape[0]

    def step(t, v):
        x_t = pl.load(x_ref, (pl.ds(t, 1), slice(None)))[0]
        v = v + (x_t - v) / tau
        s = (v > v_th).astype(x_ref.dtype)
        if soft_reset:
            v = v - s * v_th
        else:
            v = v * (1.0 - s)
        pl.store(out_ref, (pl.ds(t, 1), slice(None)), s[None])
        return v

    v0 = jnp.zeros((x_ref.shape[1],), x_ref.dtype)
    lax.fori_loop(0, T, step, v0)


def lif_pallas(x: jax.Array, *, tau: float = 2.0, v_th: float = 1.0,
               soft_reset: bool = True, block_n: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """x: [T, N] input currents → spikes [T, N] (forward only).

    ``interpret=None`` autodetects the backend (compiled on TPU,
    interpreted elsewhere); compiled mode rounds the N-tile up to the
    TPU lane width so the membrane tile is hardware-aligned.
    """
    T, N = x.shape
    interpret = resolve_interpret(interpret)
    block_n = lane_pad(min(block_n, N), interpret)
    pad = (-N) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    Np = x.shape[1]
    kernel = functools.partial(_lif_kernel, tau=tau, v_th=v_th,
                               soft_reset=soft_reset)
    out = pl.pallas_call(
        kernel,
        grid=(Np // block_n,),
        in_specs=[pl.BlockSpec((T, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((T, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((T, Np), x.dtype),
        interpret=interpret,
    )(x)
    return out[:, :N]
