"""Jit'd wrapper for the fused LIF kernel, shaped like snn.lif_over_time."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.snn import LIFConfig
from repro.kernels.lif.lif import lif_pallas
from repro.kernels.lif.ref import lif_ref


@partial(jax.jit, static_argnames=("cfg", "use_ref"))
def lif_over_time(x: jax.Array, cfg: LIFConfig = LIFConfig(),
                  use_ref: bool = False) -> jax.Array:
    """x: [T, B, ...] → spikes [T, B, ...] (inference path, no surrogate)."""
    T = x.shape[0]
    flat = x.reshape(T, -1)
    fn = lif_ref if use_ref else lif_pallas
    out = fn(flat, tau=cfg.tau, v_th=cfg.v_threshold,
             soft_reset=cfg.soft_reset)
    return out.reshape(x.shape)
