"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    act="silu",
    rope_theta=1000000.0,
)
