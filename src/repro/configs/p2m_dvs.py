"""The paper's own model: P²M-constrained spiking CNN for DVS gesture
recognition (4 conv + FC512 + FC-classes; first layer in-pixel analog).
This is the paper-faithful configuration used by benchmarks and examples.
"""
from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data.events import EventStreamConfig

# full-scale (DVS128-Gesture geometry)
CONFIG = P2MModelConfig(
    p2m=P2MConfig(out_channels=16, kernel_size=3, stride=1, t_intg_ms=10.0,
                  n_sub=4, leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
    backbone=SpikingCNNConfig(
        in_channels=2, channels=(16, 32, 64, 64), input_hw=(128, 128),
        fc_hidden=512, n_classes=11, first_layer_external=True),
    coarse_window_ms=1000.0,
)

DATA = EventStreamConfig(name="gesture", height=128, width=128, n_classes=11,
                         duration_ms=4000.0)


def reduced(hw: int = 24, channels=(8, 16, 16, 16), fc: int = 64
            ) -> tuple[P2MModelConfig, EventStreamConfig]:
    """CPU-scale variant for smoke tests / benchmarks."""
    from dataclasses import replace
    cfg = CONFIG
    cfg = replace(
        cfg,
        p2m=replace(cfg.p2m, out_channels=channels[0]),
        backbone=replace(cfg.backbone, channels=channels, input_hw=(hw, hw),
                         fc_hidden=fc))
    data = replace(DATA, height=hw, width=hw, duration_ms=2000.0)
    return cfg, data
