"""llama-3.2-vision-90b — decoder LM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision family].

100 layers = 20 groups of (4 self-attn + 1 cross-attn). The vision frontend
is a STUB per spec: input_specs() supplies precomputed patch embeddings
[B, 1601, 1280] (ViT-H grid + CLS); the cross-attn K/V projections consume
them directly. kv=8 replicates to 16 for the model axis.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    act="silu",
    rope_theta=500000.0,
    cross_every=5,
    n_image_tokens=1601,
    vision_dim=1280,
    weight_sharding="2d",
)
