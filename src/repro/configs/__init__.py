"""Architecture registry: ``get_config("<arch-id>")`` → LMConfig.

Arch ids use the assignment's dashes; module files use underscores.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES, LMConfig, ShapeConfig, shape_applicable, smoke_variant,
)

ARCHS: dict[str, str] = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma-7b": "gemma_7b",
    "qwen3-32b": "qwen3_32b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-7b": "zamba2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "grok-1-314b": "grok_1_314b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(arch: str) -> LMConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)
