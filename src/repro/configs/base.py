"""Architecture + shape configuration dataclasses.

Logical configs carry the published numbers; ``phys_*`` properties expose the
TP-padded physical shapes actually allocated (GSPMD rejects uneven shardings,
so vocab / head counts are padded to multiples of the model-axis size — the
standard Megatron/vLLM practice). ``tp_multiple=1`` (smoke configs) keeps
physical == logical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.utils import round_up


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                    # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    act: str = "silu"              # silu → SwiGLU, gelu → GeGLU
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    # --- hybrid (zamba2): one shared attention block every k SSM blocks ---
    attn_every: int = 0
    # --- VLM: cross-attention to image tokens every k layers ---
    cross_every: int = 0
    n_image_tokens: int = 0
    vision_dim: int = 0
    # --- audio/enc-dec ---
    encoder_layers: int = 0        # >0 → encoder-decoder (n_layers = decoder)
    # --- physical/TP ---
    tp_multiple: int = 16          # pad heads/vocab for this model-axis size
    vocab_pad_multiple: int = 2048
    # --- numerics / distribution knobs ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    weight_sharding: str = "auto"  # auto | 2d | tp
    zero1: bool = True
    attn_chunk: int = 1024         # online-softmax KV chunk
    moe_impl: str = "dense"        # dense(one-hot einsum) | scatter

    # ---------------- derived physical shapes ----------------
    @property
    def phys_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, self.tp_multiple)
        return round_up(self.vocab_size, m)

    @property
    def phys_heads(self) -> int:
        return round_up(self.n_heads, self.tp_multiple)

    @property
    def phys_kv_heads(self) -> int:
        if self.n_kv_heads >= self.tp_multiple:
            assert self.n_kv_heads % self.tp_multiple == 0, self.name
            return self.n_kv_heads
        # replicate kv heads up to the TP degree (vLLM/Megatron practice)
        assert self.tp_multiple % self.n_kv_heads == 0 or True
        return round_up(self.tp_multiple, self.n_kv_heads)

    @property
    def q_per_kv(self) -> int:
        assert self.phys_heads % self.phys_kv_heads == 0, self.name
        return self.phys_heads // self.phys_kv_heads

    # ---------------- SSM derived ----------------
    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    # ---------------- structure ----------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def n_self_layers(self) -> int:
        return self.n_layers

    def effective_weight_sharding(self) -> str:
        if self.weight_sharding != "auto":
            return self.weight_sharding
        return "2d" if self.param_count_est() > 8e9 else "tp"

    def param_count_est(self) -> float:
        """Rough parameter count (for sharding-mode selection & rooflines)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        if self.family == "ssm":
            di, nh, gN = self.ssm_inner, self.ssm_nheads, self.ssm_groups * self.ssm_state
            per = D * (2 * di + 2 * gN + nh) + di * D + self.ssm_conv * (di + 2 * gN)
            return L * per + 2 * V * D
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * D
        if self.n_experts:
            ffn = self.n_experts * 3 * D * F + D * self.n_experts
        else:
            ffn = 3 * D * F
        per = attn + ffn + 2 * D
        if self.family == "hybrid":
            di, nh, gN = self.ssm_inner, self.ssm_nheads, self.ssm_groups * self.ssm_state
            ssm_per = D * (2 * di + 2 * gN + nh) + di * D
            n_attn = self.n_layers // max(self.attn_every, 1)
            return (self.n_layers - n_attn) * ssm_per + n_attn * per + 2 * V * D
        total = L * per + 2 * V * D
        if self.is_encdec:
            total += self.encoder_layers * per
        if self.cross_every:
            total += (L // self.cross_every) * attn
        return total

    def active_param_count_est(self) -> float:
        if not self.n_experts:
            return self.param_count_est()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * D
        ffn_active = self.top_k * 3 * D * F + D * self.n_experts
        return L * (attn + ffn_active + 2 * D) + 2 * self.vocab_size * D


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# Families with sub-quadratic context handling run long_500k; pure
# full-attention archs skip it (DESIGN.md §5).
_SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: LMConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in _SUBQUADRATIC_FAMILIES:
        return False, "pure full-attention arch — long_500k skipped per spec"
    return True, ""


def smoke_variant(cfg: LMConfig) -> LMConfig:
    """Tiny same-family config for CPU smoke tests (no TP padding)."""
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, tp_multiple=1, vocab_pad_multiple=8,
        n_image_tokens=8 if cfg.cross_every else 0,
        vision_dim=32 if cfg.cross_every else 0,
        cross_every=2 if cfg.cross_every else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        attn_every=2 if cfg.attn_every else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.n_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        remat="none", zero1=False, weight_sharding="tp", attn_chunk=64,
    )
    return replace(cfg, **kw)
