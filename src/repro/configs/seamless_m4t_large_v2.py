"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf]. The audio frontend is a STUB: the encoder consumes
precomputed frame embeddings [B, S_enc, 1024]. 24 encoder + 24 decoder
layers; vocab 256206 pads to 258048 for the 16-way model axis.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,            # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    tie_embeddings=True,
)
