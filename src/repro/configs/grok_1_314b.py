"""grok-1-314b — 8 experts top-2 MoE [hf:xai-org/grok-1].

8 experts < 16-way model axis → tensor-parallel *inside* experts (d_ff
32768 shards 16-way); weights 2D-sharded (model × data/FSDP) — 314B params
cannot replicate across the data axis.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    weight_sharding="2d",
)
