"""phi4-mini-3.8b — dense, RoPE SwiGLU GQA [arXiv:2412.08905; hf].

NOTE (TP padding): 24 q-heads and 8 kv-heads are not divisible by the 16-way
model axis; physical layout pads q→32 heads (8 zero-init) and replicates
kv→16 (vLLM/Megatron practice). Logical numbers below are the published ones.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    act="silu",
    rope_theta=10000.0,
    tie_embeddings=True,
)
