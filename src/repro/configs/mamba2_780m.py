"""mamba2-780m — attention-free SSM, SSD algorithm [arXiv:2405.21060].

d_inner = 2*1536 = 3072, ssm_head_dim 64 → 48 SSD heads, state N=128.
attention fields are placeholders (family="ssm" never builds attention).
Vocab 50280 pads to 51200 for the 16-way model axis (Megatron practice).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1, n_kv_heads=1, head_dim=64,   # unused (attention-free)
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    tie_embeddings=True,
)
