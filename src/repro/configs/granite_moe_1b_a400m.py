"""granite-moe-1b-a400m — 32 experts top-8, d_ff=512 per expert
[hf:ibm-granite/granite-3.0-1b-a400m-base]. Expert-parallel: 32 experts
shard 2-per-device over the 16-way model axis. Vocab 49155 pads to 51200.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    capacity_factor=1.25,
    tie_embeddings=True,
)
