"""zamba2-7b — hybrid: Mamba-2 stack + ONE shared attention block applied
periodically [arXiv:2411.15242].

81 Mamba-2 blocks grouped 9×9; after each group of 9 the single shared
attention+MLP block runs (Zamba2 shares transformer-block weights across
invocations; we omit the per-invocation LoRA deltas — noted in DESIGN.md).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    attn_every=9,
)
