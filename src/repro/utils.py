"""Small shared utilities: pytree helpers, rng threading, shape math."""
from __future__ import annotations

import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_finite(tree: PyTree) -> bool:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return True
    return bool(jnp.all(jnp.stack(leaves)))


def split_keys(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


class RngStream:
    """Deterministic named rng stream: each `.next(name)` is independent."""

    def __init__(self, key: jax.Array):
        self._key = key
        self._count = 0

    def next(self, name: str = "") -> jax.Array:
        self._count += 1
        return jax.random.fold_in(self._key, hash((name, self._count)) % (2**31))


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def flatten_dict(d: dict, prefix: str = "") -> dict[str, Any]:
    out = {}
    for k, v in d.items():
        path = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, path))
        else:
            out[path] = v
    return out


def unflatten_dict(flat: dict[str, Any]) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """(path-string, leaf) pairs using '/'-joined dict keys / indices."""
    flat_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for keypath, leaf in flat_with_path:
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """Map fn(path_str, leaf) -> new leaf over a pytree."""
    def _fn(keypath, leaf):
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return fn("/".join(parts), leaf)
    return jax.tree_util.tree_map_with_path(_fn, tree)
