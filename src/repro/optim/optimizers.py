"""Optimizers from scratch (no optax offline): AdamW, SGD(+momentum),
global-norm clipping, LR schedules. Functional API:

    opt = adamw(lr=3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are plain pytrees so they shard/checkpoint like params. The `pspec_fn`
hook lets the sharding layer assign ZeRO-1 partition specs to state leaves.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup_steps, 1), final_frac)
    def fn(step):
        warm = lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw(lr: float | Schedule = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01,
          mask_fn: Callable[[str], bool] | None = None) -> Optimizer:
    """AdamW. `mask_fn(path)` returns False to disable weight decay on a leaf
    (biases/norms). Moments are fp32 regardless of param dtype."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros,
                "nu": jax.tree.map(jnp.zeros_like, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)), state["nu"], grads)

        from repro.utils import tree_map_with_path

        def upd(path, p):
            m = _get(mu, path)
            v = _get(nu, path)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            wd = weight_decay if (mask_fn is None or mask_fn(path)) else 0.0
            return (-(lr_t * (u + wd * p.astype(jnp.float32)))).astype(p.dtype)

        updates = tree_map_with_path(upd, params)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)


def _get(tree: PyTree, path: str):
    cur = tree
    for part in path.split("/"):
        if isinstance(cur, dict):
            cur = cur[part]
        elif isinstance(cur, (list, tuple)):
            cur = cur[int(part)]
        else:  # pragma: no cover
            raise KeyError(path)
    return cur


def sgd(lr: float | Schedule = 1e-2, momentum: float = 0.9,
        nesterov: bool = False) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                           state["mom"], grads)
        if nesterov:
            eff = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                               mom, grads)
        else:
            eff = mom
        updates = jax.tree.map(lambda e, p: (-(lr_t * e)).astype(p.dtype), eff, params)
        return updates, {"mom": mom, "step": step}

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)
