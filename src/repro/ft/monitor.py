"""Fault-tolerance monitors for the training loop.

``StragglerMonitor`` — per-step wall-time EMA with kσ outlier detection.
On real pods step time is a collective property (the slowest host gates
everyone), so a persistent outlier means a straggling host / degraded ICI
link; the loop's policy hook decides what to do (log, checkpoint + evict,
re-mesh). Tests drive it with a simulated clock.

``HeartbeatTracker`` — liveness bookkeeping for N workers. A worker missing
``timeout_s`` of heartbeats is dead; the elastic planner (ft/elastic.py)
consumes the dead-set to propose a smaller mesh.

``PreemptionGuard`` — converts SIGTERM/SIGINT into a polled flag so the
training loop can finish its step, write a final checkpoint, and exit
cleanly (the standard TPU-pod maintenance-event dance).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class StragglerMonitor:
    """EMA + variance tracker over step wall-times; flags >kσ outliers."""
    alpha: float = 0.1          # EMA weight of the newest sample
    k_sigma: float = 4.0        # outlier threshold
    warmup_steps: int = 8       # ignore compile/first-touch noise
    min_sigma_frac: float = 0.02  # σ floor as a fraction of the mean

    _mean: float = field(default=0.0, init=False)
    _var: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    _flags: list = field(default_factory=list, init=False)

    def observe(self, step: int, dt_s: float) -> bool:
        """Record one step time. Returns True when flagged as straggler."""
        self._n += 1
        if self._n <= self.warmup_steps:
            # prime the EMA without flagging
            if self._n == 1:
                self._mean = dt_s
            else:
                self._mean += self.alpha * (dt_s - self._mean)
            return False
        sigma = max(self._var ** 0.5, self.min_sigma_frac * max(self._mean, 1e-12))
        is_outlier = dt_s > self._mean + self.k_sigma * sigma
        if is_outlier:
            self._flags.append((step, dt_s, self._mean, sigma))
        else:
            # update statistics from non-outlier samples only, so a stuck
            # host does not inflate the baseline it is measured against
            delta = dt_s - self._mean
            self._mean += self.alpha * delta
            self._var = (1 - self.alpha) * (self._var + self.alpha * delta * delta)
        return is_outlier

    @property
    def mean_s(self) -> float:
        return self._mean

    @property
    def sigma_s(self) -> float:
        return self._var ** 0.5

    @property
    def flags(self) -> list:
        return list(self._flags)

    def consecutive_flags(self, window: int = 3) -> bool:
        """True when the last `window` observed steps were all flagged."""
        if len(self._flags) < window:
            return False
        steps = [f[0] for f in self._flags[-window:]]
        return steps == list(range(steps[0], steps[0] + window))


@dataclass
class HeartbeatTracker:
    """Last-seen bookkeeping for worker liveness (simulated clock in tests)."""
    n_workers: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self._last_seen = {w: now for w in range(self.n_workers)}

    def beat(self, worker: int) -> None:
        self._last_seen[worker] = self.clock()

    def dead(self) -> list[int]:
        now = self.clock()
        return sorted(w for w, t in self._last_seen.items()
                      if now - t > self.timeout_s)

    def alive(self) -> list[int]:
        dead = set(self.dead())
        return [w for w in range(self.n_workers) if w not in dead]


class PreemptionGuard:
    """SIGTERM/SIGINT → polled flag. Use as a context manager around the
    training loop; inside, check ``guard.preempted`` once per step."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._signals = signals
        self._previous: dict = {}
        self._preempted = False

    def __enter__(self):
        for s in self._signals:
            self._previous[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        return False

    def _handler(self, signum, frame):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def trigger(self) -> None:
        """Test hook: simulate a maintenance event."""
        self._preempted = True
