"""Elastic re-mesh planning: given the surviving chip count, pick the next
(data, model) mesh the job restarts onto.

Invariants the planner maintains:

* the **model axis is preserved** when possible — TP degree is baked into
  the padded physical shapes (heads/vocab padded to tp_multiple), so keeping
  it avoids re-padding and keeps checkpoints bit-identical; the data axis
  absorbs capacity loss (DP is the elastic dimension, as in production
  systems);
* the global batch must stay divisible by the new data-parallel degree —
  the planner reports the largest feasible data axis and, if the batch does
  not divide, the per-step accumulation factor that restores the global
  batch exactly;
* failures that break the model axis (survivors < tp) degrade the model
  axis to the largest power-of-two divisor of the survivor count that still
  divides the padded head count.

Checkpoints are mesh-shape-agnostic (checkpoint/store.py), so executing the
plan is: drain → checkpoint → restart with ``ElasticPlan.mesh_shape`` →
restore. The planner is pure and unit-testable.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, int]          # (data, model)
    grad_accum: int                      # microbatch factor to keep global batch
    dropped_chips: int
    note: str

    @property
    def chips(self) -> int:
        return self.mesh_shape[0] * self.mesh_shape[1]


def _largest_pow2_divisor(n: int, cap: int) -> int:
    p = 1
    while p * 2 <= cap and n % (p * 2) == 0:
        p *= 2
    return p


def plan_remesh(surviving_chips: int, *, tp: int, global_batch: int,
                prev_data_axis: int | None = None) -> ElasticPlan:
    """Plan the next mesh after failures leave ``surviving_chips`` healthy."""
    if surviving_chips < 1:
        raise ValueError("no surviving chips")
    if surviving_chips >= tp and surviving_chips % tp == 0:
        model = tp
        note = "model axis preserved"
    elif surviving_chips >= tp:
        # keep tp, round the data axis down to the largest full multiple
        model = tp
        note = "model axis preserved; idle remainder chips"
    else:
        model = _largest_pow2_divisor(tp, surviving_chips)
        note = f"model axis degraded {tp}->{model} (survivors < tp)"
    data = max(surviving_chips // model, 1)
    used = data * model

    # restore the exact global batch: accumulate if it no longer divides
    if global_batch % data == 0:
        accum = 1
    else:
        # per-device microbatch of 1 with accumulation over the remainder
        accum = -(-global_batch // data)  # ceil
        note += f"; grad-accum x{accum} restores global batch {global_batch}"
    return ElasticPlan(mesh_shape=(data, model), grad_accum=accum,
                       dropped_chips=surviving_chips - used, note=note)
