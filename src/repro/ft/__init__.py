from repro.ft.monitor import (  # noqa: F401
    HeartbeatTracker, PreemptionGuard, StragglerMonitor,
)
from repro.ft.elastic import ElasticPlan, plan_remesh  # noqa: F401
