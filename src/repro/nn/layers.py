"""Transformer building blocks: RMSNorm, RoPE, GQA attention (online-softmax
chunked — flash-style memory profile in pure jnp), GLU MLPs, embeddings.

All params are plain dicts of jnp arrays; every apply casts to the config's
compute dtype internally and keeps softmax/norm statistics in fp32.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig

Params = dict


def cdt(cfg: LMConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: LMConfig):
    return jnp.dtype(cfg.param_dtype)


def _normal(key, shape, stddev, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: LMConfig, *, cross: bool = False) -> Params:
    D, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.phys_heads, cfg.phys_kv_heads
    keys = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    kv_in = cfg.vision_dim if (cross and cfg.vision_dim and cfg.family == "vlm") else D
    p = {
        "wq": _normal(keys[0], (D, H * hd), s, pdt(cfg)),
        "wk": _normal(keys[1], (kv_in, KV * hd), s, pdt(cfg)),
        "wv": _normal(keys[2], (kv_in, KV * hd), s, pdt(cfg)),
        "wo": _normal(keys[3], (H * hd, D), s / math.sqrt(2 * cfg.n_layers), pdt(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, pdt(cfg))
        p["k_norm"] = rmsnorm_init(hd, pdt(cfg))
    return p


def project_qkv(p: Params, x: jax.Array, kv_src: jax.Array, cfg: LMConfig,
                positions: jax.Array | None, kv_positions: jax.Array | None,
                *, use_rope: bool = True):
    """Project and (optionally) rotate. Returns q [B,S,H,hd], k/v [B,Skv,KV,hd]."""
    H, KV, hd = cfg.phys_heads, cfg.phys_kv_heads, cfg.head_dim
    dt = cdt(cfg)
    B, S = x.shape[0], x.shape[1]
    Skv = kv_src.shape[1]
    q = (x.astype(dt) @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (kv_src.astype(dt) @ p["wk"].astype(dt)).reshape(B, Skv, KV, hd)
    v = (kv_src.astype(dt) @ p["wv"].astype(dt)).reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        if kv_positions is None:
            kv_positions = jnp.arange(Skv)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def project_q(p: Params, x: jax.Array, cfg: LMConfig) -> jax.Array:
    """Query-only projection (decode over a precomputed cross-attn cache)."""
    H, hd = cfg.phys_heads, cfg.head_dim
    dt = cdt(cfg)
    B, S = x.shape[0], x.shape[1]
    q = (x.astype(dt) @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    return q


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, chunk: int,
                   q_offset: jax.Array | int = 0,
                   kv_len: jax.Array | None = None) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks (flash-style memory).

    q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] with H % KV == 0 (GQA).
    ``q_offset``: absolute position of q[0] (causal masking during decode).
    ``kv_len``: number of valid kv positions (masks cache tail).
    Returns [B, Sq, H, hd]; statistics in fp32.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)
    chunk = min(chunk, Skv)
    if Skv % chunk:  # pad KV to a chunk multiple; mask the tail via kv_len
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Skv
        Skv = Skv + pad
    n_chunks = Skv // chunk
    kc = k.reshape(B, n_chunks, chunk, KV, hd)
    vc = v.reshape(B, n_chunks, chunk, KV, hd)
    off = jnp.asarray(q_offset)
    # [Sq] for scalar offsets, [B, Sq] for per-row offsets
    q_pos = off[..., None] + jnp.arange(Sq) if off.ndim else \
        off + jnp.arange(Sq)

    def step(qg, q_pos, carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp                                  # [B,chunk,KV,hd]
        s = jnp.einsum("bqkgh,bckh->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = idx * chunk + jnp.arange(chunk)
        # mask is [B, Sq, chunk]; q_pos/kv_len broadcast per-row ([B]) or
        # batch-uniform (scalar) — continuous batching decodes rows at
        # different sequence positions through the same step
        mask = jnp.ones((1, qg.shape[1], chunk), jnp.bool_)
        if causal:
            mask = q_pos[..., :, None] >= kv_pos[None, :]
            if mask.ndim == 2:
                mask = mask[None]
        if kv_len is not None:
            kl = jnp.asarray(kv_len)
            kl = kl[:, None, None] if kl.ndim == 1 else kl
            mask = mask & (kv_pos[None, None, :] < kl)
        s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf): contribute nothing
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        # masked lanes carry s = -inf, so exp() already zeroes them — no
        # second where() (saves one [B,Sq,KV,G,chunk] HBM materialization)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l = l * corr + p.sum(axis=-1)
        # PV matmul at the model's compute dtype with fp32 accumulate
        # (flash/MXU practice): for bf16 models this halves the dominant
        # score-tensor HBM traffic; max |p| ≤ 1 so the cast costs < 2^-8
        # relative. fp32 callers (tests/oracles) keep the exact path.
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p.astype(q.dtype),
                        vb.astype(q.dtype),
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    def run_scan(qg_i, q_pos_i, kv_hi):
        """Online-softmax over kv chunks [0, kv_hi) for one q block."""
        Sq_i = qg_i.shape[1]
        m0 = jnp.full((B, Sq_i, KV, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Sq_i, KV, G), jnp.float32)
        a0 = jnp.zeros((B, Sq_i, KV, G, hd), jnp.float32)
        idxs = jnp.arange(kv_hi)
        (m, l, acc), _ = lax.scan(
            partial(step, qg_i, q_pos_i), (m0, l0, a0),
            (idxs, jnp.moveaxis(kc[:, :kv_hi], 1, 0),
             jnp.moveaxis(vc[:, :kv_hi], 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return out

    # causal q-splitting: q block i only attends to kv chunks ≤ its upper
    # end, so later blocks scan longer prefixes — skips the fully-masked
    # (i, j>i) tiles that cost ~half of a full S x S sweep (38% fewer score
    # FLOPs/bytes at nq=4; the causal bound is 50%).
    nq = 4
    static_offset = isinstance(q_offset, int)
    if (causal and static_offset and q_offset == 0 and Sq == Skv
            and kv_len is None and n_chunks % nq == 0 and Sq % nq == 0
            and n_chunks >= nq and Sq // nq >= 1):
        outs = []
        qs = Sq // nq
        for i in range(nq):
            qg_i = qg[:, i * qs:(i + 1) * qs]
            q_pos_i = q_pos[i * qs:(i + 1) * qs]
            outs.append(run_scan(qg_i, q_pos_i, (i + 1) * (n_chunks // nq)))
        out = jnp.concatenate(outs, axis=1)
    else:
        out = run_scan(qg, q_pos, n_chunks)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attn_out(p: Params, o: jax.Array, cfg: LMConfig) -> jax.Array:
    B, S = o.shape[0], o.shape[1]
    dt = cdt(cfg)
    return o.reshape(B, S, -1) @ p["wo"].astype(dt)


def self_attention(p: Params, x: jax.Array, cfg: LMConfig, *,
                   causal: bool = True,
                   positions: jax.Array | None = None) -> jax.Array:
    q, k, v = project_qkv(p, x, x, cfg, positions, positions)
    o = attention_core(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return attn_out(p, o, cfg)


def cross_attention(p: Params, x: jax.Array, memory: jax.Array,
                    cfg: LMConfig) -> jax.Array:
    """memory: [B, Sm, D_mem] (already projected modality embeddings)."""
    q, k, v = project_qkv(p, x, memory, cfg, None, None, use_rope=False)
    o = attention_core(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return attn_out(p, o, cfg)


# --- decode-path attention over a cache --------------------------------

def decode_attention(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, cfg: LMConfig,
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention: x [B,1,D]; cache_k/v [B, Smax, KV, hd].

    ``pos`` is the index the new token writes to; positions ≥ pos mask out.
    Scalar pos = lockstep batch; **[B] pos = continuous batching** (each
    slot at its own sequence length — rope, cache write, and the kv mask
    are all per-row).
    """
    per_row = pos.ndim == 1
    rope_pos = pos[:, None] if per_row else pos[None, None]
    q, k, v = project_qkv(p, x, x, cfg, rope_pos, rope_pos)
    cache_k = _cache_write(cache_k, k, pos)
    cache_v = _cache_write(cache_v, v, pos)
    o = attention_core(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                       causal=False, chunk=cfg.attn_chunk, q_offset=pos,
                       kv_len=pos + 1)
    return attn_out(p, o, cfg), cache_k, cache_v


def _cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """cache [B, Smax, KV, hd] ← new [B, 1, KV, hd] at position pos
    (scalar, or [B] for per-row slots)."""
    new = new.astype(cache.dtype)
    if pos.ndim == 1:
        return jax.vmap(
            lambda c, n, p: lax.dynamic_update_slice(
                c, n, (p.astype(jnp.int32), 0, 0)))(cache, new, pos)
    return lax.dynamic_update_slice(
        cache, new, (0, pos.astype(jnp.int32), 0, 0))


# ---------------------------------------------------------------------------
# MLP (GLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: LMConfig, d_ff: int | None = None) -> Params:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(D)
    return {
        "wg": _normal(k1, (D, F), s, pdt(cfg)),
        "wu": _normal(k2, (D, F), s, pdt(cfg)),
        "wd": _normal(k3, (F, D), (1.0 / math.sqrt(F)) / math.sqrt(2 * cfg.n_layers),
                      pdt(cfg)),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def mlp_apply(p: Params, x: jax.Array, cfg: LMConfig) -> jax.Array:
    dt = cdt(cfg)
    x = x.astype(dt)
    h = _act(x @ p["wg"].astype(dt), cfg.act) * (x @ p["wu"].astype(dt))
    return h @ p["wd"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg: LMConfig) -> Params:
    V, D = cfg.phys_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"embedding": _normal(k1, (V, D), 1.0, pdt(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(k2, (D, V), 1.0 / math.sqrt(D), pdt(cfg))
    return p


def embed_apply(p: Params, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(cdt(cfg))


def unembed_apply(p: Params, x: jax.Array, cfg: LMConfig) -> jax.Array:
    dt = cdt(cfg)
    if cfg.tie_embeddings:
        logits = x.astype(dt) @ p["embedding"].T.astype(dt)
    else:
        logits = x.astype(dt) @ p["unembed"].astype(dt)
    # mask padded vocab entries
    if cfg.phys_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.phys_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -jnp.inf)
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [..., V] (may contain -inf pad-mask), labels [...]. fp32 math."""
    lf = logits.astype(jnp.float32)
    lf = jnp.where(jnp.isinf(lf), -1e30, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def chunked_cross_entropy(p_embed: Params, h: jax.Array, labels: jax.Array,
                          cfg, seq_chunk: int = 256) -> jax.Array:
    """Mean CE without materializing [B, S, V] logits.

    Scans over sequence chunks; each step computes logits for ``seq_chunk``
    positions, reduces to (lse − gold), and discards them. Cuts peak
    activation memory by S/seq_chunk — the difference between fitting and
    not fitting HBM for 200k-vocab archs at 4k×256 batches.
    """
    B, S, D = h.shape
    seq_chunk = min(seq_chunk, S)
    assert S % seq_chunk == 0, (S, seq_chunk)
    n = S // seq_chunk
    hc = jnp.moveaxis(h.reshape(B, n, seq_chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, seq_chunk), 1, 0)

    def step(tot, inp):
        hb, lb = inp
        logits = unembed_apply(p_embed, hb, cfg)
        ce = softmax_cross_entropy(logits, lb)
        return tot + ce.sum(), None

    tot, _ = lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (B * S)
