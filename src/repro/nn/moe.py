"""Mixture-of-Experts layer: top-k routing with capacity, scatter-based
dispatch (GShard-style but without the O(T·E·C) one-hot dispatch tensor —
tokens are scattered into [E, C, D] buffers by rank, which stays feasible at
million-token global batches).

Sharding modes (decided by the sharding rules, not here):
  * EP  — expert axis sharded over "model" (granite: 32 experts / 16)
  * TP  — per-expert d_ff sharded over "model" (grok: 8 experts < 16)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.nn.layers import _act, _normal, cdt, pdt

Params = dict


def moe_init(key, cfg: LMConfig) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    return {
        "router": _normal(k0, (D, E), s, jnp.float32),   # router in fp32
        "wg": _normal(k1, (E, D, F), s, pdt(cfg)),
        "wu": _normal(k2, (E, D, F), s, pdt(cfg)),
        "wd": _normal(k3, (E, F, D),
                      (1.0 / math.sqrt(F)) / math.sqrt(2 * cfg.n_layers), pdt(cfg)),
    }


def capacity(n_tokens: int, cfg: LMConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8


def _batch_groups(total_tokens: int) -> int:
    """Dispatch group count = number of batch shards in the ambient mesh.

    GShard-style locality: capacity is enforced PER GROUP so the rank
    cumsum and the dispatch scatter never cross a data shard — without
    grouping, GSPMD must materialize the global [T·K, D] dispatch on every
    device and all-reduce it (measured: 65% of granite-moe's collective
    bytes and 14x its per-device memory traffic).
    """
    from jax.interpreters import pxla
    import numpy as np
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        return 1
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    g = int(np.prod([shape[a] for a in ("pod", "data") if a in shape]))
    return g if g > 1 and total_tokens % g == 0 else 1


def _shard_moe(x: jax.Array, *spec_tail) -> jax.Array:
    """Constraint helper: leading group axis over batch axes."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or x.shape[0] == 1:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(axes if len(axes) > 1 else axes[0], *spec_tail))


def moe_apply(p: Params, x: jax.Array, cfg: LMConfig,
              groups: int | None = None) -> tuple[jax.Array, dict]:
    """x: [B, S, D] → (y [B, S, D], aux with load-balance loss + stats).

    Grouped (locality-first) dispatch: tokens are split into ``groups``
    independent dispatch groups (defaulting to the mesh's batch-shard
    count); capacity, ranking, and the scatter/gather all stay inside a
    group. The only cross-device movement is the [G, E, Cg, D] buffer
    transpose to expert-major — an all-to-all over the EP axis.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = groups if groups is not None else _batch_groups(T)
    Tg = T // G
    Cg = capacity(Tg, cfg)
    dt = cdt(cfg)
    xg = _shard_moe(x.reshape(G, Tg, D))

    logits = xg.astype(jnp.float32) @ p["router"]            # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # [G, Tg, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # --- rank within (group, expert): capacity enforcement --------------
    # choice-major order so top-1 assignments win capacity slots first.
    flat_e = jnp.swapaxes(expert_idx, 1, 2).reshape(G, K * Tg)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [G, K*Tg, E]
    ranks = jnp.cumsum(onehot, axis=1) - onehot              # rank in expert
    rank = jnp.sum(ranks * onehot, axis=-1)                  # [G, K*Tg]
    keep = rank < Cg
    # aux: load-balance loss (Switch) + drop fraction
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E,
                                      dtype=jnp.float32), axis=(0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(density * density_prob)
    aux = {"lb_loss": lb_loss,
           "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}

    # --- scatter tokens into per-group [E*Cg, D] buffers -----------------
    slot = flat_e * Cg + jnp.minimum(rank, Cg - 1)           # [G, K*Tg]
    tok = jnp.tile(jnp.arange(Tg), K)                        # [K*Tg]
    contrib = jnp.where(keep, 1.0, 0.0).astype(dt)           # [G, K*Tg]
    src = xg.astype(dt)[:, tok, :] * contrib[..., None]      # [G, K*Tg, D]

    def scatter_one(slots_g, src_g):
        return jnp.zeros((E * Cg, D), dt).at[slots_g].add(src_g)
    buf = jax.vmap(scatter_one)(slot, src)                   # [G, E*Cg, D]
    buf = buf.reshape(G, E, Cg, D)

    # --- expert FFN: transpose to expert-major (EP all-to-all) ----------
    bufe = jnp.swapaxes(buf, 0, 1).reshape(E, G * Cg, D)
    if E % max(cfg.tp_multiple, 1) == 0:
        from jax.interpreters import pxla
        if not pxla.thread_resources.env.physical_mesh.empty:
            from jax.sharding import PartitionSpec as P
            mesh = pxla.thread_resources.env.physical_mesh
            if "model" in mesh.axis_names:
                bufe = jax.lax.with_sharding_constraint(
                    bufe, P("model", None, None))
    h = _act(jnp.einsum("ecd,edf->ecf", bufe, p["wg"].astype(dt)), cfg.act)
    h = h * jnp.einsum("ecd,edf->ecf", bufe, p["wu"].astype(dt))
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))  # [E, G*Cg, D]

    # --- transpose back + per-group gather/combine -----------------------
    outg = _shard_moe(jnp.swapaxes(out.reshape(E, G, Cg, D), 0, 1)
                      .reshape(G, E * Cg, D))

    w = (jnp.swapaxes(gate_vals, 1, 2).reshape(G, K * Tg) *
         jnp.where(keep, 1.0, 0.0)).astype(dt)               # [G, K*Tg]

    def gather_one(out_g, slots_g, w_g):
        gathered = out_g[slots_g]                            # [K*Tg, D]
        return jnp.zeros((Tg, D), dt).at[tok].add(gathered * w_g[:, None])
    y = jax.vmap(gather_one)(outg, slot, w)                  # [G, Tg, D]
    return y.reshape(B, S, D), aux
