"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Train/prefill path is the chunked SSD algorithm: quadratic attention-like
term inside fixed-size chunks + a linear recurrence across chunk states.
Decode path carries (conv_state, ssm_state) and costs O(1) per token — this
is what makes the long_500k shape runnable for ssm/hybrid archs.

Projections are kept *split* (wz / wx / wbc / wdt instead of one fused
in_proj) so each output lands on a cleanly shardable axis: d_inner and the
SSD head count shard over "model"; the small B/C projections replicate.
Depthwise conv is per-channel, so splitting x from B/C is exact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.nn.layers import _normal, cdt, pdt, rmsnorm

Params = dict


def ssm_dims(cfg: LMConfig) -> dict:
    di = cfg.ssm_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    return dict(di=di, gn=gn, nh=cfg.ssm_nheads, hp=cfg.ssm_head_dim)


def ssm_init(key, cfg: LMConfig) -> Params:
    d = ssm_dims(cfg)
    D = cfg.d_model
    keys = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(D)
    dt_init = jnp.log(jnp.exp(jnp.linspace(1e-3, 0.1, d["nh"])) - 1.0)  # inv softplus
    return {
        "wz": _normal(keys[0], (D, d["di"]), s, pdt(cfg)),
        "wx": _normal(keys[1], (D, d["di"]), s, pdt(cfg)),
        "wbc": _normal(keys[2], (D, 2 * d["gn"]), s, pdt(cfg)),
        "wdt": _normal(keys[3], (D, d["nh"]), s, pdt(cfg)),
        "conv_wx": _normal(keys[4], (cfg.ssm_conv, d["di"]), 0.2, pdt(cfg)),
        "conv_bx": jnp.zeros((d["di"],), pdt(cfg)),
        "conv_wbc": _normal(keys[5], (cfg.ssm_conv, 2 * d["gn"]), 0.2, pdt(cfg)),
        "conv_bbc": jnp.zeros((2 * d["gn"],), pdt(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, d["nh"])).astype(jnp.float32),
        "D_skip": jnp.ones((d["nh"],), jnp.float32),
        "dt_bias": dt_init.astype(jnp.float32),
        "norm": jnp.ones((d["di"],), pdt(cfg)),
        "out_proj": _normal(keys[2], (d["di"], D),
                            (1.0 / math.sqrt(d["di"])) / math.sqrt(2 * cfg.n_layers),
                            pdt(cfg)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B,S,C], w [K,C] → [B,S,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., L] log-decay increments → [..., L, L] lower-tri cumulative sums
    S[i,j] = sum_{k=j+1..i} a_k  (i ≥ j), -inf above diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                initial_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD scan.

    x [b,s,h,p], dt [b,s,h] (post-softplus), A [h] (negative),
    B, C [b,s,g,n] with h % g == 0. Returns (y [b,s,h,p], state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hr = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(f32)

    a = dtc * A                                              # [b,nc,L,h] ≤ 0
    a_cs = jnp.cumsum(a, axis=2)                             # [b,nc,L,h]

    # ---- intra-chunk (quadratic within chunk) --------------------------
    seg = _segsum(jnp.moveaxis(a, 2, -1))                    # [b,nc,h,L,L]
    decay = jnp.exp(seg)
    scores = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)        # [b,nc,g,L,L]
    scores = jnp.repeat(scores, hr, axis=2)                  # g → h
    scores = scores * decay * jnp.moveaxis(dtc, 2, -1)[..., None, :]
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", scores, xc)

    # ---- chunk states ----------------------------------------------------
    decay_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)           # [b,nc,L,h]
    Bh = jnp.repeat(Bc, hr, axis=3)                          # [b,nc,L,h,n]
    S_chunk = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                         Bh, dtc * decay_end, xc)            # [b,nc,h,p,n]

    # ---- inter-chunk recurrence -----------------------------------------
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])                 # [b,nc,h]
    s0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((b, h, p, n), f32))

    def step(state, inp):
        cd, sc = inp                                          # [b,h], [b,h,p,n]
        prev = state
        state = state * cd[..., None, None] + sc
        return state, prev

    final, prev_states = lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # [b,nc,h,p,n]

    Ch = jnp.repeat(Cc, hr, axis=3)                          # [b,nc,L,h,n]
    y_inter = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                         Ch, jnp.exp(a_cs), prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def _project(p: Params, x: jax.Array, cfg: LMConfig):
    """Shared projection path. x [B,S,D] → (z, x_raw, bc_raw, dt_raw)."""
    dt_ = cdt(cfg)
    x = x.astype(dt_)
    z = x @ p["wz"].astype(dt_)
    xr = x @ p["wx"].astype(dt_)
    bc = x @ p["wbc"].astype(dt_)
    dtr = x @ p["wdt"].astype(dt_)
    return z, xr, bc, dtr


def ssm_block_apply(p: Params, x: jax.Array, cfg: LMConfig,
                    chunk: int = 128) -> jax.Array:
    """Full Mamba-2 block (training). x: [B, S, D] → [B, S, D]."""
    y, _, _ = _ssm_block_full(p, x, cfg, chunk)
    return y


def _ssm_block_full(p: Params, x: jax.Array, cfg: LMConfig, chunk: int = 128):
    """Returns (out, final ssm state, conv tails) — prefill needs all three."""
    d = ssm_dims(cfg)
    dt_ = cdt(cfg)
    B_, S_, _ = x.shape
    z, x_raw, bc_raw, dt_raw = _project(p, x, cfg)
    xs = jax.nn.silu(_causal_conv(x_raw, p["conv_wx"].astype(dt_),
                                  p["conv_bx"].astype(dt_)))
    bcs = jax.nn.silu(_causal_conv(bc_raw, p["conv_wbc"].astype(dt_),
                                   p["conv_bbc"].astype(dt_)))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B_, S_, d["nh"], d["hp"])
    Bm = bcs[..., :d["gn"]].reshape(B_, S_, cfg.ssm_groups, cfg.ssm_state)
    Cm = bcs[..., d["gn"]:].reshape(B_, S_, cfg.ssm_groups, cfg.ssm_state)
    y, state = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + p["D_skip"].astype(y.dtype)[:, None] * xh
    y = y.reshape(B_, S_, d["di"])
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    K = cfg.ssm_conv
    tails = {"x": x_raw[:, -(K - 1):, :], "bc": bc_raw[:, -(K - 1):, :]}
    return out, state, tails


# ---------------------------------------------------------------------------
# decode path — O(1) per token
# ---------------------------------------------------------------------------

def ssm_init_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    d = ssm_dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d["di"]), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * d["gn"]), dtype),
        "state": jnp.zeros((batch, d["nh"], d["hp"], cfg.ssm_state), jnp.float32),
    }


def ssm_block_decode(p: Params, x: jax.Array, cache: dict, cfg: LMConfig
                     ) -> tuple[jax.Array, dict]:
    """x: [B, 1, D] one token. Returns (y [B,1,D], new cache)."""
    d = ssm_dims(cfg)
    dt_ = cdt(cfg)
    B_ = x.shape[0]
    z, x_raw, bc_raw, dt_raw = _project(p, x[:, 0:1], cfg)
    z, x_raw, bc_raw, dt_raw = z[:, 0], x_raw[:, 0], bc_raw[:, 0], dt_raw[:, 0]

    def conv_step(win_cache, new, w, b):
        win = jnp.concatenate([win_cache, new[:, None, :]], axis=1)  # [B,K,C]
        out = jnp.einsum("bkc,kc->bc", win.astype(dt_), w.astype(dt_)) + b.astype(dt_)
        return jax.nn.silu(out), win[:, 1:, :]

    xs, new_cx = conv_step(cache["conv_x"], x_raw, p["conv_wx"], p["conv_bx"])
    bcs, new_cbc = conv_step(cache["conv_bc"], bc_raw, p["conv_wbc"], p["conv_bbc"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    A = -jnp.exp(p["A_log"])
    hr = d["nh"] // cfg.ssm_groups
    xh = xs.reshape(B_, d["nh"], d["hp"]).astype(jnp.float32)
    Bm = jnp.repeat(bcs[..., :d["gn"]].reshape(B_, cfg.ssm_groups, cfg.ssm_state),
                    hr, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(bcs[..., d["gn"]:].reshape(B_, cfg.ssm_groups, cfg.ssm_state),
                    hr, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * A)                                   # [B,nh]
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Cm, state)
    y = y + p["D_skip"][:, None] * xh
    y = y.reshape(B_, d["di"]).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return y, {"conv_x": new_cx, "conv_bc": new_cbc, "state": state}
