from repro.roofline.hlo import collective_bytes, parse_hlo_collectives  # noqa: F401
from repro.roofline.model import HW_V5E, roofline_terms  # noqa: F401
