"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` — note these are
*global* (whole-program) numbers under SPMD, so dividing by chip count gives
the per-chip time. Collective bytes come from the HLO parser (hlo.py).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link


HW_V5E = HardwareModel(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   hw: HardwareModel = HW_V5E) -> dict:
    t_compute = flops / (chips * hw.peak_flops)
    t_memory = bytes_accessed / (chips * hw.hbm_bw)
    t_collective = collective_bytes / (chips * hw.ici_bw)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_collective)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "roofline_bound_s": bound,
        # fraction of peak compute achievable if the dominant term were the
        # only cost (the score we hillclimb):
        "compute_fraction": t_compute / bound if bound > 0 else 0.0,
    }


def model_flops(param_count: float, tokens: float, kind: str = "train") -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count * tokens
