"""Loop-aware cost model over post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers programs (a 64-layer model reports 1/64 of its FLOPs). This
module re-derives the three roofline inputs directly from the HLO:

  * MXU FLOPs:   2 · numel(result) · contraction for every ``dot`` (+convs),
  * bytes:       operand + result bytes of every non-fused instruction
                 (fusions count their boundary, not their interior — interior
                 ops never touch HBM),
  * collective bytes: operand bytes of all-gather / all-reduce /
                 reduce-scatter / all-to-all / collective-permute, split
                 intra- vs cross-pod via replica_groups,

with every while body multiplied by its ``known_trip_count`` backend config
(jax scans always carry it). Computation costs are memoized; call graphs are
DAGs so this is linear in HLO size.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0,
    "u1": 1, "s1": 1,
}

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}[,)\s]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _shapes_of(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _TYPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # text after the opcode's '(' (operands + attrs)
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)        # kind -> bytes
    coll_count: dict = field(default_factory=dict)  # kind -> count
    cross_pod: float = 0.0
    intra_pod: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0) + v
        for k, v in o.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v
        self.cross_pod += o.cross_pod
        self.intra_pod += o.intra_pod
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()},
                    {k: v * n for k, v in self.coll_count.items()},
                    self.cross_pod * n, self.intra_pod * n)

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll.values()))

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes,
            "total_bytes": self.collective_bytes,
            "bytes_by_kind": {k: float(v) for k, v in self.coll.items()},
            "count_by_kind": {k: float(v) for k, v in self.coll_count.items()},
            "cross_pod_bytes": float(self.cross_pod),
            "intra_pod_bytes": float(self.intra_pod),
        }


def _split_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    cur_name = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line or line.rstrip().endswith("->")):
            cur_name = hdr.group(1)
            cur = []
            comps[cur_name] = cur
            # register the computation's parameters from the signature
            sig = hdr.group(2)
            for pm in re.finditer(r"([\w\.\-]+)\s*:\s*(\(?[^,()]*(?:\([^)]*\))?"
                                  r"[^,]*)", sig):
                pass   # parameter types handled via 'parameter' instructions
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type: leading tuple-paren or single token
        if rhs.startswith("("):
            depth, i = 0, 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str = rhs[:i + 1]
            tail = rhs[i + 1:].strip()
        else:
            type_str, _, tail = rhs.partition(" ")
        # opcode = first word of tail, its args follow in parens
        paren = tail.find("(")
        if paren < 0:
            continue
        opcode = tail[:paren].strip()
        rest = tail[paren + 1:]
        comps[cur_name].append(_Instr(name, type_str, opcode, rest, line))
    return comps


def _sig_param_types(text: str) -> dict[str, dict[str, str]]:
    """computation -> param name -> type str (from signatures)."""
    out: dict[str, dict[str, str]] = {}
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if not hdr:
            continue
        comp, sig = hdr.group(1), hdr.group(2)
        params: dict[str, str] = {}
        depth = 0
        token = ""
        parts = []
        for ch in sig:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(token)
                token = ""
            else:
                token += ch
        if token.strip():
            parts.append(token)
        for part in parts:
            if ":" not in part:
                continue
            pname, ptype = part.split(":", 1)
            params[pname.strip().lstrip("%")] = ptype.strip()
        out[comp] = params
    return out


def _dot_flops(instr: _Instr, table: dict[str, str]) -> float:
    result_elems = 1
    shapes = _shapes_of(instr.type_str)
    if not shapes:
        return 0.0
    for d in shapes[0][1]:
        result_elems *= d
    cm = _CONTRACT_RE.search(instr.rest)
    ops = _OPERANDS_RE.findall(instr.rest.split(")")[0])
    contraction = 1
    if cm and ops:
        lhs_type = table.get(ops[0])
        if lhs_type:
            lhs_shapes = _shapes_of(lhs_type)
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for idx in (int(x) for x in cm.group(1).split(",") if x):
                    if idx < len(dims):
                        contraction *= dims[idx]
    return 2.0 * result_elems * contraction


def _conv_flops(instr: _Instr, table: dict[str, str]) -> float:
    shapes = _shapes_of(instr.type_str)
    if not shapes:
        return 0.0
    result_elems = 1
    for d in shapes[0][1]:
        result_elems *= d
    ops = _OPERANDS_RE.findall(instr.rest.split(")")[0])
    if len(ops) < 2 or ops[1] not in table:
        return 2.0 * result_elems   # unknown kernel: lower bound
    k_shapes = _shapes_of(table[ops[1]])
    if not k_shapes:
        return 2.0 * result_elems
    kdims = k_shapes[0][1]
    k_elems = 1
    for d in kdims:
        k_elems *= d
    # per output element: kernel_elems / out_channels MACs (feature dim last)
    out_feat = kdims[-1] if kdims else 1
    return 2.0 * result_elems * (k_elems / max(out_feat, 1))


def _is_cross_pod(line: str, pod_stride: int) -> bool | None:
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    for grp in re.findall(r"\{([\d,\s]*)\}", "{" + m.group(1) + "}"):
        ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
        if len(ids) >= 2 and (max(ids) // pod_stride) != (min(ids) // pod_stride):
            return True
    return False


class HloCostModel:
    def __init__(self, hlo_text: str, pod_stride: int = 256):
        self.comps = _split_computations(hlo_text)
        self.sig_params = _sig_param_types(hlo_text)
        self.pod_stride = pod_stride
        self._memo: dict[str, Cost] = {}
        self._fused: set[str] = set()
        # fused computations: bodies of fusion ops — their interior doesn't
        # touch HBM; FLOPs inside still count.
        for comp, instrs in self.comps.items():
            for ins in instrs:
                if ins.opcode == "fusion":
                    cm = _CALLS_RE.search(ins.rest)
                    if cm:
                        self._fused.add(cm.group(1))

    def _table_for(self, comp: str) -> dict[str, str]:
        table: dict[str, str] = dict(self.sig_params.get(comp, {}))
        for ins in self.comps.get(comp, []):
            table[ins.name] = ins.type_str
        return table

    def comp_cost(self, comp: str, *, in_fusion: bool = False) -> Cost:
        key = comp + ("#f" if in_fusion else "")
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        table = self._table_for(comp)
        for ins in self.comps.get(comp, []):
            total += self._instr_cost(ins, table, in_fusion)
        self._memo[key] = total
        return total

    def _operand_bytes(self, ins: _Instr, table: dict[str, str]) -> int:
        seg = ins.rest.split(")")[0]
        names = _OPERANDS_RE.findall(seg)
        if ins.opcode == "fusion":
            return self._fusion_operand_bytes(ins, names, table)
        return sum(_bytes_of(table[n]) for n in names if n in table)

    def _fusion_operand_bytes(self, ins: _Instr, names: list[str],
                              table: dict[str, str]) -> int:
        """Fusion operand traffic, dynamic-slice aware.

        A fusion whose parameter is consumed ONLY by dynamic-slice ops reads
        just the slice from HBM, not the whole operand — charging the full
        array over-counts scan-over-stacked-weights programs by n_layers x
        (a decode step reads [1, ...] of a [48, ...] stack per iteration).
        """
        m = _CALLS_RE.search(ins.rest)
        total = 0
        sliced: dict[int, int] = {}
        if m and m.group(1) in self.comps:
            body = self.comps[m.group(1)]
            # parameter index -> instruction name
            pidx: dict[str, int] = {}
            for bi in body:
                if bi.opcode == "parameter":
                    pm = re.match(r"(\d+)", bi.rest)
                    if pm:
                        pidx[bi.name] = int(pm.group(1))
            # find params consumed only by dynamic-slice; record slice bytes
            consumers: dict[str, list[_Instr]] = {}
            for bi in body:
                for opn in _OPERANDS_RE.findall(bi.rest.split(")")[0]):
                    if opn in pidx:
                        consumers.setdefault(opn, []).append(bi)
            for pname, uses in consumers.items():
                if uses and all(u.opcode in ("dynamic-slice",
                                             "dynamic-update-slice")
                                for u in uses):
                    # dynamic-slice reads the slice; dynamic-update-slice
                    # aliases its big operand in place (reads nothing of it)
                    sliced[pidx[pname]] = sum(
                        _bytes_of(u.type_str) for u in uses
                        if u.opcode == "dynamic-slice")
        for i, n in enumerate(names):
            if n not in table:
                continue
            total += sliced.get(i, _bytes_of(table[n]))
        return total

    def _instr_cost(self, ins: _Instr, table: dict[str, str],
                    in_fusion: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        if op == "dot":
            c.flops += _dot_flops(ins, table)
        elif op == "convolution":
            c.flops += _conv_flops(ins, table)
        elif op == "while":
            m = _WHILE_RE.search(ins.rest)
            trips = 1
            tm = _TRIP_RE.search(ins.line)
            if tm:
                trips = int(tm.group(1))
            if m:
                body = self.comp_cost(m.group(2), in_fusion=in_fusion)
                cond = self.comp_cost(m.group(1), in_fusion=in_fusion)
                inner = Cost()
                inner += body
                inner += cond
                c += inner.scaled(trips)
            return c      # while op itself: no extra bytes (buffers alias)
        elif op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            if m:
                c += self.comp_cost(m.group(1), in_fusion=True)
        elif op in ("call", "custom-call", "conditional", "async-start"):
            m = _TO_APPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
            if m:
                c += self.comp_cost(m.group(1), in_fusion=in_fusion)
        elif op.startswith(_COLLECTIVE_KINDS) or any(
                op == k or op == k + "-start" for k in _COLLECTIVE_KINDS):
            kind = next(k for k in _COLLECTIVE_KINDS if op.startswith(k))
            if not op.endswith("-done"):
                nbytes = self._operand_bytes(ins, table)
                if nbytes == 0:
                    nbytes = _bytes_of(ins.type_str)
                c.coll[kind] = c.coll.get(kind, 0) + nbytes
                c.coll_count[kind] = c.coll_count.get(kind, 0) + 1
                xp = _is_cross_pod(ins.line, self.pod_stride)
                if xp:
                    c.cross_pod += nbytes
                elif xp is False:
                    c.intra_pod += nbytes
        # memory traffic: boundary of non-fused instructions only
        if not in_fusion and op not in ("while", "parameter", "constant",
                                        "get-tuple-element", "tuple", "bitcast"):
            c.bytes += self._result_bytes(ins) + self._operand_bytes(ins, table)
        return c

    def _result_bytes(self, ins: _Instr) -> int:
        """Result-side traffic; a fusion rooted at dynamic-update-slice
        writes only the update (the carried array aliases in place)."""
        if ins.opcode == "fusion":
            m = _CALLS_RE.search(ins.rest)
            if m and m.group(1) in self.comps:
                body = self.comps[m.group(1)]
                if body and body[-1].opcode == "dynamic-update-slice":
                    root = body[-1]
                    names = _OPERANDS_RE.findall(root.rest.split(")")[0])
                    tbl = self._table_for(m.group(1))
                    if len(names) >= 2 and names[1] in tbl:
                        return _bytes_of(tbl[names[1]])
        return _bytes_of(ins.type_str)

    def entry_cost(self) -> Cost:
        entry = None
        for name in self.comps:
            if "main" in name or name.startswith("entry"):
                entry = name
                break
        if entry is None:   # fall back: the computation not called by others
            called: set[str] = set()
            for instrs in self.comps.values():
                for ins in instrs:
                    for pat in (_CALLS_RE, _TO_APPLY_RE, _WHILE_RE):
                        m = pat.search(ins.rest)
                        if m:
                            called.update(g for g in m.groups() if g)
            entry = next(n for n in self.comps if n not in called)
        return self.comp_cost(entry)


def analyze_hlo(hlo_text: str, pod_stride: int = 256) -> Cost:
    return HloCostModel(hlo_text, pod_stride).entry_cost()


# --- compatibility helpers -------------------------------------------------

def parse_hlo_collectives(hlo_text: str, pod_stride: int = 256) -> Cost:
    return analyze_hlo(hlo_text, pod_stride)


def collective_bytes(hlo_text: str) -> float:
    return analyze_hlo(hlo_text).collective_bytes
