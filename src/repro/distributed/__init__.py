from repro.distributed.compression import (  # noqa: F401
    CompressionState, compress_int8, decompress_int8, compressed_allreduce,
    init_error_feedback,
)
