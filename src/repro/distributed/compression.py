"""Gradient compression for cross-pod all-reduce: int8 block quantization
with error feedback (1-bit-Adam / PowerSGD-family trick, int8 variant).

Why: the multi-pod mesh reduces gradients over the "pod" axis across the
data-center network (much thinner than intra-pod ICI). Quantizing the
cross-pod leg to int8 cuts that traffic 4× (fp32) / 2× (bf16); the residual
(quantization error) is added back into the *next* step's gradient — error
feedback — which keeps SGD convergence unaffected to first order
(Karimireddy et al., 2019).

Scheme per leaf:
  * split the flattened gradient into blocks of ``block`` elements,
  * per-block scale = max|g| / 127 (symmetric int8),
  * q = round(g / scale) ∈ [-127, 127]  (int8),
  * residual = g - q·scale  (carried in the error-feedback state, fp32).

Used inside shard_map for the pod-axis reduce (the "model"/"data" legs stay
full precision over ICI). Pure functions; the pjit train step threads
``CompressionState`` alongside the optimizer state.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    """Error-feedback residual per gradient leaf (same tree as grads)."""
    residual: PyTree


def init_error_feedback(grads_like: PyTree) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.size
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return x.reshape(-1), pad


def compress_int8(g: jax.Array, block: int = 256
                  ) -> tuple[jax.Array, jax.Array, int]:
    """g (any shape) → (q int8 [nblocks, block], scale f32 [nblocks], pad)."""
    flat, pad = _pad_to(g.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], pad


def decompress_int8(q: jax.Array, scale: jax.Array, pad: int,
                    shape: tuple[int, ...]) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_allreduce(g: jax.Array, ef: jax.Array, axis_name: str,
                         block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce of one leaf over ``axis_name``.

    Must run inside shard_map with ``axis_name`` bound. Returns
    (mean-reduced gradient, new error-feedback residual).

    The compressed payload (int8 q + one f32 scale per block) is what
    crosses the network: 1 + 4/block bytes/elem vs 4 — a ~3.8× cut at
    block=256.
    """
    gf = g.astype(jnp.float32) + ef
    q, scale, pad = compress_int8(gf, block)
    # what this shard actually contributes after quantization:
    contributed = decompress_int8(q, scale, pad, g.shape)
    new_ef = gf - contributed
    # the WIRE payload is the compressed form: all-gather int8 q + f32
    # per-block scales (1 + 4/block bytes/elem vs 4), then dequantize and
    # mean locally — int8 summation would overflow, and gather+local-reduce
    # is the standard scheme for quantized cross-pod legs.
    q_all = jax.lax.all_gather(q, axis_name)          # [N, blocks, block] i8
    s_all = jax.lax.all_gather(scale, axis_name)      # [N, blocks] f32
    flat = (q_all.astype(jnp.float32) *
            s_all[..., None]).sum(axis=0).reshape(-1)
    if pad:
        flat = flat[:-pad]
    n = q_all.shape[0]
    reduced = (flat / n).reshape(g.shape)
    return reduced.astype(g.dtype), new_ef


def tree_compressed_allreduce(grads: PyTree, state: CompressionState,
                              axis_name: str, block: int = 256
                              ) -> tuple[PyTree, CompressionState]:
    out = jax.tree.map(
        lambda g, e: compressed_allreduce(g, e, axis_name, block),
        grads, state.residual)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return reduced, CompressionState(residual=new_res)
