"""Mesh-sharding of the serving engine's lane axis.

The ``[capacity, ...]`` lane axis of the streaming fold/readout programs
(repro.stream.accumulator) is embarrassingly parallel — every lane
integrates its own stream's leak ODE with the same deployed weights — so
it shards exactly the way the sweep engine's stacked variant axis does
(core/sweep_exec.py): a 1-D device mesh, ``shard_map`` over the leading
axis, and leading-axis padding up to a device multiple.

:class:`LaneExecutor` is the :class:`~repro.core.sweep_exec.MeshExecutor`
instantiation for the 1-D ``"lane"`` mesh. ``devices=1`` is the exact
unsharded path (no mesh, no padding, plain ``jax.jit``); ``devices=n``
pads the lane capacity to a multiple of n and runs each device's
``capacity / n`` lanes under ``shard_map``. Padded lanes are never
admitted (their ``active`` mask stays False, and the per-shard
:class:`~repro.serve.slots.ShardedSlots` bookkeeping never places a
stream on them), so sharded serving is bit-for-bit identical to
``devices=1`` — the same parity bar the sweep executor set
(tests/test_stream_shard.py pins it).

On CPU CI the mesh comes from forced host devices, mirroring the sweep::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.stream --smoke --devices 8
"""
from __future__ import annotations

from dataclasses import dataclass

from jax.sharding import PartitionSpec

from repro.core.sweep_exec import MeshExecutor, P_REP
from repro.serve.slots import ShardedSlots

LANE_AXIS = "lane"
# pytree-prefix specs for the serving steps: state/frames/masks are all
# stacked on the leading lane axis; closed-over weights are replicated.
P_LANE = PartitionSpec(LANE_AXIS)

__all__ = ["LANE_AXIS", "P_LANE", "P_REP", "LaneExecutor",
           "make_lane_executor", "ShardedSlots"]


@dataclass(frozen=True)
class LaneExecutor(MeshExecutor):
    """The serving engine's executor: the lane axis on a 1-D ``"lane"``
    mesh. All mesh/padding/spec machinery is inherited from
    :class:`~repro.core.sweep_exec.MeshExecutor`."""
    axis: str = LANE_AXIS


def make_lane_executor(devices: int | None) -> LaneExecutor:
    """CLI entry: ``devices=None`` → single-device executor.

    Validates the device count EAGERLY (builds the mesh up front) so a
    bad ``--devices`` fails before any stream is opened — the same
    contract as ``sweep_exec.make_executor``.
    """
    ex = LaneExecutor(devices=devices or 1)
    if ex.is_sharded:
        _ = ex.mesh
    return ex
