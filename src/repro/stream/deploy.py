"""Deployment handshake: sweep artifact + checkpoint → a servable model.

The offline sweep engine (repro.core.sweep) measures every circuit
variant; serving deploys ONE of them. The handshake has two halves:

  * the **sweep artifact** (``p2m-codesign-sweep/v3`` JSON) is the menu —
    :func:`select_record` picks the record (circuit, v_threshold, sigma,
    T_INTG, n_sub, protocol) to deploy, by accuracy or explicitly;
  * the **checkpoint** (repro.checkpoint.store layout) is the weights —
    :func:`deploy_from_sweep` slices the chosen variant's trained
    layer-1 + backbone (+ BN state) out of a ``keep_params=True`` grid
    run and writes one committed checkpoint whose ``extra`` block embeds
    the record and the full model config, so :func:`load_deployment`
    rebuilds the servable :class:`Deployment` from the checkpoint alone.

``offline_forward`` is the deployment-level batched reference forward —
the oracle the streaming engine (repro.stream.engine) is tested against,
and the precise statement of what "serving this record" computes.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import leakage, p2m_layer, snn
from repro.core.analog import AnalogConfig
from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import LIFConfig, SpikingCNNConfig

DEPLOY_SCHEMA = "p2m-stream-deploy/v1"


# ---------------------------------------------------------------------------
# model-config (de)serialization — the checkpoint must be self-describing
# ---------------------------------------------------------------------------

def model_config_to_dict(cfg: P2MModelConfig) -> dict:
    """JSON-safe dict of the full model config (enums → values)."""
    d = asdict(cfg)
    d["p2m"]["leak"]["circuit"] = cfg.p2m.leak.circuit.value
    return d


def model_config_from_dict(d: dict) -> P2MModelConfig:
    """Inverse of :func:`model_config_to_dict` (JSON round-trip safe:
    lists are coerced back to the config tuples)."""
    p2m = dict(d["p2m"])
    leak = dict(p2m.pop("leak"))
    leak["circuit"] = CircuitConfig(leak["circuit"])
    analog_cfg = AnalogConfig(**p2m.pop("analog"))
    bb = dict(d["backbone"])
    lif = LIFConfig(**bb.pop("lif"))
    bb["channels"] = tuple(bb["channels"])
    bb["input_hw"] = tuple(bb["input_hw"])
    return P2MModelConfig(
        p2m=P2MConfig(**p2m, analog=analog_cfg,
                      leak=LeakageConfig(**leak)),
        backbone=SpikingCNNConfig(**bb, lif=lif),
        coarse_window_ms=d["coarse_window_ms"])


def leak_config_from_variant(variant: dict, base: LeakageConfig
                             ) -> LeakageConfig:
    """A record's ``"variant"`` dict (core/variant_grid.variant_dict) →
    the LeakageConfig the serving path runs. The record carries the
    RESOLVED comparator threshold, so it is pinned as the per-variant
    override (no model-default fallback ambiguity at load time)."""
    return replace(base,
                   circuit=CircuitConfig(variant["circuit"]),
                   null_mismatch=float(variant["null_mismatch"]),
                   v_threshold=float(variant["v_threshold"]),
                   sigma=float(variant.get("sigma") or 0.0))


# ---------------------------------------------------------------------------
# the servable bundle
# ---------------------------------------------------------------------------

@dataclass
class Deployment:
    """One servable variant: model config pinned to the deployed cell
    (``p2m.t_intg_ms``/``n_sub``/``leak`` = the record's), its trained
    params + BN state, and the sweep record it came from.

    ``meta`` is the registry-facing metadata the checkpoint carries
    beyond the record itself (``dataset``, ``sensor_hw``, ...) — what
    :func:`repro.stream.registry.entry_meta` folds into the catalog row
    so a fleet registry can match streams to variants without reopening
    the training data."""
    model_cfg: P2MModelConfig
    params: dict                 # {"p2m": {...}, "backbone": {...}}
    bn_state: dict
    record: dict
    protocol: str = "frozen"
    meta: dict = field(default_factory=dict)

    @property
    def coeffs(self) -> leakage.LeakCoeffs:
        """Branch-free numerics of the deployed variant — exactly what
        the offline engine's jitted steps ran with."""
        return leakage.leak_coeffs(self.model_cfg.p2m.leak,
                                   self.model_cfg.p2m.v_threshold)

    @property
    def t_intg_ms(self) -> float:
        return self.model_cfg.p2m.t_intg_ms

    def deployed_meta(self) -> dict:
        """The ``"deployed"`` block of the serving-stats artifact."""
        return {"label": self.record.get("label"),
                "protocol": self.protocol,
                "t_intg_ms": self.t_intg_ms,
                "n_sub": self.model_cfg.p2m.n_sub,
                "variant": self.record.get("variant"),
                "accuracy_offline": self.record.get("accuracy")}


def offline_forward(dep: Deployment, events: jax.Array) -> dict:
    """The deployment's offline batched forward — the reference the
    online engine must match (tests/test_streaming.py).

    ``events``: [B, T, n_sub, H, W, 2] binned frames over the full
    stream. Returns the intermediate tensors of the serving contract:
    layer-1 ``spikes`` [B, T, H, W, C] and ``v_pre``, the 2x-``pooled``
    spike maps, the backbone-grid ``coarse`` counts, and the rate-decoded
    ``logits`` [B, n_classes].
    """
    cfg = dep.model_cfg
    spikes, v_pre = p2m_layer.p2m_forward_curvefit_coeffs(
        dep.params["p2m"], events, cfg.p2m, dep.coeffs)
    B, T = spikes.shape[:2]
    tb = snn.max_pool(spikes.reshape((B * T,) + spikes.shape[2:]))
    pooled = tb.reshape((B, T) + tb.shape[1:])
    coarse = p2m_layer.coarsen_spikes(pooled, cfg.coarsen_group())
    logits, _, _ = snn.spiking_cnn_apply(dep.params["backbone"],
                                         dep.bn_state, coarse, cfg.backbone,
                                         train=False)
    return {"spikes": spikes, "v_pre": v_pre, "pooled": pooled,
            "coarse": coarse, "logits": logits}


def fresh_deployment(model_cfg: P2MModelConfig, *, seed: int = 0,
                     protocol: str = "frozen") -> Deployment:
    """An UNTRAINED deployment (fresh init) — serving-path benchmarks
    measure latency/throughput, which do not need trained weights."""
    from repro.core import codesign, variant_grid

    params, state = codesign.model_init(jax.random.PRNGKey(seed), model_cfg)
    lc = model_cfg.p2m.leak
    record = {
        "label": variant_grid.variant_label(lc),
        "t_intg_ms": model_cfg.p2m.t_intg_ms,
        "n_sub": model_cfg.p2m.n_sub,
        "variant": variant_grid.variant_dict(
            lc, v_threshold_default=model_cfg.p2m.v_threshold,
            n_sub=model_cfg.p2m.n_sub),
        "accuracy": None,
        "untrained": True,
    }
    return Deployment(model_cfg=model_cfg, params=params, bn_state=state,
                      record=record, protocol=protocol)


# ---------------------------------------------------------------------------
# record selection
# ---------------------------------------------------------------------------

def _record_sort_key(r: dict) -> tuple:
    """Total deterministic order over sweep records: best accuracy first,
    ties broken by shortest T_INTG, label, protocol, n_sub, and finally
    the canonical (key-sorted) variant dict. Every component is an
    intrinsic record field — NEVER the position in the records list — so
    selection is reproducible across dict/JSON orderings, which is what
    keeps registry compat keys and deployed checkpoints stable across
    re-serializations of the same artifact."""
    variant = r.get("variant") or {}
    return (-(r.get("accuracy") or 0.0), r["t_intg_ms"],
            str(r.get("label")), str(r.get("protocol")),
            r.get("n_sub") or 0,
            json.dumps(variant, sort_keys=True, default=float))


def select_record(records: list[dict], *, protocol: str | None = None,
                  t_intg_ms: float | None = None,
                  label: str | None = None) -> dict:
    """Pick the record to deploy: filter by protocol / T_INTG / variant
    label, then take the best accuracy. Tie-breaking is TOTAL
    (:func:`_record_sort_key`): equal-accuracy records resolve by
    intrinsic fields, never by input order, so the same artifact always
    deploys the same record however its JSON was (re)serialized."""
    pool = [r for r in records
            if (protocol is None or r.get("protocol") == protocol)
            and (t_intg_ms is None or r["t_intg_ms"] == t_intg_ms)
            and (label is None or r["label"] == label)]
    if not pool:
        raise ValueError(
            f"no sweep record matches protocol={protocol!r} "
            f"t_intg_ms={t_intg_ms!r} label={label!r} "
            f"({len(records)} records total)")
    return min(pool, key=_record_sort_key)


def select_from_artifact(artifact: dict | str | Path, **kwargs) -> dict:
    """``select_record`` over a sweep-artifact dict or JSON path."""
    if isinstance(artifact, (str, Path)):
        artifact = json.loads(Path(artifact).read_text())
    schema = artifact.get("schema", "")
    if not str(schema).startswith("p2m-codesign-sweep/"):
        raise ValueError(f"not a co-design sweep artifact "
                         f"(schema={schema!r})")
    return select_record(artifact["records"], **kwargs)


# ---------------------------------------------------------------------------
# checkpoint save / load
# ---------------------------------------------------------------------------

def save_deployment(directory: str | Path, dep: Deployment) -> Path:
    """Write one committed, self-describing serving checkpoint. The
    ``extra`` block embeds the record, the full model config, and the
    registry metadata (``dep.meta`` — dataset, sensor_hw, ...) so
    :func:`load_deployment` can feed
    :meth:`repro.stream.registry.Registry.register` directly."""
    tree = {"params": dep.params, "bn_state": dep.bn_state}
    extra = {
        "deploy_schema": DEPLOY_SCHEMA,
        "protocol": dep.protocol,
        "record": dep.record,
        "model_config": model_config_to_dict(dep.model_cfg),
        "registry_meta": dict(dep.meta),
    }
    return store.save_checkpoint(directory, 0, tree, extra)


def load_deployment(directory: str | Path,
                    artifact: dict | str | Path | None = None) -> Deployment:
    """Rebuild a :class:`Deployment` from a serving checkpoint.

    ``artifact`` optionally cross-checks the checkpoint against the sweep
    artifact it was deployed from: the embedded record must appear there
    (same label / protocol / T_INTG) — the handshake guard against
    serving weights whose menu entry was regenerated.

    Corrupt or internally inconsistent extras raise ``ValueError``
    instead of mis-deploying: a checkpoint whose embedded record
    disagrees with its embedded model config (t_intg_ms / n_sub / leak
    variant) would serve weights under the WRONG circuit numerics.
    """
    tree, extra = store.load_checkpoint(directory)
    if extra.get("deploy_schema") != DEPLOY_SCHEMA:
        raise ValueError(
            f"{directory} is not a streaming deployment checkpoint "
            f"(extra.deploy_schema={extra.get('deploy_schema')!r}; "
            f"expected {DEPLOY_SCHEMA!r})")
    missing = [k for k in ("record", "model_config", "protocol")
               if k not in extra]
    if missing:
        raise ValueError(
            f"{directory} deployment checkpoint extras are corrupt: "
            f"missing {missing} — re-run deploy_from_sweep")
    try:
        model_cfg = model_config_from_dict(extra["model_config"])
    except (KeyError, TypeError, ValueError) as e:
        raise ValueError(
            f"{directory} deployment checkpoint embeds a malformed "
            f"model_config ({e!r}) — re-run deploy_from_sweep") from e
    record = extra["record"]
    for fld in ("t_intg_ms", "n_sub"):
        if fld in record and record[fld] != getattr(model_cfg.p2m, fld):
            raise ValueError(
                f"{directory} checkpoint record/model_config mismatch: "
                f"record.{fld}={record[fld]!r} but model_config pins "
                f"{getattr(model_cfg.p2m, fld)!r} — the extras were "
                f"tampered with or mixed from different deployments")
    variant = record.get("variant") or {}
    if ("circuit" in variant
            and variant["circuit"] != model_cfg.p2m.leak.circuit.value):
        raise ValueError(
            f"{directory} checkpoint record/model_config mismatch: "
            f"record.variant.circuit={variant['circuit']!r} but "
            f"model_config pins {model_cfg.p2m.leak.circuit.value!r} — "
            f"serving would run the wrong leak numerics")
    tree = jax.tree.map(jnp.asarray, tree)
    dep = Deployment(
        model_cfg=model_cfg,
        params=tree["params"], bn_state=tree["bn_state"],
        record=record, protocol=extra["protocol"],
        meta=dict(extra.get("registry_meta") or {}))
    if artifact is not None:
        _check_against_artifact(dep, artifact)
    return dep


def _check_against_artifact(dep: Deployment,
                            artifact: dict | str | Path) -> None:
    if isinstance(artifact, (str, Path)):
        artifact = json.loads(Path(artifact).read_text())
    key = ("label", "protocol", "t_intg_ms", "n_sub")
    want = tuple(dep.record.get(k) for k in key)
    for r in artifact.get("records", []):
        if tuple(r.get(k) for k in key) == want:
            return
    raise ValueError(
        f"checkpoint record {dict(zip(key, want))} not found in the sweep "
        f"artifact — the artifact and checkpoint are from different runs")


def deploy_from_sweep(result: Any, model_cfg: P2MModelConfig, record: dict,
                      directory: str | Path,
                      meta: dict | None = None) -> Path:
    """Slice ``record``'s variant out of a ``keep_params=True``
    :class:`~repro.core.sweep.GridResult` and write its serving
    checkpoint. Frozen cells share one layer-1; unfrozen cells carry a
    per-variant stacked layer-1 that is sliced like the backbone.
    ``meta`` (dataset, sensor_hw, ...) is persisted as the checkpoint's
    registry metadata (see repro.stream.registry)."""
    cell = (record["t_intg_ms"], record["n_sub"])
    if cell not in result.final_params:
        raise ValueError(
            f"grid result holds no final params for cell {cell} — run the "
            f"sweep with keep_params=True (cells kept: "
            f"{sorted(result.final_params)})")
    g = list(result.labels).index(record["label"])
    fp = result.final_params[cell]
    take = lambda tree: jax.tree.map(lambda v: v[g], tree)  # noqa: E731
    p2m_params = (take(fp["p2m"]) if result.protocol == "unfrozen"
                  else fp["p2m"])
    leak = leak_config_from_variant(record["variant"], model_cfg.p2m.leak)
    cfg_cell = replace(model_cfg, p2m=replace(
        model_cfg.p2m, t_intg_ms=record["t_intg_ms"],
        n_sub=record["n_sub"], mode="curvefit", leak=leak))
    dep = Deployment(model_cfg=cfg_cell,
                     params={"p2m": p2m_params,
                             "backbone": take(fp["backbone"])},
                     bn_state=take(fp["state"]),
                     record=record, protocol=result.protocol,
                     meta=dict(meta or {}))
    return save_deployment(directory, dep)


# ---------------------------------------------------------------------------
# adaptation delta checkpoints (repro.stream.adapt → new registry entries)
# ---------------------------------------------------------------------------

ADAPT_DELTA_SCHEMA = "p2m-stream-adapt-delta/v1"


def deployment_digest(dep: Deployment) -> str:
    """Content digest of a deployment as an ADAPTATION BASE: the full
    model config plus the exact quantized layer-1 weights and comparator
    threshold the per-lane deltas are relative to. A delta checkpoint is
    only meaningful against the base it was learned on —
    :func:`load_adapt_delta` refuses to apply one whose stamped digest
    does not match the offered base."""
    w_q = p2m_layer.effective_weights(dep.params["p2m"], dep.model_cfg.p2m)
    h = hashlib.sha256()
    h.update(json.dumps(model_config_to_dict(dep.model_cfg),
                        sort_keys=True, default=float).encode())
    h.update(np.asarray(w_q, np.float32).tobytes())
    h.update(np.float32(dep.coeffs.v_threshold).tobytes())
    return h.hexdigest()[:16]


def save_adapt_delta(directory: str | Path, base: Deployment, *,
                     dw, dtheta: float, base_name: str = "default",
                     base_uid: int = 0, lane: int = 0, n_updates: int = 0,
                     rule: str = "surrogate",
                     meta: dict | None = None) -> Path:
    """Write one adapted lane's deltas as a committed delta checkpoint.

    ``dw``/``dtheta`` are relative to ``base``'s QUANTIZED layer-1
    weights and deployed threshold (the convention of
    :meth:`repro.stream.engine.StreamEngine.harvest` — the lane served
    ``quantize(w_q_base + dw)`` at ``theta_base + dtheta``). The extras
    stamp the base's registry identity (``base_name``/``base_uid``) and
    its content digest, so a later :func:`load_adapt_delta` can validate
    the delta is being applied to the exact base it was learned on."""
    dw = np.asarray(dw, np.float32)
    w_q = p2m_layer.effective_weights(base.params["p2m"],
                                      base.model_cfg.p2m)
    if dw.shape != w_q.shape:
        raise ValueError(
            f"dw shape {dw.shape} does not match the base's layer-1 "
            f"weights {tuple(w_q.shape)}")
    tree = {"dw": dw, "dtheta": np.float32(dtheta)}
    extra = {
        "delta_schema": ADAPT_DELTA_SCHEMA,
        "base": {"name": base_name, "uid": int(base_uid),
                 "digest": deployment_digest(base)},
        "lane": int(lane),
        "n_updates": int(n_updates),
        "rule": rule,
        "meta": dict(meta or {}),
    }
    return store.save_checkpoint(directory, 0, tree, extra)


def load_adapt_delta(directory: str | Path, base: Deployment, *,
                     expect_uid: int | None = None) -> dict:
    """Load a delta checkpoint and validate it against ``base``.

    Raises ``ValueError`` when the checkpoint is not a delta, the stamped
    base digest does not match ``base`` (tampered extras, or a delta
    learned against different weights/config), the delta shape is wrong,
    or ``expect_uid`` (e.g. the uid of the CURRENT registration of the
    base name) disagrees with the stamped uid — the stale-base guard
    against applying deltas across a hot-swap."""
    tree, extra = store.load_checkpoint(directory)
    if extra.get("delta_schema") != ADAPT_DELTA_SCHEMA:
        raise ValueError(
            f"{directory} is not an adaptation delta checkpoint "
            f"(extra.delta_schema={extra.get('delta_schema')!r}; "
            f"expected {ADAPT_DELTA_SCHEMA!r})")
    stamped = extra.get("base") or {}
    missing = [k for k in ("name", "uid", "digest") if k not in stamped]
    if missing:
        raise ValueError(f"{directory} delta checkpoint base stamp is "
                         f"corrupt: missing {missing}")
    digest = deployment_digest(base)
    if stamped["digest"] != digest:
        raise ValueError(
            f"{directory} delta was learned against base digest "
            f"{stamped['digest']} but the offered deployment digests to "
            f"{digest} — applying it would adapt the wrong weights")
    if expect_uid is not None and int(stamped["uid"]) != int(expect_uid):
        raise ValueError(
            f"{directory} delta is stamped for base uid {stamped['uid']} "
            f"but the live registration is uid {expect_uid} — the base "
            f"entry was hot-swapped since this delta was harvested")
    dw = np.asarray(tree["dw"], np.float32)
    w_q = p2m_layer.effective_weights(base.params["p2m"],
                                      base.model_cfg.p2m)
    if dw.shape != tuple(w_q.shape):
        raise ValueError(
            f"{directory} delta dw shape {dw.shape} does not match the "
            f"base's layer-1 weights {tuple(w_q.shape)}")
    return {"dw": dw, "dtheta": float(tree["dtheta"]),
            "base_name": stamped["name"], "base_uid": int(stamped["uid"]),
            "lane": int(extra.get("lane", 0)),
            "n_updates": int(extra.get("n_updates", 0)),
            "rule": extra.get("rule"), "meta": dict(extra.get("meta") or {})}


def apply_adapt_delta(base: Deployment, delta: dict, *,
                      label_suffix: str = "+adapt") -> Deployment:
    """Fold a (validated) delta into ``base`` → a new servable
    :class:`Deployment` that computes exactly what the adapted lane was
    serving: raw layer-1 weights ``w_q_base + dw`` (whose quantization
    reproduces the lane's effective weights — the quantizer is
    idempotent on grid points and ``dw`` is clipped well inside the clip
    range) and comparator threshold ``theta_base + dtheta`` pinned as
    the leak-config override. The compat key is unchanged (leak and
    threshold are excluded from it), so the result registers beside its
    base in the same registry and re-serves from the same engine."""
    cfg = base.model_cfg
    w_q = p2m_layer.effective_weights(base.params["p2m"], cfg.p2m)
    new_theta = float(base.coeffs.v_threshold) + float(delta["dtheta"])
    model_cfg = replace(cfg, p2m=replace(
        cfg.p2m, leak=replace(cfg.p2m.leak, v_threshold=new_theta)))
    variant = dict(base.record.get("variant") or {})
    if "v_threshold" in variant:
        variant["v_threshold"] = new_theta
    record = {
        **base.record,
        "label": f"{base.record.get('label')}{label_suffix}",
        "variant": variant,
        "adapted": {"base_name": delta.get("base_name", "default"),
                    "base_uid": int(delta.get("base_uid", 0)),
                    "lane": int(delta.get("lane", 0)),
                    "n_updates": int(delta.get("n_updates", 0)),
                    "rule": delta.get("rule"),
                    "dw_norm": float(np.linalg.norm(delta["dw"]))},
    }
    params = {"p2m": {**base.params["p2m"],
                      "w": jnp.asarray(w_q) + jnp.asarray(delta["dw"])},
              "backbone": base.params["backbone"]}
    return Deployment(model_cfg=model_cfg, params=params,
                      bn_state=base.bn_state, record=record,
                      protocol=base.protocol, meta=dict(base.meta))


# ---------------------------------------------------------------------------
# one-call train → artifact + checkpoints (smoke CLI / tests)
# ---------------------------------------------------------------------------

def train_and_deploy(out_dir: str | Path, *, dataset: str = "synthetic-gesture",
                     data_root: str | None = None, hw: int = 16,
                     protocols: tuple[str, ...] = ("frozen",),
                     t_intg_grid_ms: tuple[float, ...] | None = None,
                     circuits: tuple[CircuitConfig, ...] | None = None,
                     smoke: bool = False,
                     deploy_t_intg_ms: float | None = None,
                     log: Any = print) -> dict:
    """Run a (fast-grid) co-design sweep with ``keep_params=True``, write
    the sweep artifact, and deploy the best record per protocol as a
    serving checkpoint. Returns ``{"artifact": path, "checkpoints":
    {protocol: ckpt dir}, "records": {protocol: record}, "results":
    {protocol: GridResult}, "source": train EventSource}``.

    ``smoke`` shrinks the step counts to CI scale;
    ``deploy_t_intg_ms`` pins the deployed record's integration time
    (default: best accuracy anywhere on the grid).
    """
    from repro.core import sweep as engine
    from repro.data import sources as sources_mod

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    data, model, sweep_cfg, grid = engine.paper_setup(
        fast=True, hw=hw, dataset=dataset, data_root=data_root)
    if smoke:
        sweep_cfg = replace(sweep_cfg, batch_size=2, pretrain_steps=2,
                            finetune_steps=1, eval_batches=1)
    if t_intg_grid_ms is not None:
        ok = set(engine.fit_t_grid(t_intg_grid_ms, data.duration_ms,
                                   model.coarse_window_ms))
        bad = [t for t in t_intg_grid_ms if t not in ok]
        if bad:
            raise ValueError(
                f"T_INTG values {bad} do not divide the coarse window "
                f"({model.coarse_window_ms:g} ms) and stream duration "
                f"({data.duration_ms:g} ms)")
        grid = replace(grid, t_intg_grid_ms=tuple(t_intg_grid_ms))
    if circuits is not None:
        grid = replace(grid, circuits=tuple(circuits))
    eval_data, eval_split = sources_mod.resolve_eval_dataset(
        dataset, hw=hw, data_root=data_root)
    results = engine.run_protocols(data, model, sweep_cfg, grid,
                                   protocols=protocols, log=log,
                                   eval_data=eval_data, keep_params=True)
    artifact = engine.protocols_artifact(results, extra_meta={
        "data": {"name": data.name, "dataset": dataset,
                 "data_root": data_root, "hw": data.height,
                 "n_classes": data.n_classes,
                 "duration_ms": data.duration_ms,
                 "eval_split": eval_split}})
    artifact_path = out / "codesign_grid_deploy.json"
    artifact_path.write_text(json.dumps(artifact, indent=2, default=float))
    checkpoints: dict[str, Path] = {}
    chosen: dict[str, dict] = {}
    for proto, result in results.items():
        rec = select_record(result.records, t_intg_ms=deploy_t_intg_ms)
        ckpt_dir = out / f"ckpt_{proto}"
        deploy_from_sweep(result, model, rec, ckpt_dir,
                          meta={"dataset": dataset,
                                "sensor_hw": list(data.sensor_hw)})
        checkpoints[proto] = ckpt_dir
        chosen[proto] = rec
        log(f"[deploy] {proto}: {rec['label']} @ T={rec['t_intg_ms']:g}ms "
            f"acc={rec['accuracy']:.3f} -> {ckpt_dir}")
    return {"artifact": artifact_path, "checkpoints": checkpoints,
            "records": chosen, "results": results, "source": data}
