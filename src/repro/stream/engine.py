"""Online event-stream serving with continuous batching.

The missing half of the offline reproduction: the sweep engine measures
circuit variants in batch; this engine SERVES one deployed variant
(repro.stream.deploy) against many concurrent live event streams.

Lifecycle of one stream (see docs/streaming.md):

  1. the replay layer (``EventSource.iter_event_chunks``) turns one
     labeled recording — AEDAT / N-MNIST file or the synthetic generator
     — into timestamped raw ``(t, x, y, p)`` chunks;
  2. ``refill`` admits the stream into a free lane of the shared
     :class:`~repro.serve.slots.SlotManager` at a T_INTG window boundary
     (the lane's charge/membrane state is zeroed — precharge);
  3. every replay tick, each occupied lane's next chunk is binned onto
     the fine sub-slot grid (repro.data.binning semantics, sensor →
     model downscale included) and ONE jitted lane-batched ``fold``
     advances every lane's leak ODE + conv deposit together;
  4. at each T_INTG boundary one jitted ``readout`` comparator-reads
     every lane, accumulates pooled spikes toward the backbone coarse
     grid, and — per lane, whenever ITS coarse window completes — steps
     the stateful spiking backbone and the rate-decoded logit average;
  5. after the stream's full duration the lane's prediction is
     finalized, the slot is released, and the queue refills it.

All lanes advance on one shared replay clock (micro-batching), but
admission/finalization are per-lane — classic continuous batching, the
same ``SlotManager`` contract the LM decode server uses.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.binning import bin_chunks, slot_us_for
from repro.data.formats import EventChunk
from repro.data.sources import EventSource
from repro.serve.slots import SlotManager
from repro.stream.accumulator import make_stream_fns
from repro.stream.deploy import Deployment

STATS_SCHEMA = "p2m-stream-serving/v1"


@dataclass
class StreamResult:
    """Per-stream serving outcome."""
    stream_id: int
    label: int
    prediction: int
    correct: bool
    n_events: int
    n_readouts: int
    n_coarse_frames: int
    admitted_window: int      # global window tick the stream was admitted
    finished_window: int
    logits: list[float] = field(default_factory=list)  # rate-decoded mean


@dataclass
class _Lane:
    """Host-side state of one admitted stream."""
    stream_id: int
    label: int
    chunks: Iterator[EventChunk]
    n_windows: int
    admitted_window: int
    windows_done: int = 0
    n_events: int = 0
    t_cursor_us: int = 0


@dataclass
class ServingReport:
    """Everything one serve() run produced; ``to_artifact()`` is the
    serving-stats JSON the CLI emits and CI schema-checks."""
    results: list[StreamResult]
    deployed: dict
    capacity: int
    chunks_per_window: int
    t_intg_ms: float
    wall_s: float
    total_events: int
    total_readouts: int
    total_layer1_spikes: float
    readout_s: list[float] = field(default_factory=list)
    fold_s: list[float] = field(default_factory=list)

    @property
    def accuracy(self) -> float:
        if not self.results:
            return 0.0
        return sum(r.correct for r in self.results) / len(self.results)

    def to_artifact(self) -> dict:
        lat = lambda xs, q: (float(np.percentile(xs, q) * 1e3)  # noqa: E731
                             if xs else 0.0)
        wall = max(self.wall_s, 1e-9)
        return {
            "schema": STATS_SCHEMA,
            "deployed": self.deployed,
            "n_streams": len(self.results),
            "capacity": self.capacity,
            "chunks_per_window": self.chunks_per_window,
            "t_intg_ms": self.t_intg_ms,
            "accuracy": self.accuracy,
            "streams": [asdict(r) for r in self.results],
            "latency_ms": {
                "readout_p50": lat(self.readout_s, 50),
                "readout_p99": lat(self.readout_s, 99),
                "readout_mean": (float(np.mean(self.readout_s) * 1e3)
                                 if self.readout_s else 0.0),
                "fold_p50": lat(self.fold_s, 50),
                "fold_p99": lat(self.fold_s, 99),
            },
            "throughput": {
                "wall_s": self.wall_s,
                "events_per_s": self.total_events / wall,
                "readouts_per_s": self.total_readouts / wall,
                "streams_per_s": len(self.results) / wall,
                "layer1_spikes_per_s": self.total_layer1_spikes / wall,
            },
        }


class StreamEngine:
    """Continuous-batching online inference over one deployment.

    ``capacity`` is the fixed lane count of the jitted steps (the decode
    batch of LM serving); ``chunks_per_window`` sets the replay
    granularity — how many raw-event chunks arrive per T_INTG window
    (must divide ``n_sub``; default: one chunk per fine sub-slot, the
    finest arrival granularity the binned contract expresses).
    ``use_kernel=True`` folds each chunk's sub-slots through the fused
    Pallas stream_fold kernel instead of the XLA scan (bit-exact either
    way — tests/test_stream_fold.py pins it).
    """

    def __init__(self, dep: Deployment, *, capacity: int = 4,
                 chunks_per_window: int | None = None,
                 use_kernel: bool = False):
        cfg = dep.model_cfg.p2m
        self.dep = dep
        self.capacity = capacity
        self.n_sub = cfg.n_sub
        self.chunks_per_window = (self.n_sub if chunks_per_window is None
                                  else chunks_per_window)
        if self.n_sub % self.chunks_per_window:
            raise ValueError(
                f"chunks_per_window={self.chunks_per_window} must divide "
                f"n_sub={self.n_sub}")
        self.chunk_slots = self.n_sub // self.chunks_per_window
        self.slot_us = slot_us_for(cfg.t_intg_ms, cfg.n_sub)
        self.chunk_us = self.slot_us * self.chunk_slots
        self.group = dep.model_cfg.coarsen_group()
        self.use_kernel = use_kernel
        self.fns = make_stream_fns(dep, capacity=capacity,
                                   chunk_slots=self.chunk_slots,
                                   use_kernel=use_kernel)

    # ------------------------------------------------------------------
    def open_stream(self, source: EventSource, key: jax.Array,
                    stream_id: int, window: int) -> _Lane:
        """Admission-ready lane record for one replayed sample."""
        h, w = self.fns.in_hw
        if (source.height, source.width) != (h, w):
            raise ValueError(
                f"source resolution {(source.height, source.width)} does "
                f"not match the deployed model's input {(h, w)}")
        if source.n_classes > self.fns.n_classes:
            raise ValueError(
                f"source has {source.n_classes} classes but the deployed "
                f"head predicts {self.fns.n_classes} — labels past the "
                f"head are unservable")
        n_windows = source.n_slots(self.dep.t_intg_ms)
        if n_windows % self.group:
            raise ValueError(
                f"stream duration {source.duration_ms:g} ms yields "
                f"{n_windows} T_INTG windows, not a multiple of the "
                f"deployed coarse group {self.group} "
                f"(coarse_window_ms={self.dep.model_cfg.coarse_window_ms:g})"
                f" — the backbone would never step; deploy a record whose "
                f"coarse window fits the stream")
        label, chunks = source.iter_event_chunks(
            key, chunk_us=self.chunk_us, slot_us=self.slot_us)
        return _Lane(stream_id=stream_id, label=label, chunks=chunks,
                     n_windows=n_windows, admitted_window=window)

    def _bin_chunk(self, source: EventSource, lane: _Lane) -> np.ndarray:
        """Next replay chunk of ``lane`` → fine sub-slot frames
        [chunk_slots, H, W, 2] (offline-binner semantics: same slot grid,
        same sensor → model downscale)."""
        chunk = next(lane.chunks)
        lane.n_events += len(chunk)
        frames = bin_chunks([chunk], n_total=self.chunk_slots,
                            slot_us=self.slot_us,
                            sensor_hw=source.sensor_hw,
                            out_hw=self.fns.in_hw,
                            t0_us=lane.t_cursor_us)
        lane.t_cursor_us += self.chunk_us
        return frames

    # ------------------------------------------------------------------
    def serve(self, source: EventSource, n_streams: int, *, seed: int = 0,
              log=None) -> ServingReport:
        """Serve ``n_streams`` replayed samples of ``source`` to
        completion and return the serving report."""
        key = jax.random.PRNGKey(seed)
        queue = [self.open_stream(source, jax.random.fold_in(key, i), i, 0)
                 for i in range(n_streams)]
        slots: SlotManager[_Lane] = SlotManager(self.capacity)
        state = self.fns.init_state()
        results: list[StreamResult] = []
        report = ServingReport(
            results=results, deployed=self.dep.deployed_meta(),
            capacity=self.capacity,
            chunks_per_window=self.chunks_per_window,
            t_intg_ms=self.dep.t_intg_ms, wall_s=0.0, total_events=0,
            total_readouts=0, total_layer1_spikes=0.0)
        h, w = self.fns.in_hw
        # warmup: compile fold/readout on a throwaway state so the
        # latency percentiles measure steady-state serving, not jit
        ws = self.fns.fold(self.fns.init_state(),
                           jnp.zeros((self.capacity, self.chunk_slots,
                                      h, w, 2)),
                           jnp.zeros((self.capacity,), bool))
        ws, _ = self.fns.readout(ws, jnp.zeros((self.capacity,), bool),
                                 jnp.zeros((self.capacity,), bool))
        jax.block_until_ready(ws["logits"])
        window = 0
        t_start = time.perf_counter()
        while queue or not slots.is_empty():
            # admit pending streams into free lanes (window boundary)
            for lane_i, lane in slots.refill(queue):
                lane.admitted_window = window
                state = self.fns.reset_lane(state, lane_i)
            active = jnp.asarray(slots.active_mask())
            # one T_INTG window = chunks_per_window replay ticks
            for _ in range(self.chunks_per_window):
                frames = np.zeros(
                    (self.capacity, self.chunk_slots, h, w, 2), np.float32)
                for lane_i, lane in slots.occupied():
                    frames[lane_i] = self._bin_chunk(source, lane)
                t0 = time.perf_counter()
                state = self.fns.fold(state, jnp.asarray(frames), active)
                jax.block_until_ready(state["x"])
                report.fold_s.append(time.perf_counter() - t0)
            # readout at the T_INTG boundary; per-lane coarse boundaries
            coarse_mask = np.zeros((self.capacity,), bool)
            for lane_i, lane in slots.occupied():
                coarse_mask[lane_i] = \
                    (lane.windows_done + 1) % self.group == 0
            t0 = time.perf_counter()
            state, out = self.fns.readout(state, active,
                                          jnp.asarray(coarse_mask))
            jax.block_until_ready(state["logits"])
            report.readout_s.append(time.perf_counter() - t0)
            n_spikes = np.asarray(out["n_spikes"])
            window += 1
            for lane_i, lane in list(slots.occupied()):
                lane.windows_done += 1
                report.total_readouts += 1
                report.total_layer1_spikes += float(n_spikes[lane_i])
                if lane.windows_done < lane.n_windows:
                    continue
                # stream complete: finalize the rate-decoded prediction
                n_c = int(state["n_coarse"][lane_i])
                logits = np.asarray(state["logits"][lane_i]) / max(n_c, 1)
                pred = int(np.argmax(logits))
                report.total_events += lane.n_events
                results.append(StreamResult(
                    stream_id=lane.stream_id, label=lane.label,
                    prediction=pred, correct=pred == lane.label,
                    n_events=lane.n_events,
                    n_readouts=lane.windows_done, n_coarse_frames=n_c,
                    admitted_window=lane.admitted_window,
                    finished_window=window,
                    logits=[float(v) for v in logits]))
                slots.release(lane_i)
                if log is not None:
                    log(f"[stream {lane.stream_id}] label={lane.label} "
                        f"pred={pred} readouts={lane.windows_done} "
                        f"events={lane.n_events}")
        report.wall_s = time.perf_counter() - t_start
        return report
