"""Online event-stream serving with continuous batching, admission
control, and (optionally) paced real-time replay.

The missing half of the offline reproduction: the sweep engine measures
circuit variants in batch; this engine SERVES one deployed variant
(repro.stream.deploy) against many concurrent live event streams.

Lifecycle of one stream (see docs/streaming.md):

  1. the stream is OFFERED (all at once, or trickled at
     ``offered_rate`` streams/s on the replay clock) and enters the
     bounded pending queue — or is SHED when the queue is full
     (backpressure: offered load beyond ``capacity + max_pending`` is
     rejected, not buffered without bound);
  2. when a lane of the shared :class:`~repro.serve.slots.SlotManager`
     frees up at a T_INTG window boundary, the stream is ADMITTED: only
     now is its replay iterator opened
     (``EventSource.iter_event_chunks`` — AEDAT / N-MNIST file or the
     synthetic generator, replayed as timestamped raw ``(t, x, y, p)``
     chunks) and the lane's charge/membrane state zeroed (precharge) —
     resident iterators never exceed the lane capacity;
  3. every replay tick, each occupied lane's next chunk is binned onto
     the fine sub-slot grid (repro.data.binning semantics, sensor →
     model downscale included) by a host-side worker thread that runs
     one chunk ahead of the device, and ONE jitted lane-batched ``fold``
     advances every lane's leak ODE + conv deposit together — no
     per-tick host sync; the window's only sync point is its readout;
  4. at each T_INTG boundary one jitted ``readout`` comparator-reads
     every lane, accumulates pooled spikes toward the backbone coarse
     grid, and — per lane, whenever ITS coarse window completes — steps
     the stateful spiking backbone and the rate-decoded logit average;
  5. after the stream's full duration the lane's prediction is
     finalized, the slot is released, and the pending queue refills it.

All lanes advance on one shared replay clock (micro-batching), but
admission/finalization are per-lane — classic continuous batching, the
same ``SlotManager`` contract the LM decode server uses.

**Paced mode** (``serve(..., paced=True)``) turns the replayer into a
real-time server: the scheduler holds window ``k`` until wall clock
``t_admit + k·t_intg`` and records a *deadline miss* whenever a readout
completes after its boundary ``t_admit + (k+1)·t_intg`` — in a physical
P²M sensor the passive capacitor's charge-retention bounds T_INTG, so a
late readout reads leaked charge; it is a correctness event, not just a
latency sample. Predictions are bit-identical to unpaced replay on the
same seed (pacing only inserts sleeps); per-lane and fleet-wide miss
counters plus the miss-margin histogram land in the
``p2m-stream-serving/v5`` stats artifact.

**Registry mode** (``StreamEngine(Registry(...))``,
repro.stream.registry) serves a CATALOG of circuit variants from one
lane table: streams request a variant at offer time, admission binds
each lane to a registry entry (rejecting unresolvable requests), and
``register``/``retire`` hot-swap entries mid-serve without perturbing
lanes bound to other entries. The v4 artifact adds the ``registry``
block (compat digest + per-entry admitted/finished/miss/throughput
rows) and ``admission.n_rejected``.

**Adaptation mode** (``StreamEngine(..., adapt=AdaptConfig(...))``,
repro.stream.adapt) turns on per-lane online plasticity: each lane
carries persistent weight/threshold deltas that a local
surrogate-gradient or reward-modulated rule updates at every labeled
coarse-window readout, compensating per-device leak drift in place.
The deltas survive stream turnover on a lane, reset when the lane
rebinds to a different registry entry uid, and are harvested via
:meth:`StreamEngine.harvest` into validated delta checkpoints
(repro.stream.deploy.save_adapt_delta) that re-register as new entries.
``adapt=None`` (the default) compiles none of this — frozen serving is
IEEE-bit-identical to the adaptation-less engine — and the v5 artifact
carries the ``adaptation`` block (rule, per-lane update counts and
delta norms, pre/post-accuracy split) either way.

**Sharded mode** (``StreamEngine(executor=LaneExecutor(devices=n))``,
CLI ``--devices``) maps the lane axis onto a 1-D ``"lane"`` device mesh
(repro.stream.shard): the capacity pads up to a device multiple, each
device folds/reads out its contiguous lane block under ``shard_map``,
and per-shard :class:`~repro.serve.slots.ShardedSlots` bookkeeping sits
behind the SAME single admission front — one bounded pending deque feeds
a lane freed on any shard. Host binning scales with it: ``bin_workers``
:class:`_BinWorker` threads each own a disjoint slice of the lane axis
(aligned with the mesh shards when ``bin_workers == devices``) and bin
their lanes one chunk ahead of the device — the multi-worker attack on
the host-bound saturation knee. Sharded serving, any worker count, and
``prefetch=False`` (the bit-identical inline oracle) all produce
bit-for-bit identical predictions and ledgers to the ``devices=1``
single-worker path.
"""
from __future__ import annotations

import math
import queue as queue_mod
import threading
import time
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.binning import bin_chunks, slot_us_for
from repro.data.formats import EventChunk
from repro.data.sources import EventSource
from repro.serve.slots import ShardedSlots
from repro.stream.accumulator import (entry_numerics, make_multi_stream_fns,
                                      make_stream_fns, stack_entries)
from repro.stream.adapt import (AdaptConfig, adapt_entry_numerics,
                                lane_stats, make_adapt_fns)
from repro.stream.deploy import Deployment
from repro.stream.registry import (Registry, RegistryEntry, compat_digest,
                                   compat_key)
from repro.stream.shard import LaneExecutor

STATS_SCHEMA = "p2m-stream-serving/v5"


class EntryTableFull(RuntimeError):
    """The engine's fixed-size per-entry param table has no reclaimable
    slot for a newly requested registry entry (every slot still has lanes
    bound to it). Admission REJECTS the stream; raise ``max_entries`` to
    co-serve more simultaneous variants."""


@dataclass
class StreamResult:
    """Per-stream serving outcome."""
    stream_id: int
    label: int
    prediction: int
    correct: bool
    n_events: int
    n_readouts: int
    n_coarse_frames: int
    offered_window: int       # global window tick the stream was offered
    admitted_window: int      # global window tick the stream was admitted
    finished_window: int
    n_misses: int = 0         # paced mode: readouts past their deadline
    # worst (largest) miss margin over the stream's readouts, ms;
    # negative = every readout beat its deadline; None = unpaced run
    miss_margin_max_ms: float | None = None
    # registry entry the lane was bound to at admission ("default" on a
    # single-deployment engine); uid disambiguates across hot-swaps
    entry: str = "default"
    entry_uid: int = 0
    logits: list[float] = field(default_factory=list)  # rate-decoded mean


@dataclass
class _Lane:
    """Host-side state of one admitted stream."""
    stream_id: int
    label: int
    chunks: Iterator[EventChunk]
    n_windows: int
    offered_window: int = 0
    admitted_window: int = 0
    windows_done: int = 0
    n_events: int = 0
    t_cursor_us: int = 0
    n_misses: int = 0
    worst_margin_ms: float | None = None
    entry_name: str = "default"   # registry entry bound at admission
    entry_uid: int = 0
    entry_slot: int = 0           # engine param-table slot of that entry


class _BinWorker:
    """Single host-side worker thread binning replay chunks ahead of the
    device fold (async host binning: while the device folds chunk ``c``,
    the worker bins chunk ``c+1``). Jobs are executed strictly in
    submission order — a lane's replay iterator is only ever advanced on
    the ONE worker that owns that lane, so chunk order per lane is
    preserved. Exceptions propagate to the consumer at ``get()``."""

    _STOP = object()

    def __init__(self, index: int = 0):
        self._tasks: queue_mod.Queue = queue_mod.Queue()
        self._results: queue_mod.Queue = queue_mod.Queue()
        self._thread = threading.Thread(
            target=self._run, name=f"stream-bin-worker-{index}",
            daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            job = self._tasks.get()
            if job is self._STOP:
                return
            try:
                self._results.put((job(), None))
            except BaseException as e:  # surfaced at get()
                self._results.put((None, e))

    def submit(self, job) -> None:
        self._tasks.put(job)

    def get(self):
        frames, err = self._results.get()
        if err is not None:
            raise err
        return frames

    def close(self) -> None:
        """Drain-and-join: cancel every not-yet-started job, stop the
        thread, and drop queued results. On the serve loop's exception
        path this releases the job closures' references to live replay
        iterators instead of leaking them to a parked daemon thread."""
        try:
            while True:
                self._tasks.get_nowait()
        except queue_mod.Empty:
            pass
        self._tasks.put(self._STOP)
        self._thread.join(timeout=10)
        try:
            while True:
                self._results.get_nowait()
        except queue_mod.Empty:
            pass

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()


class _BinPool:
    """Fixed pool of :class:`_BinWorker` threads, one per lane partition
    (the engine assigns each worker a contiguous slice of the lane axis —
    mesh-shard-aligned when ``bin_workers == devices``). The consumer
    submits one job per worker per replay tick and gathers them in worker
    order, so assembly — and therefore the folded frames — is
    deterministic for any worker count."""

    def __init__(self, n: int):
        self.workers = [_BinWorker(i) for i in range(n)]

    def submit(self, worker: int, job) -> None:
        self.workers[worker].submit(job)

    def get(self, worker: int):
        return self.workers[worker].get()

    def close(self) -> None:
        for w in self.workers:
            w.close()

    @property
    def any_alive(self) -> bool:
        return any(w.alive for w in self.workers)


@dataclass
class ServingReport:
    """Everything one serve() run produced; ``to_artifact()`` is the
    serving-stats JSON the CLI emits and CI schema-checks."""
    results: list[StreamResult]
    deployed: dict
    capacity: int
    chunks_per_window: int
    t_intg_ms: float
    wall_s: float
    total_events: int
    total_readouts: int
    total_layer1_spikes: float
    paced: bool = False
    offered_rate: float | None = None
    max_pending: int | None = None
    devices: int = 1              # lane-mesh shards (1 = unsharded)
    bin_workers: int = 1          # host binning worker threads
    padded_capacity: int = 0      # lane axis after mesh padding
    lanes_per_shard: int = 0
    per_shard_admitted: list[int] = field(default_factory=list)
    n_offered: int = 0
    n_admitted: int = 0
    n_shed: int = 0               # rejected: pending queue was full
    # rejected at admission: variant request unresolvable (no match,
    # ambiguous, incompatible compat key, or entry table full)
    n_rejected: int = 0
    n_deferred: int = 0           # admitted later than their offer window
    # registry view: compat digest of the serving geometry, param-table
    # size, and one per-entry counter row per (name, uid) ever admitted
    registry_compat: str = ""
    registry_max_entries: int = 1
    entry_rows: list[dict] = field(default_factory=list)
    max_open_streams: int = 0     # peak concurrently-open replay iterators
    # adaptation view (None = engine served frozen): rule, cumulative
    # update count, per-lane delta rows, pre/post accuracy split
    adaptation: dict | None = None
    n_misses: int = 0             # fleet-wide deadline misses (paced)
    # one margin per (occupied lane, window) readout in paced mode:
    # readout completion − deadline, ms (positive = missed)
    miss_margin_ms: list[float] = field(default_factory=list)
    readout_s: list[float] = field(default_factory=list)
    fold_s: list[float] = field(default_factory=list)

    @property
    def accuracy(self) -> float:
        if not self.results:
            return 0.0
        return sum(r.correct for r in self.results) / len(self.results)

    @property
    def miss_rate(self) -> float:
        n = len(self.miss_margin_ms)
        return self.n_misses / n if n else 0.0

    def deadline_stats(self) -> dict:
        """Fleet-wide deadline accounting: counters, miss-margin
        percentiles, and a coarse margin histogram (empty on unpaced
        runs, where no readout carries a deadline)."""
        m = np.asarray(self.miss_margin_ms, dtype=float)
        if m.size:
            pct = {q: float(np.percentile(m, int(q[1:])))
                   for q in ("p50", "p90", "p99")}
            pct["max"] = float(m.max())
            counts, edges = np.histogram(m, bins=8)
            hist = {"edges_ms": [float(e) for e in edges],
                    "counts": [int(c) for c in counts]}
        else:
            pct = {"p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}
            hist = {"edges_ms": [], "counts": []}
        return {"n_deadlines": int(m.size), "n_misses": self.n_misses,
                "miss_rate": self.miss_rate, "margin_ms": pct,
                "histogram": hist}

    def to_artifact(self) -> dict:
        lat = lambda xs, q: (float(np.percentile(xs, q) * 1e3)  # noqa: E731
                             if xs else 0.0)
        wall = max(self.wall_s, 1e-9)
        return {
            "schema": STATS_SCHEMA,
            "deployed": self.deployed,
            "n_streams": len(self.results),
            "capacity": self.capacity,
            "chunks_per_window": self.chunks_per_window,
            "t_intg_ms": self.t_intg_ms,
            "accuracy": self.accuracy,
            "paced": self.paced,
            "sharding": {
                "devices": self.devices,
                "bin_workers": self.bin_workers,
                "padded_capacity": self.padded_capacity,
                "lanes_per_shard": self.lanes_per_shard,
                "per_shard_admitted": list(self.per_shard_admitted),
            },
            "admission": {
                "offered_rate": self.offered_rate,
                "max_pending": self.max_pending,
                "n_offered": self.n_offered,
                "n_admitted": self.n_admitted,
                "n_shed": self.n_shed,
                "n_rejected": self.n_rejected,
                "n_deferred": self.n_deferred,
                "max_open_streams": self.max_open_streams,
            },
            "registry": {
                "compat": self.registry_compat,
                "max_entries": self.registry_max_entries,
                "entries": [
                    {**row,
                     "accuracy": (row["n_correct"] / row["n_finished"]
                                  if row["n_finished"] else 0.0),
                     "events_per_s": row["n_events"] / wall}
                    for row in self.entry_rows
                ],
            },
            "adaptation": (self.adaptation if self.adaptation is not None
                           else {"enabled": False, "rule": None,
                                 "lr_w": 0.0, "lr_theta": 0.0,
                                 "n_updates": 0, "accuracy_pre": None,
                                 "accuracy_post": None, "lanes": []}),
            "deadlines": self.deadline_stats(),
            "streams": [asdict(r) for r in self.results],
            "latency_ms": {
                "readout_p50": lat(self.readout_s, 50),
                "readout_p99": lat(self.readout_s, 99),
                "readout_mean": (float(np.mean(self.readout_s) * 1e3)
                                 if self.readout_s else 0.0),
                "fold_p50": lat(self.fold_s, 50),
                "fold_p99": lat(self.fold_s, 99),
            },
            "throughput": {
                "wall_s": self.wall_s,
                "events_per_s": self.total_events / wall,
                # the fleet-scale metric: what ONE device of the lane
                # mesh sustains (events_per_s / devices)
                "events_per_s_per_device": (self.total_events / wall
                                            / max(self.devices, 1)),
                "readouts_per_s": self.total_readouts / wall,
                "streams_per_s": len(self.results) / wall,
                "layer1_spikes_per_s": self.total_layer1_spikes / wall,
            },
        }


class StreamEngine:
    """Continuous-batching online inference over one deployment — or,
    given a :class:`~repro.stream.registry.Registry`, over a CATALOG of
    compat-equal deployments with per-stream variant selection.

    **Registry mode** (``StreamEngine(registry, ...)``): the first
    registered entry anchors the shared serving geometry (compat key);
    per-lane numerics live in a fixed-size param table of ``max_entries``
    slots whose stacked bundle is an *argument* of the jitted
    multi-variant fold/readout (repro.stream.accumulator
    .make_multi_stream_fns) — so ``register``/``retire`` on the live
    registry (hot-swap) re-stacks the bundle without recompiling and
    without perturbing lanes bound to other entries. Admission resolves
    each stream's variant request (``serve(..., variants=...)``) against
    the registry (:meth:`Registry.resolve`); unresolvable requests (no
    match / ambiguous / wrong compat / table full) REJECT the stream
    (``n_rejected``) instead of guessing. A retired entry's params stay
    in their table slot until the last lane bound to it releases, so
    in-flight streams finish on the exact weights they were admitted
    with. Mixed-variant serving is bit-identical per stream to
    single-variant serving (tests/test_registry.py).

    ``capacity`` is the fixed lane count of the jitted steps (the decode
    batch of LM serving); ``chunks_per_window`` sets the replay
    granularity — how many raw-event chunks arrive per T_INTG window
    (must divide ``n_sub``; default: one chunk per fine sub-slot, the
    finest arrival granularity the binned contract expresses).
    ``use_kernel=True`` folds each chunk's sub-slots through the fused
    Pallas stream_fold kernel instead of the XLA scan (bit-exact either
    way — tests/test_stream_fold.py pins it). ``prefetch=False`` turns
    off the async host-binning workers and bins chunks inline on the
    serving thread (debug aid; the folded numbers are identical).

    ``executor`` (repro.stream.shard.LaneExecutor) shards the lane axis
    over a 1-D ``"lane"`` device mesh: the capacity pads up to a multiple
    of ``executor.devices`` (padding lanes are never admitted) and the
    jitted steps run under ``shard_map`` — bit-for-bit identical to the
    default single-device executor. ``bin_workers`` sets the host binning
    pool width (default: one worker per mesh shard, so ``devices=1``
    keeps the single-worker pipeline); each worker owns a fixed disjoint
    slice of the lane axis, which keeps per-lane chunk order — and the
    binned frames — deterministic for any worker count.
    """

    def __init__(self, dep: "Deployment | Registry", *, capacity: int = 4,
                 chunks_per_window: int | None = None,
                 use_kernel: bool = False, prefetch: bool = True,
                 executor: LaneExecutor | None = None,
                 bin_workers: int | None = None,
                 max_entries: int | None = None,
                 default_entry: str | None = None,
                 adapt: AdaptConfig | None = None):
        if isinstance(dep, Registry):
            if len(dep) == 0:
                raise ValueError(
                    "registry is empty — register at least one entry "
                    "before building a serving engine")
            self.registry: Registry | None = dep
            anchor = next(dep.entries())
            self.compat = anchor.compat
            self.dep = anchor.dep
            self.default_entry = default_entry
            self.max_entries = (max(len(dep) + 1, 2)
                                if max_entries is None else max_entries)
            if self.max_entries < len(dep):
                raise ValueError(
                    f"max_entries={self.max_entries} cannot hold the "
                    f"{len(dep)} already-registered entries")
        else:
            if max_entries is not None or default_entry is not None:
                raise ValueError("max_entries/default_entry require a "
                                 "registry-backed engine")
            self.registry = None
            self.dep = dep
            self.compat = compat_key(dep)
            self.default_entry = None
            self.max_entries = 1
        cfg = self.dep.model_cfg.p2m
        dep = self.dep
        self.capacity = capacity
        self.executor = executor or LaneExecutor()
        self.padded_capacity = self.executor.padded_size(capacity)
        self.lanes_per_shard = self.padded_capacity // self.executor.devices
        if bin_workers is not None and bin_workers < 1:
            raise ValueError(f"bin_workers must be >= 1, got {bin_workers}")
        self.bin_workers = (self.executor.devices if bin_workers is None
                            else bin_workers)
        self.n_sub = cfg.n_sub
        self.chunks_per_window = (self.n_sub if chunks_per_window is None
                                  else chunks_per_window)
        if self.n_sub % self.chunks_per_window:
            raise ValueError(
                f"chunks_per_window={self.chunks_per_window} must divide "
                f"n_sub={self.n_sub}")
        self.chunk_slots = self.n_sub // self.chunks_per_window
        self.slot_us = slot_us_for(cfg.t_intg_ms, cfg.n_sub)
        self.chunk_us = self.slot_us * self.chunk_slots
        self.group = dep.model_cfg.coarsen_group()
        self.use_kernel = use_kernel
        self.prefetch = prefetch
        self.adapt = adapt
        # adaptation re-linearizes the leak per lane at every readout,
        # so adapting engines carry each entry's LeakCoeffs in the
        # bundle (extra replicated scalars; frozen engines keep the
        # exact PR 9 bundle and compiled program)
        self._nb_fn = (adapt_entry_numerics if adapt is not None
                       else entry_numerics)
        if adapt is not None:
            self.fns = make_adapt_fns(
                dep, capacity=self.padded_capacity,
                chunk_slots=self.chunk_slots, adapt=adapt,
                use_kernel=use_kernel, executor=self.executor,
                registry=self.registry is not None)
            # per-lane deltas/traces, resident across serve() calls so
            # a lane keeps learning over stream turnover and harvest
            # works after the run
            self.adapt_state = self.fns.init_adapt()
            # entry uid each lane's deltas were learned against (-1 =
            # never admitted): rebinding to a different uid voids them
            self._lane_entry_uid = np.full((self.padded_capacity,), -1,
                                           np.int64)
            self._lane_base: list[Deployment | None] = \
                [None] * self.padded_capacity
            self._lane_base_name = ["default"] * self.padded_capacity
            self._labels = np.full((self.padded_capacity,), -1, np.int32)
        elif self.registry is not None:
            self.fns = make_multi_stream_fns(
                dep, capacity=self.padded_capacity,
                chunk_slots=self.chunk_slots, use_kernel=use_kernel,
                executor=self.executor)
        else:
            self.fns = make_stream_fns(dep, capacity=self.padded_capacity,
                                       chunk_slots=self.chunk_slots,
                                       use_kernel=use_kernel,
                                       executor=self.executor)
        if self.registry is not None:
            # fixed-size per-entry param table: slot i holds the numerics
            # of one (name, uid) registration; refcounts track how many
            # resident lanes are bound to it, so hot-swap keeps a retired
            # entry's weights until its last lane drains. Unused slots
            # hold the anchor's numerics as shape placeholders.
            anchor_nb = self._nb_fn(dep)
            self._entry_slots: list[tuple[str, int] | None] = \
                [None] * self.max_entries
            self._entry_refs = [0] * self.max_entries
            self._entry_nbs = [anchor_nb] * self.max_entries
            self._bundle = stack_entries(self._entry_nbs)
            self._entry_of = np.zeros((self.padded_capacity,), np.int32)

    # -- registry param-table bookkeeping ------------------------------
    def _slot_stale(self, slot: int) -> bool:
        """True when the table slot's (name, uid) is no longer live in
        the registry (retired, or the name was hot-swapped to a new
        uid) — reclaimable once its refcount hits zero."""
        assert self.registry is not None
        key = self._entry_slots[slot]
        if key is None:
            return True
        name, uid = key
        return name not in self.registry or self.registry.get(name).uid != uid

    def _bind_entry(self, entry: RegistryEntry) -> int:
        """Bind one more lane to ``entry``, installing its numerics into
        the param table on first use (re-stacking the device bundle —
        shapes unchanged, so no recompile). Raises :class:`EntryTableFull`
        when every slot still has lanes bound to it."""
        key = (entry.name, entry.uid)
        for i, k in enumerate(self._entry_slots):
            if k == key:
                self._entry_refs[i] += 1
                return i
        victim = None
        for i in range(self.max_entries):
            if self._entry_refs[i] == 0 and self._slot_stale(i):
                victim = i
                break
        if victim is None:  # evict a live-but-unused cached entry
            for i in range(self.max_entries):
                if self._entry_refs[i] == 0:
                    victim = i
                    break
        if victim is None:
            raise EntryTableFull(
                f"all {self.max_entries} entry slots have resident lanes "
                f"(bound: {[k for k in self._entry_slots if k]}) — raise "
                f"max_entries to co-serve more variants")
        self._entry_slots[victim] = key
        self._entry_nbs[victim] = self._nb_fn(entry.dep)
        self._entry_refs[victim] = 1
        self._bundle = stack_entries(self._entry_nbs)
        return victim

    def _unbind_entry(self, slot: int) -> None:
        assert self._entry_refs[slot] > 0
        self._entry_refs[slot] -= 1

    # ------------------------------------------------------------------
    def open_stream(self, source: EventSource, key: jax.Array,
                    stream_id: int) -> _Lane:
        """Open one replayed sample into an admission-ready lane record.

        Called at ADMISSION time, not at offer time: an open lane holds a
        live replay iterator (and, for file-backed sources, its buffers),
        so opening lazily bounds resident iterators by the lane capacity
        instead of the offered stream count. Admission time itself is
        stamped by ``serve`` when the lane is placed."""
        h, w = self.fns.in_hw
        if (source.height, source.width) != (h, w):
            raise ValueError(
                f"source resolution {(source.height, source.width)} does "
                f"not match the deployed model's input {(h, w)}")
        if source.n_classes > self.fns.n_classes:
            raise ValueError(
                f"source has {source.n_classes} classes but the deployed "
                f"head predicts {self.fns.n_classes} — labels past the "
                f"head are unservable")
        n_windows = source.n_slots(self.dep.t_intg_ms)
        if n_windows % self.group:
            raise ValueError(
                f"stream duration {source.duration_ms:g} ms yields "
                f"{n_windows} T_INTG windows, not a multiple of the "
                f"deployed coarse group {self.group} "
                f"(coarse_window_ms={self.dep.model_cfg.coarse_window_ms:g})"
                f" — the backbone would never step; deploy a record whose "
                f"coarse window fits the stream")
        label, chunks = source.iter_event_chunks(
            key, chunk_us=self.chunk_us, slot_us=self.slot_us)
        return _Lane(stream_id=stream_id, label=label, chunks=chunks,
                     n_windows=n_windows)

    def _bin_chunk(self, source: EventSource, lane: _Lane) -> np.ndarray:
        """Next replay chunk of ``lane`` → fine sub-slot frames
        [chunk_slots, H, W, 2] (offline-binner semantics: same slot grid,
        same sensor → model downscale)."""
        chunk = next(lane.chunks)
        lane.n_events += len(chunk)
        frames = bin_chunks([chunk], n_total=self.chunk_slots,
                            slot_us=self.slot_us,
                            sensor_hw=source.sensor_hw,
                            out_hw=self.fns.in_hw,
                            t0_us=lane.t_cursor_us)
        lane.t_cursor_us += self.chunk_us
        return frames

    def _worker_of(self, lane: int) -> int:
        """Owning bin worker of a global lane: contiguous balanced slices
        of the padded lane axis, exactly shard-aligned when
        ``bin_workers == devices`` (worker w bins mesh shard w). A lane
        is owned by ONE worker for its whole lifetime, so its replay
        iterator only ever advances on that worker's thread."""
        return lane * self.bin_workers // self.padded_capacity

    def _partition(self, occupied: list[tuple[int, _Lane]]
                   ) -> list[list[tuple[int, _Lane]]]:
        """Split the occupied lanes by owning bin worker."""
        parts: list[list[tuple[int, _Lane]]] = [
            [] for _ in range(self.bin_workers)]
        for lane_i, lane in occupied:
            parts[self._worker_of(lane_i)].append((lane_i, lane))
        return parts

    def _bin_part(self, source: EventSource,
                  lanes: list[tuple[int, _Lane]]
                  ) -> list[tuple[int, np.ndarray]]:
        """One worker's share of a replay tick: each owned occupied
        lane's next chunk, binned to [chunk_slots, H, W, 2]. Runs on the
        owning :class:`_BinWorker` thread when prefetching."""
        return [(lane_i, self._bin_chunk(source, lane))
                for lane_i, lane in lanes]

    def _assemble(self, parts: list[list[tuple[int, np.ndarray]]]
                  ) -> np.ndarray:
        """Workers' per-lane blocks → the fold's full
        [padded_capacity, chunk_slots, H, W, 2] batch (unoccupied and
        mesh-padding lanes stay zero; they fold masked-inactive)."""
        h, w = self.fns.in_hw
        frames = np.zeros((self.padded_capacity, self.chunk_slots, h, w, 2),
                          np.float32)
        for part in parts:
            for lane_i, block in part:
                frames[lane_i] = block
        return frames

    # ------------------------------------------------------------------
    def serve(self, source: EventSource, n_streams: int, *, seed: int = 0,
              paced: bool = False, offered_rate: float | None = None,
              max_pending: int | None = None, variants=None,
              on_window=None, log=None) -> ServingReport:
        """Serve ``n_streams`` replayed samples of ``source`` and return
        the serving report.

        ``offered_rate`` trickles the offers at that many streams/s on
        the replay clock (window ``w`` ↔ ``w·t_intg`` of stream time;
        under ``paced=True`` that is wall time too); default offers all
        streams up front. ``max_pending`` bounds the pending queue:
        offers arriving when ``pending + free lanes`` is exhausted are
        SHED and counted (``None`` = unbounded, no shedding). Offers,
        admission, and shedding are all driven by the deterministic
        window counter — never by the wall clock — so paced and unpaced
        runs of the same seed serve identical streams with bit-identical
        predictions; pacing only decides *when* each window runs and
        whether its readout missed its deadline.

        ``variants`` (registry mode) carries each stream's variant
        request — an entry name, a metadata matcher dict, or ``None``
        for the engine's ``default_entry`` — as a sequence of length
        ``n_streams`` or a callable ``stream_id -> request``, resolved
        at ADMISSION time against the live registry (so a hot-swap
        between offer and admission is honoured); unresolvable requests
        reject the stream (``n_rejected``). ``on_window(window)`` is
        called at the top of every window iteration — the hook tests and
        ops use to ``register``/``retire`` registry entries mid-serve
        (hot-swap) on the serving thread."""
        if offered_rate is not None and offered_rate <= 0:
            raise ValueError(f"offered_rate must be > 0 streams/s, got "
                             f"{offered_rate}")
        if max_pending is not None and max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        if variants is None:
            req_of = lambda sid: None                         # noqa: E731
        elif self.registry is None:
            raise ValueError("variants requires a registry-backed engine")
        elif callable(variants):
            req_of = variants
        else:
            vlist = list(variants)
            if len(vlist) != n_streams:
                raise ValueError(f"variants has {len(vlist)} requests for "
                                 f"n_streams={n_streams}")
            req_of = lambda sid: vlist[sid]                   # noqa: E731
        key = jax.random.PRNGKey(seed)
        t_intg_s = self.dep.t_intg_ms * 1e-3
        offers_per_window = (None if offered_rate is None
                             else offered_rate * t_intg_s)

        def offer_window(i: int) -> int:
            return (0 if offers_per_window is None
                    else int(math.floor(i / offers_per_window)))

        slots: ShardedSlots[_Lane] = ShardedSlots(self.capacity,
                                                  self.executor.devices)
        pending: deque[tuple[int, int]] = deque()  # (stream_id, offered_w)
        state = self.fns.init_state()
        results: list[StreamResult] = []
        report = ServingReport(
            results=results, deployed=self.dep.deployed_meta(),
            capacity=self.capacity,
            chunks_per_window=self.chunks_per_window,
            t_intg_ms=self.dep.t_intg_ms, wall_s=0.0, total_events=0,
            total_readouts=0, total_layer1_spikes=0.0, paced=paced,
            offered_rate=offered_rate, max_pending=max_pending,
            devices=self.executor.devices, bin_workers=self.bin_workers,
            padded_capacity=self.padded_capacity,
            lanes_per_shard=self.lanes_per_shard,
            per_shard_admitted=[0] * self.executor.devices,
            registry_compat=compat_digest(self.compat),
            registry_max_entries=self.max_entries)
        # per-(name, uid) counter rows, created at first admission; the
        # dicts are shared with report.entry_rows and mutated in place
        rows: dict[tuple[str, int], dict] = {}

        def row_of(lane: _Lane) -> dict:
            k = (lane.entry_name, lane.entry_uid)
            if k not in rows:
                rows[k] = {"name": k[0], "uid": k[1], "n_admitted": 0,
                           "n_finished": 0, "n_correct": 0, "n_misses": 0,
                           "n_events": 0, "n_readouts": 0}
                report.entry_rows.append(rows[k])
            return rows[k]

        h, w = self.fns.in_hw
        # warmup: compile fold/readout on a throwaway state so the
        # latency percentiles measure steady-state serving, not jit
        wx = (() if self.registry is None else
              (jnp.zeros((self.padded_capacity,), jnp.int32), self._bundle))
        wmask = jnp.zeros((self.padded_capacity,), bool)
        wframes = jnp.zeros((self.padded_capacity,
                             self.chunk_slots, h, w, 2))
        if self.adapt is None:
            ws = self.fns.fold(self.fns.init_state(), wframes, wmask, *wx)
            ws, _ = self.fns.readout(ws, wmask, wmask, *wx)
        else:
            wl = jnp.full((self.padded_capacity,), -1, jnp.int32)
            ws, wa = self.fns.fold(self.fns.init_state(),
                                   self.fns.init_adapt(), wframes, wmask,
                                   *wx)
            ws, wa, _ = self.fns.readout(ws, wa, wmask, wmask, wl, *wx)
        jax.block_until_ready(ws["logits"])
        pool = _BinPool(self.bin_workers) if self.prefetch else None
        next_offer = 0
        window = 0
        t_start = time.perf_counter()
        try:
            while (next_offer < n_streams or pending
                   or not slots.is_empty()):
                # ---- ops hook (hot-swap point): runs before this
                # window's admissions so a swap at window k governs
                # every stream admitted at k onward ---------------------
                if on_window is not None:
                    on_window(window)
                # ---- offers arriving at this window boundary ----------
                while (next_offer < n_streams
                       and offer_window(next_offer) <= window):
                    report.n_offered += 1
                    if (max_pending is not None
                            and len(pending) >= max_pending + slots.n_free):
                        report.n_shed += 1
                        if log is not None:
                            log(f"[admission] shed stream {next_offer} at "
                                f"window {window} (pending full)")
                    else:
                        pending.append((next_offer, window))
                    next_offer += 1
                # ---- lazy admission into free lanes (window boundary) -
                while pending and not slots.is_full():
                    sid, offered_w = pending.popleft()
                    if self.registry is not None:
                        # variant selection: resolve the stream's request
                        # against the LIVE registry; unresolvable →
                        # reject (never guess a variant for a sensor)
                        try:
                            entry = self.registry.resolve(
                                req_of(sid), compat=self.compat,
                                default=self.default_entry)
                            slot_e = self._bind_entry(entry)
                        except (LookupError, ValueError, TypeError,
                                EntryTableFull) as e:
                            report.n_rejected += 1
                            if log is not None:
                                log(f"[admission] rejected stream {sid} "
                                    f"at window {window}: {e}")
                            continue
                    lane = self.open_stream(
                        source, jax.random.fold_in(key, sid), sid)
                    lane.offered_window = offered_w
                    lane.admitted_window = window
                    if window > offered_w:
                        report.n_deferred += 1
                    lane_i = slots.admit(lane)
                    assert lane_i is not None
                    if self.registry is not None:
                        lane.entry_name = entry.name
                        lane.entry_uid = entry.uid
                        lane.entry_slot = slot_e
                        self._entry_of[lane_i] = slot_e
                    state = self.fns.reset_lane(state, lane_i)
                    if self.adapt is not None:
                        # learned deltas persist across streams on the
                        # lane (it models one physical sensor) but are
                        # void against a different base entry
                        uid = entry.uid if self.registry is not None else 0
                        if self._lane_entry_uid[lane_i] == uid:
                            self.adapt_state = \
                                self.fns.reset_lane_transient(
                                    self.adapt_state, lane_i)
                        else:
                            self.adapt_state = self.fns.reset_lane_full(
                                self.adapt_state, lane_i)
                        self._lane_entry_uid[lane_i] = uid
                        self._lane_base[lane_i] = (
                            entry.dep if self.registry is not None
                            else self.dep)
                        self._lane_base_name[lane_i] = lane.entry_name
                        self._labels[lane_i] = lane.label
                    report.n_admitted += 1
                    row_of(lane)["n_admitted"] += 1
                    report.per_shard_admitted[slots.shard_of(lane_i)] += 1
                report.max_open_streams = max(report.max_open_streams,
                                              slots.n_occupied)
                occupied = list(slots.occupied())
                active = jnp.asarray(slots.active_mask())
                # registry mode: this window's per-lane entry indices +
                # the (possibly just re-stacked) param bundle ride along
                # as jitted-step arguments — same shapes, no recompile
                extra = (() if self.registry is None else
                         (jnp.asarray(self._entry_of), self._bundle))
                # ---- paced: hold until this window's wall-clock start -
                if paced:
                    delay = (t_start + window * t_intg_s
                             - time.perf_counter())
                    if delay > 0:
                        time.sleep(delay)
                # ---- fold the window's replay chunks ------------------
                # binning runs one chunk ahead on the worker pool (each
                # worker bins only its own lane slice, in parallel) and
                # the fold dispatches are left in flight — the window's
                # only host↔device sync is the readout below
                parts_by_worker = self._partition(occupied)
                if pool is not None:
                    for _ in range(self.chunks_per_window):
                        for wi, lanes in enumerate(parts_by_worker):
                            pool.submit(wi, lambda ls=lanes:
                                        self._bin_part(source, ls))
                for _ in range(self.chunks_per_window):
                    t0 = time.perf_counter()
                    parts = ([pool.get(wi)
                              for wi in range(self.bin_workers)]
                             if pool is not None else
                             [self._bin_part(source, ls)
                              for ls in parts_by_worker])
                    frames = self._assemble(parts)
                    if self.adapt is None:
                        state = self.fns.fold(state, jnp.asarray(frames),
                                              active, *extra)
                    else:
                        state, self.adapt_state = self.fns.fold(
                            state, self.adapt_state, jnp.asarray(frames),
                            active, *extra)
                    report.fold_s.append(time.perf_counter() - t0)
                # ---- readout at the T_INTG boundary -------------------
                coarse_mask = np.zeros((self.padded_capacity,), bool)
                for lane_i, lane in occupied:
                    coarse_mask[lane_i] = \
                        (lane.windows_done + 1) % self.group == 0
                t0 = time.perf_counter()
                if self.adapt is None:
                    state, out = self.fns.readout(state, active,
                                                  jnp.asarray(coarse_mask),
                                                  *extra)
                else:
                    state, self.adapt_state, out = self.fns.readout(
                        state, self.adapt_state, active,
                        jnp.asarray(coarse_mask),
                        jnp.asarray(self._labels), *extra)
                n_spikes = np.asarray(out["n_spikes"])  # window sync point
                t_done = time.perf_counter()
                report.readout_s.append(t_done - t0)
                # paced: every occupied lane's readout k carries deadline
                # t_admit + k·t_intg; on the shared replay clock that is
                # the window boundary t_start + (window+1)·t_intg
                margin_ms = ((t_done - (t_start + (window + 1) * t_intg_s))
                             * 1e3 if paced else None)
                window += 1
                for lane_i, lane in occupied:
                    lane.windows_done += 1
                    report.total_readouts += 1
                    row = row_of(lane)
                    row["n_readouts"] += 1
                    report.total_layer1_spikes += float(n_spikes[lane_i])
                    if margin_ms is not None:
                        report.miss_margin_ms.append(margin_ms)
                        lane.worst_margin_ms = (
                            margin_ms if lane.worst_margin_ms is None
                            else max(lane.worst_margin_ms, margin_ms))
                        if margin_ms > 0:
                            lane.n_misses += 1
                            report.n_misses += 1
                            row["n_misses"] += 1
                    if lane.windows_done < lane.n_windows:
                        continue
                    # stream complete: finalize rate-decoded prediction
                    n_c = int(state["n_coarse"][lane_i])
                    logits = (np.asarray(state["logits"][lane_i])
                              / max(n_c, 1))
                    pred = int(np.argmax(logits))
                    report.total_events += lane.n_events
                    row["n_finished"] += 1
                    row["n_correct"] += int(pred == lane.label)
                    row["n_events"] += lane.n_events
                    results.append(StreamResult(
                        stream_id=lane.stream_id, label=lane.label,
                        prediction=pred, correct=pred == lane.label,
                        n_events=lane.n_events,
                        n_readouts=lane.windows_done, n_coarse_frames=n_c,
                        offered_window=lane.offered_window,
                        admitted_window=lane.admitted_window,
                        finished_window=window,
                        n_misses=lane.n_misses,
                        miss_margin_max_ms=lane.worst_margin_ms,
                        entry=lane.entry_name, entry_uid=lane.entry_uid,
                        logits=[float(v) for v in logits]))
                    slots.release(lane_i)
                    if self.adapt is not None:
                        self._labels[lane_i] = -1
                    if self.registry is not None:
                        self._unbind_entry(lane.entry_slot)
                    if log is not None:
                        log(f"[stream {lane.stream_id}] label={lane.label} "
                            f"pred={pred} readouts={lane.windows_done} "
                            f"events={lane.n_events}"
                            + (f" misses={lane.n_misses}" if paced else ""))
        finally:
            # runs on the exception path too: a failed readout/fold must
            # drain-and-join every bin worker (cancelling queued jobs) so
            # no daemon thread leaks holding an open stream iterator
            if pool is not None:
                pool.close()
        report.wall_s = time.perf_counter() - t_start
        if self.adapt is not None:
            lanes = lane_stats(jax.device_get(self.adapt_state))

            def _acc(rs: list[StreamResult]) -> float | None:
                return (sum(r.correct for r in rs) / len(rs)
                        if rs else None)

            # learning-curve split in finish order: accuracy over the
            # first vs second half of this run's streams — a cheap
            # online signal that adaptation is helping (tools/
            # ab_compare.py does the significance test properly)
            half = len(results) // 2
            report.adaptation = {
                "enabled": True,
                "rule": self.adapt.rule,
                "lr_w": self.adapt.lr_w,
                "lr_theta": self.adapt.lr_theta,
                "n_updates": sum(r["n_updates"] for r in lanes),
                "accuracy_pre": _acc(results[:half]),
                "accuracy_post": _acc(results[half:]),
                "lanes": lanes,
            }
        return report

    # ------------------------------------------------------------------
    def harvest(self, lane: int) -> dict:
        """One adapted lane's learned deltas + base identity, ready for
        delta-checkpoint export (repro.stream.deploy.save_adapt_delta)
        and re-registration as a new registry entry.

        The deltas are relative to the lane's base entry's QUANTIZED
        layer-1 weights and deployed threshold — exactly how the lane
        served them (``quantize(w_base + dw)``, ``theta_base + dtheta``).
        Harvesting a lane that never applied an update is allowed (zero
        deltas round-trip fine); a lane that never served raises."""
        if self.adapt is None:
            raise ValueError("engine was built without adapt= — nothing "
                             "to harvest")
        if not 0 <= lane < self.padded_capacity:
            raise ValueError(f"lane {lane} out of range "
                             f"[0, {self.padded_capacity})")
        base = self._lane_base[lane]
        if base is None:
            raise ValueError(f"lane {lane} never served a stream — no "
                             f"base entry to delta against")
        ast = jax.device_get(self.adapt_state)
        return {
            "lane": lane,
            "dw": np.asarray(ast["dw"][lane]),
            "dtheta": float(ast["dtheta"][lane]),
            "n_updates": int(ast["n_updates"][lane]),
            "base_name": self._lane_base_name[lane],
            "base_uid": int(self._lane_entry_uid[lane]),
            "base": base,
        }
