"""Leak-aware online charge accumulation: the jitted lane-batched steps
behind the streaming engine.

The paper's central constraint — the passive kernel capacitor loses
charge between event arrival and readout — is an *online* phenomenon.
This module integrates it online: each serving lane carries the linear
charge state ``x`` of one stream's pixel array, and every arriving
sub-slot of events advances the exact leak ODE before depositing its
conv contribution:

    x ← x · a + conv(events_k) · dv_unit,     a = e^(−dt/τ)  per filter

Folding sub-slots ``k = 0..n_sub−1`` this way telescopes to the offline
curve-fit forward's decay weighting ``Σ_k conv(ev_k)·a^(n_sub−1−k)``
(core/p2m_layer.curvefit_reduce) — an EMPTY sub-slot is one multiply by
``a`` (the capacitor keeps leaking while nothing arrives), and a chunk
gap of Δt sub-slots decays by ``a^Δt`` without touching the event path.
At each T_INTG boundary :func:`readout` adds the window's asymptotic
drift, applies the fitted transfer curve + process variation, compares
against the variant's threshold, 2x-pools the binary spikes onto the
sensor output, accumulates them toward the backbone's coarse grid, and
— on lanes crossing a coarse boundary — steps the stateful spiking
backbone (core/snn.spiking_cnn_stream_step) and the rate-decoding logit
average. The capacitor precharges (x ← 0) after every readout.

Everything is masked per lane (``active`` / ``coarse_mask``), so one
fixed-shape jitted step serves a continuously-batched lane table whose
streams start, progress, and finish independently. Numerical parity with
the offline batched forward (repro.stream.deploy.offline_forward) is
pinned by tests/test_streaming.py.

The lane axis is also *mesh-shardable* (repro.stream.shard): pass a
sharded :class:`~repro.stream.shard.LaneExecutor` and the fold/readout
bodies run under ``shard_map`` over a 1-D ``"lane"`` mesh — one
contiguous lane block per device, the deployed weights replicated. Every
lane's numerics are independent of its neighbours (no cross-lane
reduction anywhere in the serving forward), which is what makes sharded
and single-device serving bit-for-bit identical
(tests/test_stream_shard.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import analog, leakage, p2m_layer, snn
# the SAME conv the offline curvefit forward runs — parity depends on
# identical padding/dimension numbers, so it is imported, not copied
from repro.core.p2m_layer import _conv
from repro.kernels.stream_fold import ops as stream_fold_ops
from repro.stream.deploy import Deployment
from repro.stream.shard import P_LANE, P_REP, LaneExecutor  # noqa: F401


def _mask(m: jax.Array, new: jax.Array, old: jax.Array) -> jax.Array:
    """Per-lane select: lanes where ``m`` take ``new``, others keep
    ``old`` (broadcast over trailing axes)."""
    return jnp.where(m.reshape(m.shape + (1,) * (new.ndim - 1)), new, old)


@dataclass(frozen=True)
class StreamFns:
    """The compiled serving surface for one deployment × lane capacity:
    ``state`` is a pytree batched on the leading lane axis."""
    init_state: Callable[[], dict]
    reset_lane: Callable[[dict, int], dict]
    fold: Callable[[dict, jax.Array, jax.Array], dict]
    readout: Callable[[dict, jax.Array, jax.Array], tuple[dict, dict]]
    in_hw: tuple[int, int]       # event-frame resolution the lanes consume
    n_classes: int


@dataclass(frozen=True)
class MultiStreamFns:
    """The compiled MULTI-VARIANT serving surface (deployment registry,
    repro.stream.registry): fold/readout additionally take a per-lane
    ``entry`` index ``[capacity] int32`` and a stacked numerics ``bundle``
    (every :func:`entry_numerics` leaf stacked on a leading ``[E]`` entry
    axis — :func:`stack_entries`). The bundle is an ARGUMENT, not a
    closure, so hot-swapping a registry entry re-stacks the bundle
    without recompiling (shapes are unchanged)."""
    init_state: Callable[[], dict]
    reset_lane: Callable[[dict, int], dict]
    fold: Callable[[dict, jax.Array, jax.Array, jax.Array, dict], dict]
    readout: Callable[[dict, jax.Array, jax.Array, jax.Array, dict],
                      tuple[dict, dict]]
    in_hw: tuple[int, int]
    n_classes: int


def relinearized_numerics(w_raw: jax.Array, theta: jax.Array, *,
                          analog_cfg, coeffs: leakage.LeakCoeffs,
                          n_sub: int, dt_ms: float) -> dict:
    """The unfrozen protocol's differentiable curvefit seam, factored out
    for online use: quantize the raw layer-1 weights (straight-through),
    re-linearize the leak from the CURRENT quantized kernel, and derive
    the per-filter sub-slot decay ``a`` and window ``drift``.

    Every op is differentiable w.r.t. ``w_raw`` (STE through the
    quantizer, branch-free ``leak_params_from_coeffs``) and ``theta`` —
    which is what lets the per-lane adaptation rule (repro.stream.adapt)
    take surrogate gradients through the exact serving numerics at each
    coarse-window readout, the online analogue of the unfrozen phase-2
    training path."""
    w_q = analog.quantize_weights(w_raw, analog_cfg)
    lk = leakage.leak_params_from_coeffs(w_q, coeffs)
    a = leakage.decay_factor(lk.tau_ms, dt_ms)                        # [C]
    _, drift = p2m_layer.window_decay(lk, n_sub, dt_ms)
    return {"w_q": w_q, "a": a, "drift": drift, "theta": theta}


def entry_numerics(dep: Deployment) -> dict:
    """The deployed variant's serving numerics, as one pytree.

    Exactly the values :func:`make_stream_fns` closes over — quantized
    layer-1 weights, the per-filter sub-slot decay ``a`` and window drift
    from the leak linearization of the DEPLOYED kernel, the transfer
    curve's process-variation params, the comparator threshold, and the
    backbone params/BN state. Two compat-equal deployments (same
    geometry; see repro.stream.registry.compat_key) yield identically
    shaped pytrees, which is what lets a registry stack them on an entry
    axis (:func:`stack_entries`) and co-serve them from one engine."""
    cfg = dep.model_cfg
    p2m_cfg = cfg.p2m
    coeffs = dep.coeffs
    nb = relinearized_numerics(
        dep.params["p2m"]["w"], coeffs.v_threshold,
        analog_cfg=p2m_cfg.analog, coeffs=coeffs,
        n_sub=p2m_cfg.n_sub, dt_ms=p2m_cfg.dt_ms)
    return {
        **nb,
        "pv": {"gain": dep.params["p2m"]["pv_gain"],
               "offset": dep.params["p2m"]["pv_offset"]},
        "backbone": dep.params["backbone"],
        "bn_state": dep.bn_state,
    }


def stack_entries(numerics: list[dict]) -> dict:
    """Stack per-entry numerics pytrees on a leading ``[E]`` entry axis —
    the ``bundle`` argument of :class:`MultiStreamFns`. All entries must
    be compat-equal (identical leaf shapes)."""
    if not numerics:
        raise ValueError("cannot stack an empty entry list")
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *numerics)


def _fold_core(x: jax.Array, frames: jax.Array, nb: dict, *,
               stride: int, dv_unit: float, use_kernel: bool) -> jax.Array:
    """One variant's chunk fold: advance the charge ODE of every lane
    through ``frames`` [capacity, chunk_slots, H, W, 2] under numerics
    ``nb`` (:func:`entry_numerics`). Each sub-slot decays the standing
    charge by ``a`` and deposits its (dv_unit-scaled) conv — empty slots
    decay without deposit."""
    if use_kernel:
        return stream_fold_ops.fold_chunk(
            x, frames, nb["w_q"], nb["a"], stride=stride, dv_unit=dv_unit)

    def sub_step(x, ev_k):
        ideal = _conv(ev_k, nb["w_q"], stride) * dv_unit
        return x * nb["a"] + ideal, None

    x, _ = lax.scan(sub_step, x, jnp.moveaxis(frames, 1, 0))
    return x


def _readout_core(state: dict, nb: dict, *, analog_cfg, bb_cfg) -> dict:
    """One variant's T_INTG readout over every lane at once: window
    drift, transfer curve + PV, comparator, 2x pool, coarse accumulate,
    backbone step. Pure — masking/selection is the caller's job."""
    v_pre = analog.transfer_curve(state["x"] + nb["drift"], analog_cfg,
                                  nb["pv"])
    spikes = snn.spike_fn(v_pre - nb["theta"])                # [B, H, W, C]
    pooled = snn.max_pool(spikes)
    coarse = state["coarse"] + pooled
    logits_t, mem2 = snn.spiking_cnn_stream_step(
        nb["backbone"], nb["bn_state"], state["mem"], coarse, bb_cfg)
    return {"spikes": spikes, "pooled": pooled, "coarse": coarse,
            "logits_t": logits_t, "mem2": mem2}


def make_stream_fns(dep: Deployment, *, capacity: int,
                    chunk_slots: int, use_kernel: bool = False,
                    executor: LaneExecutor | None = None) -> StreamFns:
    """Build the jitted lane-batched fold/readout steps for ``dep``.

    ``chunk_slots`` is the number of fine sub-slots one replay chunk
    spans (``fold`` consumes frames ``[capacity, chunk_slots, H, W, 2]``);
    it must divide ``n_sub`` so T_INTG boundaries land on chunk
    boundaries. ``use_kernel=True`` routes the sub-slot fold through the
    fused Pallas stream_fold kernel (one launch per chunk, charge tile
    VMEM-resident — see docs/kernels.md); the XLA ``lax.scan`` fold
    below is its bit-exactness oracle and stays the default.

    A sharded ``executor`` (repro.stream.shard.LaneExecutor) partitions
    the lane axis over the 1-D ``"lane"`` mesh: ``capacity`` must then be
    a multiple of ``executor.devices`` (the engine pads it —
    ``LaneExecutor.padded_size``), fold/readout bodies run under
    ``shard_map`` with the state/frames/masks split into contiguous
    per-device lane blocks and the deployed weights replicated, and
    ``init_state``/``reset_lane`` stay global (admission is host-side and
    touches one lane at a time). ``executor=None`` (or ``devices=1``) is
    the exact unsharded path.
    """
    ex = executor or LaneExecutor()
    if capacity % ex.devices:
        raise ValueError(
            f"capacity={capacity} must be a multiple of "
            f"executor.devices={ex.devices} — pad the lane axis first "
            f"(LaneExecutor.padded_size)")
    cfg = dep.model_cfg
    p2m_cfg = cfg.p2m
    bb_cfg = cfg.backbone
    n_sub = p2m_cfg.n_sub
    if n_sub % chunk_slots:
        raise ValueError(f"chunk_slots={chunk_slots} must divide "
                         f"n_sub={n_sub}")
    H, W = bb_cfg.input_hw
    C = p2m_cfg.out_channels
    hp, wp = H // p2m_cfg.stride // 2, W // p2m_cfg.stride // 2  # post-pool

    # variant numerics, identical to the offline curvefit path: quantized
    # weights, leak linearization from the DEPLOYED kernel, per-filter
    # sub-slot decay a, window drift toward V_inf, transfer curve + PV.
    nb = entry_numerics(dep)

    def init_state() -> dict:
        return {
            # linear charge accumulator (pre-transfer-curve swing volts),
            # at the conv OUTPUT resolution (stride applied)
            "x": jnp.zeros((capacity, H // p2m_cfg.stride,
                            W // p2m_cfg.stride, C)),
            # pooled layer-1 spikes accumulating toward the next coarse
            # backbone frame
            "coarse": jnp.zeros((capacity, hp, wp, C)),
            # backbone LIF membranes (per layer) + rate-decoding average
            "mem": snn.spiking_cnn_stream_init(bb_cfg, capacity),
            "logits": jnp.zeros((capacity, bb_cfg.n_classes)),
            "n_coarse": jnp.zeros((capacity,), jnp.int32),
        }

    @jax.jit
    def reset_lane(state: dict, lane: jax.Array) -> dict:
        """Zero one lane's state (a newly admitted stream's precharge)."""
        return jax.tree.map(
            lambda v: v.at[lane].set(jnp.zeros_like(v[lane])), state)

    def fold_body(state: dict, frames: jax.Array, active: jax.Array
                  ) -> dict:
        """Advance the charge ODE through one replay chunk.

        ``frames`` [capacity, chunk_slots, H, W, 2] — the chunk's events
        binned on the fine sub-slot grid; ``active`` [capacity] bool.
        Each sub-slot decays the standing charge by ``a`` and deposits
        its (dv_unit-scaled) conv — empty slots decay without deposit.
        Under a sharded executor this body sees one device's contiguous
        lane block (capacity / devices lanes).
        """
        x = _fold_core(state["x"], frames, nb, stride=p2m_cfg.stride,
                       dv_unit=p2m_cfg.analog.dv_unit,
                       use_kernel=use_kernel)
        return {**state, "x": _mask(active, x, state["x"])}

    def readout_body(state: dict, active: jax.Array,
                     coarse_mask: jax.Array) -> tuple[dict, dict]:
        """T_INTG-boundary readout for every lane at once.

        ``active`` gates which lanes read out (and precharge);
        ``coarse_mask ⊆ active`` marks lanes whose coarse window just
        completed — only those step the backbone and the logit average.
        Returns the new state and per-lane outputs (binary spike map,
        pooled spike count) for stats and parity checks.
        """
        ro = _readout_core(state, nb, analog_cfg=p2m_cfg.analog,
                           bb_cfg=bb_cfg)
        spikes, pooled, coarse = ro["spikes"], ro["pooled"], ro["coarse"]
        logits_t, mem2 = ro["logits_t"], ro["mem2"]
        new_state = {
            "x": _mask(active, jnp.zeros_like(state["x"]), state["x"]),
            "coarse": _mask(active,
                            _mask(coarse_mask, jnp.zeros_like(coarse),
                                  coarse),
                            state["coarse"]),
            "mem": jax.tree.map(lambda n, o: _mask(coarse_mask, n, o),
                                mem2, state["mem"]),
            "logits": state["logits"] + _mask(coarse_mask, logits_t,
                                              jnp.zeros_like(logits_t)),
            "n_coarse": state["n_coarse"] + coarse_mask.astype(jnp.int32),
        }
        out = {"spikes": spikes,
               "n_spikes": jnp.sum(pooled, axis=(1, 2, 3))
               * active.astype(pooled.dtype)}
        return new_state, out

    # shard the lane axis over the mesh (identity when devices=1): every
    # input/output leaf is lane-leading, the closed-over deployed weights
    # replicate. jit wraps the shard_map, as in the sweep engine.
    fold = jax.jit(ex.shard(fold_body,
                            in_specs=(P_LANE, P_LANE, P_LANE),
                            out_specs=P_LANE))
    readout = jax.jit(ex.shard(readout_body,
                               in_specs=(P_LANE, P_LANE, P_LANE),
                               out_specs=(P_LANE, P_LANE)))

    return StreamFns(init_state=init_state, reset_lane=reset_lane,
                     fold=fold, readout=readout, in_hw=(H, W),
                     n_classes=bb_cfg.n_classes)


def make_multi_stream_fns(dep: Deployment, *, capacity: int,
                          chunk_slots: int, use_kernel: bool = False,
                          executor: LaneExecutor | None = None
                          ) -> MultiStreamFns:
    """Build the jitted MULTI-VARIANT fold/readout steps (deployment
    registry serving). ``dep`` is the engine's ANCHOR entry — it only
    pins the shared serving geometry (resolution, stride, channels,
    n_sub, backbone architecture; the compat key); the actual per-lane
    numerics arrive per call as a stacked ``bundle``
    (:func:`stack_entries` over :func:`entry_numerics`) plus a per-lane
    ``entry`` index ``[capacity] int32`` into its ``[E]`` axis.

    Bit-exactness contract (the registry's headline invariant): for each
    entry ``e``, the body runs the IDENTICAL full-lane-batch program a
    single-variant engine would run with ``e``'s numerics — ``lax.map``
    over the entry axis, the same idiom the sweep engine uses for the
    variant axis — and then gathers, per lane, the row of the entry that
    lane is bound to. Because every lane's numerics are independent of
    its neighbours (no cross-lane reduction anywhere in the serving
    forward — the same property that makes sharding bit-exact), lane
    ``i`` of entry ``e``'s sweep is bit-identical to lane ``i`` of a
    single-variant serve, so the gathered mixed-variant state is
    bit-identical per lane too (tests/test_registry.py pins it, on 1
    device and on a lane mesh).

    Under a sharded ``executor`` the state/frames/masks and the entry
    index split into per-device lane blocks (``P_LANE``) while the
    bundle replicates (``P_REP``) — every device carries all E variants,
    exactly as the single-variant engine replicates its one deployment.
    """
    ex = executor or LaneExecutor()
    if capacity % ex.devices:
        raise ValueError(
            f"capacity={capacity} must be a multiple of "
            f"executor.devices={ex.devices} — pad the lane axis first "
            f"(LaneExecutor.padded_size)")
    cfg = dep.model_cfg
    p2m_cfg = cfg.p2m
    bb_cfg = cfg.backbone
    if p2m_cfg.n_sub % chunk_slots:
        raise ValueError(f"chunk_slots={chunk_slots} must divide "
                         f"n_sub={p2m_cfg.n_sub}")
    H, W = bb_cfg.input_hw
    C = p2m_cfg.out_channels
    hp, wp = H // p2m_cfg.stride // 2, W // p2m_cfg.stride // 2

    def init_state() -> dict:
        return {
            "x": jnp.zeros((capacity, H // p2m_cfg.stride,
                            W // p2m_cfg.stride, C)),
            "coarse": jnp.zeros((capacity, hp, wp, C)),
            "mem": snn.spiking_cnn_stream_init(bb_cfg, capacity),
            "logits": jnp.zeros((capacity, bb_cfg.n_classes)),
            "n_coarse": jnp.zeros((capacity,), jnp.int32),
        }

    @jax.jit
    def reset_lane(state: dict, lane: jax.Array) -> dict:
        return jax.tree.map(
            lambda v: v.at[lane].set(jnp.zeros_like(v[lane])), state)

    def _gather(tree, entry: jax.Array):
        """Per-lane entry selection: leaf [E, capacity, ...] → lane i
        takes row ``[entry[i], i]`` — the exact gather that makes mixed
        serving bit-identical to the per-entry full-batch programs."""
        lanes = jnp.arange(entry.shape[0])
        return jax.tree.map(lambda leaf: leaf[entry, lanes], tree)

    def fold_body(state: dict, frames: jax.Array, active: jax.Array,
                  entry: jax.Array, bundle: dict) -> dict:
        xs = lax.map(
            lambda nb: _fold_core(state["x"], frames, nb,
                                  stride=p2m_cfg.stride,
                                  dv_unit=p2m_cfg.analog.dv_unit,
                                  use_kernel=use_kernel),
            {"w_q": bundle["w_q"], "a": bundle["a"]})   # [E, cap, ...]
        x = _gather(xs, entry)
        return {**state, "x": _mask(active, x, state["x"])}

    def readout_body(state: dict, active: jax.Array,
                     coarse_mask: jax.Array, entry: jax.Array,
                     bundle: dict) -> tuple[dict, dict]:
        ro = _gather(
            lax.map(lambda nb: _readout_core(state, nb,
                                             analog_cfg=p2m_cfg.analog,
                                             bb_cfg=bb_cfg),
                    bundle),
            entry)
        spikes, pooled, coarse = ro["spikes"], ro["pooled"], ro["coarse"]
        logits_t, mem2 = ro["logits_t"], ro["mem2"]
        new_state = {
            "x": _mask(active, jnp.zeros_like(state["x"]), state["x"]),
            "coarse": _mask(active,
                            _mask(coarse_mask, jnp.zeros_like(coarse),
                                  coarse),
                            state["coarse"]),
            "mem": jax.tree.map(lambda n, o: _mask(coarse_mask, n, o),
                                mem2, state["mem"]),
            "logits": state["logits"] + _mask(coarse_mask, logits_t,
                                              jnp.zeros_like(logits_t)),
            "n_coarse": state["n_coarse"] + coarse_mask.astype(jnp.int32),
        }
        out = {"spikes": spikes,
               "n_spikes": jnp.sum(pooled, axis=(1, 2, 3))
               * active.astype(pooled.dtype)}
        return new_state, out

    # lane-leading leaves shard over the mesh; the entry index rides the
    # lane axis with them; the bundle (all E variants) replicates.
    fold = jax.jit(ex.shard(
        fold_body,
        in_specs=(P_LANE, P_LANE, P_LANE, P_LANE, P_REP),
        out_specs=P_LANE))
    readout = jax.jit(ex.shard(
        readout_body,
        in_specs=(P_LANE, P_LANE, P_LANE, P_LANE, P_REP),
        out_specs=(P_LANE, P_LANE)))

    return MultiStreamFns(init_state=init_state, reset_lane=reset_lane,
                          fold=fold, readout=readout, in_hw=(H, W),
                          n_classes=bb_cfg.n_classes)
