"""Per-lane online adaptation: local plasticity through the serving path.

The sweep enumerates the paper's retention/accuracy trade-off *offline*,
per variant cell. A deployed sensor experiences it *per device*: its
leak drifts with temperature and fab corner (the ``sigma`` axis), and
the weights it was deployed with slowly stop matching the capacitors
they drive. This module is the neuromorphic answer the ROADMAP calls
for — a local, per-lane plasticity rule that nudges each lane's layer-1
quantized weights and comparator threshold *during* serving, the online
analogue of the unfrozen training protocol.

Mechanics
---------
Each serving lane (= one physical sensor) carries an :class:`AdaptState`
row on the ``[capacity, ...]`` lane axis:

- ``dw``/``dtheta`` — the lane's persistent weight/threshold deltas,
  applied as ``quantize(w_base + dw)`` (straight-through, the same
  quantizer the unfrozen protocol trains through) and
  ``theta_base + dtheta``. They survive stream turnover on the lane and
  reset only when the lane rebinds to a different registry entry.
- ``ev`` — a per-filter decay-weighted event accumulator
  ``E_f ← E_f · a_f + ev_k`` folded alongside the charge, so the readout
  can *recompute* the window's linear charge from the raw events under
  the current weights (``diag(conv(E, w_q))``, bit-equal to the fold's
  telescoped sum up to fp ordering) and differentiate through it. It
  precharges (``E ← 0``) with the capacitor at every readout.
- ``elig_w``/``elig_theta`` — eligibility traces for the three-factor
  rule; ``n_updates`` counts applied updates.

At each coarse-window readout the rule takes a truncated (depth-1)
surrogate gradient through the exact serving numerics — re-quantize,
re-linearize the leak, re-derive drift, transfer curve, ATan surrogate
spike, pool, backbone step (``accumulator.relinearized_numerics``, the
unfrozen protocol's differentiable curvefit seam) — and applies one of
two local rules:

- ``surrogate`` — plain surrogate-gradient descent on the window's
  cross-entropy against the replayed stream's label (when it carries
  one; unlabeled lanes never update).
- ``reward`` — reward-modulated three-factor fallback: the gradient
  toward the lane's OWN prediction accumulates into an eligibility
  trace, and a scalar reward (+1 correct / −1 wrong, 0 unlabeled)
  gates the trace into the weights — the RSTDP analogue.

Everything is lane-diagonal: no cross-lane reduction anywhere, so the
state shards with the lane axis (``P_LANE``) under the lane mesh exactly
like the serving state, per-lane updates provably never perturb other
lanes, and registry serving gathers each lane's base numerics from the
stacked entry bundle before applying that lane's deltas.

Adaptation is a *separate opt-in compiled surface*: with
``StreamEngine(adapt=None)`` none of this module runs and serving stays
IEEE-bit-identical to the frozen path. The fused Pallas fold
(``kernels/stream_fold``) has no VJP and shares one weight tensor across
lanes, so ``use_kernel=True`` + adaptation raises (pinned by
tests/test_stream_adapt.py). Adapted lanes are harvested through
``StreamEngine.harvest`` and round-trip as validated checkpoint deltas
(repro.stream.deploy.save_adapt_delta) into new registry entries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import analog, snn
# same conv as the serving fold/offline curvefit — gradient parity
# depends on identical padding/dimension numbers
from repro.core.p2m_layer import _conv
from repro.stream.accumulator import (_mask, entry_numerics,
                                      make_multi_stream_fns,
                                      make_stream_fns,
                                      relinearized_numerics)
from repro.stream.deploy import Deployment
from repro.stream.shard import P_LANE, P_REP, LaneExecutor

RULES = ("surrogate", "reward")

# per-stream transients: reset at every admission. dw/dtheta/n_updates
# persist across streams on a lane and reset only on entry rebind.
_TRANSIENT = ("elig_w", "elig_theta", "ev")


@dataclass(frozen=True)
class AdaptConfig:
    """Local-rule hyperparameters (one config for the whole fleet; the
    *state* is per lane)."""
    rule: str = "surrogate"          # "surrogate" | "reward"
    lr_w: float = 5e-3               # weight-delta learning rate
    lr_theta: float = 0.0            # threshold-delta learning rate
    trace_decay: float = 0.9         # eligibility-trace decay (reward rule)
    clip_w: float = 0.5              # |dw| bound (keeps quantizer in range)
    clip_theta: float = 0.05         # |dtheta| bound (volts)

    def __post_init__(self) -> None:
        if self.rule not in RULES:
            raise ValueError(f"adapt rule must be one of {RULES}, "
                             f"got {self.rule!r}")
        if self.lr_w < 0 or self.lr_theta < 0:
            raise ValueError("learning rates must be >= 0")
        if self.clip_w <= 0 or self.clip_theta <= 0:
            raise ValueError("delta clips must be > 0")


@dataclass(frozen=True)
class AdaptFns:
    """Jitted adaptation-enabled serving steps — the drop-in replacement
    for StreamFns/MultiStreamFns when a StreamEngine runs with
    ``adapt=``. ``fold``/``readout`` thread the :class:`AdaptState` dict
    alongside the serving state; registry engines append the usual
    ``(entry, bundle)`` pair (bundle extended with per-entry
    ``LeakCoeffs`` — :func:`adapt_entry_numerics`)."""
    init_state: Callable[[], dict]
    init_adapt: Callable[[], dict]
    reset_lane: Callable[..., dict]
    reset_lane_transient: Callable[..., dict]
    reset_lane_full: Callable[..., dict]
    fold: Callable[..., tuple]
    readout: Callable[..., tuple]
    in_hw: tuple[int, int]
    n_classes: int


def adapt_entry_numerics(dep: Deployment) -> dict:
    """:func:`~repro.stream.accumulator.entry_numerics` extended with the
    entry's leak-circuit constants. Adaptation re-linearizes the leak
    from the CURRENT per-lane weights at every readout, so the stacked
    bundle must carry each entry's ``LeakCoeffs`` (a pytree of scalars —
    it stacks on the entry axis and gathers per lane like every other
    leaf), not just the pre-derived ``a``/``drift``."""
    return {**entry_numerics(dep), "coeffs": dep.coeffs}


def make_adapt_fns(dep: Deployment, *, capacity: int, chunk_slots: int,
                   adapt: AdaptConfig, use_kernel: bool = False,
                   executor: LaneExecutor | None = None,
                   registry: bool = False) -> AdaptFns:
    """Build the jitted per-lane-adapting fold/readout for ``dep``.

    The serving forward matches the frozen engine's semantics exactly
    (same masking, same state update) but is vmapped per lane so each
    lane serves under its OWN ``quantize(w_base + dw)`` /
    ``theta_base + dtheta`` numerics, re-linearized through
    ``relinearized_numerics`` each chunk. ``registry=True`` builds the
    multi-variant flavor: fold/readout take ``(entry, bundle)`` and
    gather each lane's base numerics before applying its deltas.
    """
    if use_kernel:
        raise ValueError(
            "online adaptation requires the differentiable XLA scan "
            "fold: kernels/stream_fold has no VJP and shares one weight "
            "tensor across lanes — serve with use_kernel=False, or drop "
            "adapt")
    # serving-state init/reset (and the lane-axis divisibility checks)
    # are identical to the frozen engine's — reuse them.
    base = (make_multi_stream_fns if registry else make_stream_fns)(
        dep, capacity=capacity, chunk_slots=chunk_slots,
        use_kernel=False, executor=executor)
    ex = executor or LaneExecutor()
    cfg = dep.model_cfg
    p2m_cfg, bb_cfg = cfg.p2m, cfg.backbone
    analog_cfg = p2m_cfg.analog
    stride, dv_unit = p2m_cfg.stride, analog_cfg.dv_unit
    H, W = bb_cfg.input_hw
    k, cin, F = p2m_cfg.kernel_size, p2m_cfg.in_channels, p2m_cfg.out_channels
    # per-lane base numerics: gathered from the bundle per call
    # (registry) or closed over (single-deployment); nb_ax is the vmap
    # axis for the nb argument of every per-lane closure.
    nb0 = adapt_entry_numerics(dep)
    nb_ax = 0 if registry else None

    def init_adapt() -> dict:
        return {
            "dw": jnp.zeros((capacity, k, k, cin, F)),
            "dtheta": jnp.zeros((capacity,)),
            "elig_w": jnp.zeros((capacity, k, k, cin, F)),
            "elig_theta": jnp.zeros((capacity,)),
            "ev": jnp.zeros((capacity, F, H, W, cin)),
            "n_updates": jnp.zeros((capacity,), jnp.int32),
        }

    @jax.jit
    def reset_lane_transient(astate: dict, lane: jax.Array) -> dict:
        """New stream on the lane: clear the window accumulator and the
        eligibility traces, KEEP the lane's learned deltas."""
        return {key: (v.at[lane].set(jnp.zeros_like(v[lane]))
                      if key in _TRANSIENT else v)
                for key, v in astate.items()}

    @jax.jit
    def reset_lane_full(astate: dict, lane: jax.Array) -> dict:
        """Lane rebinds to a different entry uid: deltas learned against
        the old base are meaningless — zero everything."""
        return jax.tree.map(
            lambda v: v.at[lane].set(jnp.zeros_like(v[lane])), astate)

    def lane_relin(nb: dict, dw: jax.Array, dtheta: jax.Array) -> dict:
        """One lane's adapted numerics through the differentiable seam."""
        return relinearized_numerics(
            nb["w_q"] + dw, nb["theta"] + dtheta, analog_cfg=analog_cfg,
            coeffs=nb["coeffs"], n_sub=p2m_cfg.n_sub, dt_ms=p2m_cfg.dt_ms)

    vrelin = jax.vmap(lane_relin, in_axes=(nb_ax, 0, 0))
    vconv = jax.vmap(lambda ev, w: _conv(ev[None], w, stride)[0])

    def _lane_nbs(extra: tuple) -> dict:
        if registry:
            entry, bundle = extra
            return jax.tree.map(lambda leaf: leaf[entry], bundle)
        return nb0

    def fold_body(state: dict, astate: dict, frames: jax.Array,
                  active: jax.Array, *extra) -> tuple[dict, dict]:
        """The scan fold under per-lane adapted numerics, plus the
        per-filter event accumulator ``E`` riding the same decay."""
        nb = _lane_nbs(extra)
        ln = vrelin(nb, astate["dw"], astate["dtheta"])
        w_q, a = ln["w_q"], ln["a"]          # [cap,k,k,2,F], [cap,F]

        def sub_step(carry, ev_k):           # ev_k [cap, H, W, 2]
            x, E = carry
            x = x * a[:, None, None, :] + vconv(ev_k, w_q) * dv_unit
            E = E * a[:, :, None, None, None] + ev_k[:, None]
            return (x, E), None

        (x, E), _ = lax.scan(sub_step, (state["x"], astate["ev"]),
                             jnp.moveaxis(frames, 1, 0))
        return ({**state, "x": _mask(active, x, state["x"])},
                {**astate, "ev": _mask(active, E, astate["ev"])})

    def lane_head(x_lin: jax.Array, ln: dict, nb: dict,
                  coarse: jax.Array, mem) -> dict:
        """One lane's readout forward from a linear charge map: transfer
        curve + PV, surrogate comparator, pool, coarse accumulate,
        backbone step. Shared by the serving pass (x from the fold) and
        the gradient pass (x recomputed from ``ev``)."""
        v_pre = analog.transfer_curve(x_lin + ln["drift"], analog_cfg,
                                      nb["pv"])
        spikes = snn.spike_fn(v_pre - ln["theta"])
        pooled = snn.max_pool(spikes[None])[0]
        coarse2 = coarse + pooled
        logits_t, mem2 = snn.spiking_cnn_stream_step(
            nb["backbone"], nb["bn_state"],
            jax.tree.map(lambda v: v[None], mem), coarse2[None], bb_cfg)
        return {"spikes": spikes, "pooled": pooled, "coarse": coarse2,
                "logits_t": logits_t[0],
                "mem2": jax.tree.map(lambda v: v[0], mem2)}

    def lane_serve(dw, dtheta, nb, x_fold, coarse, mem) -> dict:
        ln = lane_relin(nb, dw, dtheta)
        return lane_head(x_fold, ln, nb, coarse, mem)

    vserve = jax.vmap(lane_serve, in_axes=(0, 0, nb_ax, 0, 0, 0))

    def lane_loss(dw, dtheta, target, nb, E, coarse, mem):
        """Window cross-entropy vs ``target`` with the linear charge
        recomputed from the event accumulator under the CURRENT deltas —
        the truncated depth-1 window through the curvefit seam (the
        decay weighting inside ``E`` and earlier windows' coarse counts
        are constants)."""
        ln = lane_relin(nb, dw, dtheta)
        y = _conv(E, ln["w_q"], stride)              # [F, Hs, Ws, F]
        x_lin = jnp.diagonal(y, axis1=0, axis2=3) * dv_unit
        ro = lane_head(x_lin, ln, nb, coarse, mem)
        return -jax.nn.log_softmax(ro["logits_t"])[target], ro["logits_t"]

    vgrad = jax.vmap(jax.grad(lane_loss, argnums=(0, 1), has_aux=True),
                     in_axes=(0, 0, 0, nb_ax, 0, 0, 0))

    def readout_body(state: dict, astate: dict, active: jax.Array,
                     coarse_mask: jax.Array, labels: jax.Array,
                     *extra) -> tuple[dict, dict, dict]:
        """Frozen-engine readout semantics under per-lane numerics, then
        one local update on lanes crossing a labeled coarse boundary."""
        nb = _lane_nbs(extra)
        ro = vserve(astate["dw"], astate["dtheta"], nb, state["x"],
                    state["coarse"], state["mem"])
        spikes, pooled, coarse = ro["spikes"], ro["pooled"], ro["coarse"]
        logits_t, mem2 = ro["logits_t"], ro["mem2"]
        new_state = {
            "x": _mask(active, jnp.zeros_like(state["x"]), state["x"]),
            "coarse": _mask(active,
                            _mask(coarse_mask, jnp.zeros_like(coarse),
                                  coarse),
                            state["coarse"]),
            "mem": jax.tree.map(lambda n, o: _mask(coarse_mask, n, o),
                                mem2, state["mem"]),
            "logits": state["logits"] + _mask(coarse_mask, logits_t,
                                              jnp.zeros_like(logits_t)),
            "n_coarse": state["n_coarse"] + coarse_mask.astype(jnp.int32),
        }

        # ---- local update (per lane, lane-diagonal) ----
        has_label = labels >= 0
        boundary = active & coarse_mask
        upd = boundary & has_label
        if adapt.rule == "surrogate":
            tgt = jnp.maximum(labels, 0)
        else:
            # three-factor: eligibility accumulates the gradient toward
            # the lane's own prediction; reward gates it in.
            tgt = jnp.argmax(logits_t, axis=-1).astype(jnp.int32)
        (g_w, g_th), _ = vgrad(astate["dw"], astate["dtheta"], tgt, nb,
                               astate["ev"], state["coarse"],
                               state["mem"])
        if adapt.rule == "surrogate":
            dw_step, th_step = adapt.lr_w * g_w, adapt.lr_theta * g_th
            elig_w, elig_th = astate["elig_w"], astate["elig_theta"]
        else:
            elig_w = _mask(boundary,
                           adapt.trace_decay * astate["elig_w"] + g_w,
                           astate["elig_w"])
            elig_th = jnp.where(boundary,
                                adapt.trace_decay * astate["elig_theta"]
                                + g_th,
                                astate["elig_theta"])
            r = jnp.where(has_label,
                          jnp.where(tgt == labels, 1.0, -1.0), 0.0)
            dw_step = adapt.lr_w * r[:, None, None, None, None] * elig_w
            th_step = adapt.lr_theta * r * elig_th
        dw = jnp.clip(astate["dw"] - dw_step, -adapt.clip_w, adapt.clip_w)
        dth = jnp.clip(astate["dtheta"] - th_step,
                       -adapt.clip_theta, adapt.clip_theta)
        new_astate = {
            "dw": _mask(upd, dw, astate["dw"]),
            "dtheta": jnp.where(upd, dth, astate["dtheta"]),
            "elig_w": elig_w,
            "elig_theta": elig_th,
            # the event accumulator precharges with the capacitor
            "ev": _mask(active, jnp.zeros_like(astate["ev"]),
                        astate["ev"]),
            "n_updates": astate["n_updates"] + upd.astype(jnp.int32),
        }
        out = {"spikes": spikes,
               "n_spikes": jnp.sum(pooled, axis=(1, 2, 3))
               * active.astype(pooled.dtype)}
        return new_state, new_astate, out

    extra_specs = (P_LANE, P_REP) if registry else ()
    fold = jax.jit(ex.shard(
        fold_body,
        in_specs=(P_LANE, P_LANE, P_LANE, P_LANE) + extra_specs,
        out_specs=(P_LANE, P_LANE)))
    readout = jax.jit(ex.shard(
        readout_body,
        in_specs=(P_LANE, P_LANE, P_LANE, P_LANE, P_LANE) + extra_specs,
        out_specs=(P_LANE, P_LANE, P_LANE)))

    return AdaptFns(init_state=base.init_state, init_adapt=init_adapt,
                    reset_lane=base.reset_lane,
                    reset_lane_transient=reset_lane_transient,
                    reset_lane_full=reset_lane_full,
                    fold=fold, readout=readout,
                    in_hw=base.in_hw, n_classes=base.n_classes)


def lane_stats(astate: dict) -> list[dict]:
    """Host-side per-lane rows for the v5 stats artifact: lanes that
    applied at least one update, with their delta norms."""
    dw = np.asarray(astate["dw"])
    dth = np.asarray(astate["dtheta"])
    n_upd = np.asarray(astate["n_updates"])
    rows = []
    for lane in range(n_upd.shape[0]):
        if int(n_upd[lane]) == 0:
            continue
        rows.append({
            "lane": lane,
            "n_updates": int(n_upd[lane]),
            "dw_norm": float(np.linalg.norm(dw[lane])),
            "dtheta": float(dth[lane]),
        })
    return rows
