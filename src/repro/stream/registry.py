"""Deployment registry: the catalog of servable checkpoints behind
multi-variant serving.

The paper's central trade-off — limited capacitor retention forcing
per-circuit choices of integration time, thresholds, and leak
compensation — means a fleet of P²M sensors never runs ONE checkpoint:
each physical sensor wants the circuit variant matching its process
corner. This module is the model catalog the serving engine
(repro.stream.engine) selects from per stream:

  * :class:`Registry` holds named :class:`RegistryEntry` rows, each a
    deployed :class:`~repro.stream.deploy.Deployment` plus
    self-describing metadata (circuit variant dict, dataset, protocol,
    ``sensor_hw``, accuracy) and a **compat key** derived from the
    artifact handshake — the canonical fingerprint of everything that
    must match for two entries to share one serving engine (replay
    geometry, backbone architecture, analog frontend; NOT the leak
    variant, which is exactly what entries differ in).
  * streams are offered with a **variant request** — an entry name, a
    metadata matcher dict, or ``None`` for the engine default — and
    admission resolves it against the live registry
    (:meth:`Registry.resolve`); no match or an ambiguous match rejects
    the stream at admission instead of mis-deploying it.
  * **hot-swap**: :meth:`Registry.register` / :meth:`Registry.retire`
    mutate the catalog while a serve is running. Every registration
    gets a fresh ``uid``, so a lane bound to a retired (or re-registered)
    entry keeps serving the exact weights it was admitted with until it
    finishes — lanes bound to other entries are never drained.

The engine-side half (per-lane stacked params, entry-table slots,
per-entry stats ledger) lives in repro.stream.engine; the bit-exactness
contract — a mixed-variant serve is bit-identical per stream to
single-variant serves of the same streams — is pinned by
tests/test_registry.py and the CI registry smoke.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Mapping

from repro.stream.deploy import (Deployment, load_deployment,
                                 model_config_to_dict)


def compat_key(dep: Deployment) -> str:
    """Canonical fingerprint of the serving geometry ``dep`` requires.

    Two deployments with equal compat keys can be co-served by one
    engine: same T_INTG / n_sub replay grid, input resolution, stride,
    channel counts, backbone architecture, analog frontend, and coarse
    window. The leak block (circuit, mismatch, thresholds, sigma) is
    EXCLUDED — that is the variant axis entries differ in — and so is
    the model-default ``v_threshold`` (each record pins its resolved
    threshold inside the variant dict). Keys are sorted before
    serialization, so the fingerprint is reproducible across dict
    orderings and process runs.
    """
    d = model_config_to_dict(dep.model_cfg)
    d["p2m"].pop("leak", None)
    d["p2m"].pop("v_threshold", None)
    return json.dumps(d, sort_keys=True, separators=(",", ":"),
                      default=float)


def compat_digest(key: str) -> str:
    """Short stable digest of a compat key (display / artifact field)."""
    return hashlib.sha256(key.encode()).hexdigest()[:12]


@dataclass(frozen=True)
class RegistryEntry:
    """One deployed checkpoint in the catalog.

    ``uid`` is unique per *registration* (not per name): re-registering
    a name after ``retire`` yields a new uid, which is how the engine
    tells weights apart across a hot-swap while old lanes drain
    naturally.
    """
    name: str
    dep: Deployment
    meta: dict
    compat: str
    uid: int

    @property
    def compat_digest(self) -> str:
        return compat_digest(self.compat)

    def describe(self) -> dict:
        """JSON-safe row for artifacts and CLI summaries."""
        return {"name": self.name, "uid": self.uid,
                "compat": self.compat_digest, **self.meta}


def entry_meta(dep: Deployment) -> dict:
    """Self-describing metadata of a deployment, flat so matcher dicts
    can address any field directly (``{"circuit": "c"}``,
    ``{"protocol": "frozen"}``, ...). The variant dict is splatted AND
    kept whole under ``"variant"``."""
    variant = dict(dep.record.get("variant") or {})
    meta = {
        "label": dep.record.get("label"),
        "protocol": dep.protocol,
        "t_intg_ms": dep.t_intg_ms,
        "n_sub": dep.model_cfg.p2m.n_sub,
        "accuracy": dep.record.get("accuracy"),
        "dataset": dep.meta.get("dataset"),
        "sensor_hw": dep.meta.get("sensor_hw"),
        "variant": variant,
    }
    meta.update(variant)
    return meta


class Registry:
    """Mutable catalog of named deployments with resolve-at-admission
    semantics. Mutations bump ``version`` so a running engine can GC its
    cached per-entry params cheaply."""

    def __init__(self) -> None:
        self._entries: dict[str, RegistryEntry] = {}
        self._next_uid = 0
        self.version = 0

    # -- CRUD -----------------------------------------------------------
    def register(self, name: str, dep: Deployment, *,
                 meta: Mapping | None = None) -> RegistryEntry:
        """Add ``dep`` to the catalog under ``name``. Names are unique —
        re-registering a live name raises (``retire`` first; the
        retire+register pair IS the hot-swap). ``meta`` overrides /
        extends the self-described metadata."""
        if not name:
            raise ValueError("registry entry name must be non-empty")
        if name in self._entries:
            raise ValueError(
                f"registry entry {name!r} already exists (uid "
                f"{self._entries[name].uid}) — retire it first to hot-swap")
        m = entry_meta(dep)
        if meta:
            m.update(meta)
        entry = RegistryEntry(name=name, dep=dep, meta=m,
                              compat=compat_key(dep), uid=self._next_uid)
        self._next_uid += 1
        self._entries[name] = entry
        self.version += 1
        return entry

    def register_checkpoint(self, name: str, directory: str | Path, *,
                            artifact=None,
                            meta: Mapping | None = None) -> RegistryEntry:
        """``load_deployment`` + ``register`` in one step — the
        checkpoint's embedded registry metadata (dataset, sensor_hw,
        record) self-describes the entry."""
        return self.register(name, load_deployment(directory, artifact),
                             meta=meta)

    def retire(self, name: str) -> RegistryEntry:
        """Remove ``name`` from the catalog. Lanes already bound to it
        keep serving its exact weights until they finish (the engine
        holds the entry's params until its last lane releases); it just
        stops matching new admissions."""
        if name not in self._entries:
            raise KeyError(f"registry has no entry {name!r} "
                           f"(entries: {sorted(self._entries)})")
        entry = self._entries.pop(name)
        self.version += 1
        return entry

    # -- lookup ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def get(self, name: str) -> RegistryEntry:
        if name not in self._entries:
            raise KeyError(f"registry has no entry {name!r} "
                           f"(entries: {sorted(self._entries)})")
        return self._entries[name]

    def names(self) -> list[str]:
        """Entry names in registration order."""
        return list(self._entries)

    def entries(self) -> Iterator[RegistryEntry]:
        yield from self._entries.values()

    def match(self, matcher: Mapping, *,
              compat: str | None = None) -> list[RegistryEntry]:
        """Entries whose metadata equals every ``matcher`` item
        (registration order). ``compat`` additionally filters to entries
        servable by an engine with that compat key."""
        out = []
        for e in self._entries.values():
            if compat is not None and e.compat != compat:
                continue
            if all(e.meta.get(k) == v for k, v in matcher.items()):
                out.append(e)
        return out

    def resolve(self, request: "str | Mapping | None" = None, *,
                compat: str | None = None,
                default: str | None = None) -> RegistryEntry:
        """Admission-time variant selection.

        ``request`` is an entry name (exact), a metadata matcher dict
        (must match exactly one entry), or ``None`` → the ``default``
        entry name when given, else the registry's sole entry. Raises
        ``LookupError`` when nothing matches and ``ValueError`` when the
        request is ambiguous or the matched entry is incompatible with
        the serving engine's ``compat`` key — admission REJECTS such
        streams rather than guessing a variant.
        """
        if request is None:
            if default is not None:
                return self.resolve(default, compat=compat)
            if len(self._entries) == 1:
                return self.resolve(next(iter(self._entries)), compat=compat)
            raise ValueError(
                f"no variant requested and no default entry set, with "
                f"{len(self._entries)} entries registered — the request "
                f"is ambiguous")
        if isinstance(request, str):
            if request not in self._entries:
                raise LookupError(
                    f"no registry entry named {request!r} "
                    f"(entries: {sorted(self._entries)})")
            entry = self._entries[request]
            if compat is not None and entry.compat != compat:
                raise ValueError(
                    f"entry {request!r} is incompatible with the serving "
                    f"engine (compat {entry.compat_digest} != engine "
                    f"{compat_digest(compat)}) — its replay geometry or "
                    f"architecture differs")
            return entry
        if isinstance(request, Mapping):
            hits = self.match(request, compat=compat)
            if not hits:
                raise LookupError(
                    f"no registry entry matches {dict(request)!r} "
                    f"(entries: {sorted(self._entries)})")
            if len(hits) > 1:
                raise ValueError(
                    f"variant request {dict(request)!r} is ambiguous: "
                    f"matches {[e.name for e in hits]}")
            return hits[0]
        raise TypeError(f"variant request must be a name, a matcher "
                        f"mapping, or None — got {type(request).__name__}")
