"""Spiking-CNN substrate: LIF neurons with surrogate gradients, conv/BN/pool
layers, and the paper's backbone network (4× [conv→BN→LIF→maxpool] → FC512 →
LIF → FC10, rate decoding). Pure functional JAX: params/state are dict
pytrees, time handled with lax.scan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict
State = dict

# ---------------------------------------------------------------------------
# Surrogate-gradient spike function (ATan surrogate, SpikingJelly's default)
# ---------------------------------------------------------------------------

_SG_ALPHA = 2.0


@jax.custom_vjp
def spike_fn(x: jax.Array) -> jax.Array:
    """Heaviside spike with ATan surrogate gradient."""
    return (x > 0.0).astype(x.dtype)


def _spike_fwd(x):
    return spike_fn(x), x


def _spike_bwd(x, g):
    # d/dx [ (1/pi) * atan(pi/2 * alpha * x) + 1/2 ]
    sg = _SG_ALPHA / (2.0 * (1.0 + (0.5 * math.pi * _SG_ALPHA * x) ** 2))
    return (g * sg,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


# ---------------------------------------------------------------------------
# LIF dynamics
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LIFConfig:
    tau: float = 2.0          # membrane time constant (in timesteps)
    v_threshold: float = 1.0
    soft_reset: bool = True   # subtract threshold on spike (vs reset to 0)


def lif_step(v: jax.Array, x: jax.Array, cfg: LIFConfig) -> tuple[jax.Array, jax.Array]:
    """One LIF update. Returns (new membrane, spikes)."""
    v = v + (x - v) / cfg.tau
    s = spike_fn(v - cfg.v_threshold)
    if cfg.soft_reset:
        v = v - s * cfg.v_threshold
    else:
        v = v * (1.0 - s)
    return v, s


def lif_over_time(x: jax.Array, cfg: LIFConfig) -> jax.Array:
    """Run LIF over the time axis. x: [T, B, ...] → spikes [T, B, ...]."""
    v0 = jnp.zeros_like(x[0])

    def step(v, xt):
        v, s = lif_step(v, xt, cfg)
        return v, s

    _, spikes = lax.scan(step, v0, x)
    return spikes


# ---------------------------------------------------------------------------
# Stateless layer helpers (params as dicts)
# ---------------------------------------------------------------------------

def conv_init(key, kh, kw, c_in, c_out, dtype=jnp.float32) -> Params:
    fan_in = kh * kw * c_in
    w = jax.random.normal(key, (kh, kw, c_in, c_out), dtype) * math.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((c_out,), dtype)}


def conv_apply(p: Params, x: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """x: [N, H, W, C] NHWC."""
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def dense_init(key, d_in, d_out, dtype=jnp.float32) -> Params:
    w = jax.random.normal(key, (d_in, d_out), dtype) * math.sqrt(2.0 / d_in)
    return {"w": w, "b": jnp.zeros((d_out,), dtype)}


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def bn_init(c: int, dtype=jnp.float32) -> tuple[Params, State]:
    params = {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}
    state = {"mean": jnp.zeros((c,), dtype), "var": jnp.ones((c,), dtype)}
    return params, state


def bn_apply(p: Params, s: State, x: jax.Array, *, train: bool,
             momentum: float = 0.9, eps: float = 1e-5) -> tuple[jax.Array, State]:
    """BatchNorm over all axes but the last (channels)."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_s = {
            "mean": momentum * s["mean"] + (1 - momentum) * mean,
            "var": momentum * s["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    inv = lax.rsqrt(var + eps)
    y = (x - mean) * inv * p["scale"] + p["bias"]
    return y, new_s


def max_pool(x: jax.Array, window: int = 2) -> jax.Array:
    """x: [N, H, W, C] → 2x2 max pool, stride=window."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, window, window, 1),
        padding="VALID")


# ---------------------------------------------------------------------------
# The paper's backbone spiking CNN
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpikingCNNConfig:
    """4 conv blocks (conv→BN→LIF→pool) + FC(512)→LIF→FC(n_classes).

    The first block can be replaced by the P²M hardware layer (see
    p2m_layer.py); in that case `first_layer_external=True` and the model
    consumes the P²M layer's (already-spiking, possibly multi-bit counts)
    output directly.
    """
    in_channels: int = 2                        # DVS ON/OFF
    channels: tuple[int, ...] = (16, 32, 64, 64)
    kernel_size: int = 3
    first_stride: int = 1
    fc_hidden: int = 512
    n_classes: int = 11
    input_hw: tuple[int, int] = (128, 128)
    lif: LIFConfig = field(default_factory=LIFConfig)
    first_layer_external: bool = False          # True when P²M supplies layer 1

    @property
    def n_conv(self) -> int:
        return len(self.channels)


def spiking_cnn_init(key: jax.Array, cfg: SpikingCNNConfig) -> tuple[Params, State]:
    keys = jax.random.split(key, cfg.n_conv + 2)
    params: Params = {}
    state: State = {}
    h, w = cfg.input_hw
    c_in = cfg.in_channels
    start = 0
    if cfg.first_layer_external:
        # layer 1 lives in the pixel array (P²M); the backbone starts at conv2.
        c_in = cfg.channels[0]
        h //= (2 * cfg.first_stride)   # P²M stride + its pool
        w //= (2 * cfg.first_stride)
        start = 1
    for i in range(start, cfg.n_conv):
        stride = cfg.first_stride if i == 0 else 1
        params[f"conv{i}"] = conv_init(keys[i], cfg.kernel_size, cfg.kernel_size,
                                       c_in, cfg.channels[i])
        bnp, bns = bn_init(cfg.channels[i])
        params[f"bn{i}"] = bnp
        state[f"bn{i}"] = bns
        c_in = cfg.channels[i]
        h = h // (2 * stride)
        w = w // (2 * stride)
    flat = h * w * c_in
    params["fc0"] = dense_init(keys[-2], flat, cfg.fc_hidden)
    params["fc1"] = dense_init(keys[-1], cfg.fc_hidden, cfg.n_classes)
    return params, state


def spiking_cnn_apply(params: Params, state: State, x: jax.Array,
                      cfg: SpikingCNNConfig, *, train: bool
                      ) -> tuple[jax.Array, State, dict[str, jax.Array]]:
    """Forward over time.

    x: [B, T, H, W, C]  (C = in_channels, or channels[0] counts if
    first_layer_external). Returns (logits [B, n_classes], new_state,
    aux) where aux["spikes/<layer>"] holds total spike counts (for the
    energy/bandwidth model) and aux["synops/<layer>"] synaptic-operation
    counts.
    """
    B, T = x.shape[0], x.shape[1]
    aux: dict[str, jax.Array] = {}
    new_state: State = {}
    # [B,T,...] → [T,B,...] so scans run over axis 0
    h = jnp.moveaxis(x, 1, 0)
    start = 1 if cfg.first_layer_external else 0
    for i in range(start, cfg.n_conv):
        stride = cfg.first_stride if i == 0 else 1
        tb = h.reshape((T * B,) + h.shape[2:])
        y = conv_apply(params[f"conv{i}"], tb, stride=stride)
        # synops: each output element consumed k*k*c_in inputs; count sparsity
        fan_in = cfg.kernel_size * cfg.kernel_size * h.shape[-1]
        aux[f"synops/conv{i}"] = jax.lax.stop_gradient(
            jnp.sum(h != 0) * fan_in * (cfg.channels[i] / h.shape[-1]))
        y, bns = bn_apply(params[f"bn{i}"], state[f"bn{i}"], y, train=train)
        new_state[f"bn{i}"] = bns
        y = y.reshape((T, B) + y.shape[1:])
        s = lif_over_time(y, cfg.lif)
        tb = s.reshape((T * B,) + s.shape[2:])
        tb = max_pool(tb)
        h = tb.reshape((T, B) + tb.shape[1:])
        aux[f"spikes/conv{i}"] = jax.lax.stop_gradient(jnp.sum(s))
    # FC head
    flat = h.reshape((T, B, -1))
    z = dense_apply(params["fc0"], flat)
    aux["synops/fc0"] = jax.lax.stop_gradient(
        jnp.sum(flat != 0).astype(jnp.float32) * params["fc0"]["w"].shape[1])
    s = lif_over_time(z, cfg.lif)
    aux["spikes/fc0"] = jax.lax.stop_gradient(jnp.sum(s))
    logits_t = dense_apply(params["fc1"], s)
    aux["synops/fc1"] = jax.lax.stop_gradient(
        jnp.sum(s != 0).astype(jnp.float32) * params["fc1"]["w"].shape[1])
    logits = jnp.mean(logits_t, axis=0)   # rate decoding
    return logits, new_state, aux


# ---------------------------------------------------------------------------
# streaming (one-coarse-frame-at-a-time) evaluation
# ---------------------------------------------------------------------------

def _stream_shapes(cfg: SpikingCNNConfig) -> tuple[dict, int]:
    """Per-layer LIF membrane shapes (pre-pool conv outputs + FC hidden)
    and the layer the stream starts at — mirrors spiking_cnn_init's shape
    walk so streaming state lines up with the trained params."""
    h, w = cfg.input_hw
    c_in = cfg.in_channels
    start = 0
    if cfg.first_layer_external:
        c_in = cfg.channels[0]
        h //= (2 * cfg.first_stride)
        w //= (2 * cfg.first_stride)
        start = 1
    shapes = {}
    for i in range(start, cfg.n_conv):
        stride = cfg.first_stride if i == 0 else 1
        h_c, w_c = h // stride, w // stride       # conv output (SAME pad)
        shapes[f"lif{i}"] = (h_c, w_c, cfg.channels[i])
        h, w = h_c // 2, w_c // 2                 # 2x pool
        c_in = cfg.channels[i]
    shapes["lif_fc0"] = (cfg.fc_hidden,)
    return shapes, start


def spiking_cnn_stream_init(cfg: SpikingCNNConfig, batch: int) -> State:
    """Zero LIF membranes for step-wise (online) evaluation — one state
    tree per serving lane batch. ``lif_over_time`` starts every scan from
    v=0, so a fresh stream state reproduces the batched forward exactly."""
    shapes, _ = _stream_shapes(cfg)
    return {k: jnp.zeros((batch,) + s) for k, s in shapes.items()}


def spiking_cnn_stream_step(params: Params, state: State, mem: State,
                            x_t: jax.Array, cfg: SpikingCNNConfig
                            ) -> tuple[jax.Array, State]:
    """One coarse timestep of the backbone with explicit LIF state.

    ``x_t`` is a single coarse frame [B, H, W, C] (what
    ``spiking_cnn_apply`` sees at one index of its time axis); ``mem``
    carries every layer's membrane between calls. Stepping T frames
    through this function and averaging the returned per-step logits is
    IDENTICAL to ``spiking_cnn_apply(..., train=False)`` on the stacked
    [B, T, ...] tensor (conv/BN are stateless at eval, LIF scans are
    causal) — the parity the online serving engine (repro.stream) relies
    on and tests/test_streaming.py pins.
    """
    _, start = _stream_shapes(cfg)
    new_mem: State = {}
    h = x_t
    for i in range(start, cfg.n_conv):
        stride = cfg.first_stride if i == 0 else 1
        y = conv_apply(params[f"conv{i}"], h, stride=stride)
        y, _ = bn_apply(params[f"bn{i}"], state[f"bn{i}"], y, train=False)
        v, s = lif_step(mem[f"lif{i}"], y, cfg.lif)
        new_mem[f"lif{i}"] = v
        h = max_pool(s)
    z = dense_apply(params["fc0"], h.reshape((h.shape[0], -1)))
    v, s = lif_step(mem["lif_fc0"], z, cfg.lif)
    new_mem["lif_fc0"] = v
    logits_t = dense_apply(params["fc1"], s)
    return logits_t, new_mem


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
