"""Behavioral model of the in-pixel analog MAC unit (paper §2, Fig 1).

The paper models its first conv layer with "a curve-fitting function that
accounts for non-linearity, non-ideality, and process variations based on the
spice simulation results" (GF22FDX). We reproduce that modeling strategy with a
behavioral stand-in, since no PDK is available offline:

  * weights map to transistor geometries with finite granularity → signed
    uniform quantization to ``weight_levels`` levels (W/L can only be drawn at
    discrete sizes);
  * the charge delivered per input event is a *non-linear* function of the
    present capacitor voltage (transistor drain current depends on V_DS): as
    V_C approaches the rail the step compresses.  We use the paper's own
    device-free abstraction — a cubic curve fit ``f(x) = c1*x + c3*x**3``
    applied to the ideal weighted sum, plus a rail clamp;
  * process variation perturbs the fitted coefficients per compute unit
    (per output filter): multiplicative gain sigma on c1 and additive offset.

Everything is differentiable so the network can be trained *through* the
hardware model, exactly as the P²M-constrained algorithmic framework does.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AnalogConfig:
    """Behavioral parameters of the analog MAC compute unit."""
    vdd: float = 0.8                 # rail voltage (V), 22FDX-ish
    v_precharge: float = 0.4         # capacitor precharge = VDD/2 (mid-rail)
    dv_unit: float = 0.010           # ideal voltage step for |w| = 1 and 1 event (V)
    weight_levels: int = 16          # 4-bit transistor geometry granularity
    w_clip: float = 1.0              # weights clipped to [-w_clip, w_clip]
    # cubic curve-fit coefficients (paper fits these to SPICE; we fix
    # plausible values that compress by ~8% at full swing)
    c1: float = 0.96
    c3: float = -0.35
    # process variation (sigma of per-filter perturbations)
    pv_gain_sigma: float = 0.02
    pv_offset_sigma_mv: float = 1.5
    enable_nonlinearity: bool = True
    enable_process_variation: bool = True


def quantize_weights(w: jax.Array, cfg: AnalogConfig) -> jax.Array:
    """Signed uniform quantization to transistor geometry levels.

    Straight-through estimator: gradients flow as identity so the model
    trains through the quantizer.
    """
    w = jnp.clip(w, -cfg.w_clip, cfg.w_clip)
    scale = cfg.w_clip / (cfg.weight_levels // 2)
    q = jnp.round(w / scale) * scale
    # straight-through: forward quantized, backward identity
    return w + jax.lax.stop_gradient(q - w)


def sample_process_variation(key: jax.Array, n_filters: int,
                             cfg: AnalogConfig) -> dict[str, jax.Array]:
    """Per-filter (per compute unit) transfer-curve perturbations."""
    kg, ko = jax.random.split(key)
    gain = 1.0 + cfg.pv_gain_sigma * jax.random.normal(kg, (n_filters,))
    offset = (cfg.pv_offset_sigma_mv * 1e-3) * jax.random.normal(ko, (n_filters,))
    if not cfg.enable_process_variation:
        gain = jnp.ones((n_filters,))
        offset = jnp.zeros((n_filters,))
    return {"gain": gain, "offset": offset}


def identity_process_variation(n_filters: int) -> dict[str, jax.Array]:
    return {"gain": jnp.ones((n_filters,)), "offset": jnp.zeros((n_filters,))}


def transfer_curve(x: jax.Array, cfg: AnalogConfig,
                   pv: dict[str, jax.Array] | None = None) -> jax.Array:
    """Curve-fit from ideal weighted sum (in volts of swing) to realized swing.

    ``x`` is the ideal accumulated voltage swing (signed, volts). The last
    axis of ``x`` is the filter axis when ``pv`` is given.
    """
    if cfg.enable_nonlinearity:
        half_swing = cfg.vdd / 2.0
        xn = x / half_swing
        y = (cfg.c1 * xn + cfg.c3 * xn**3) * half_swing
    else:
        y = x
    if pv is not None:
        y = y * pv["gain"] + pv["offset"]
    # rail clamp: capacitor voltage cannot leave [0, VDD]
    return jnp.clip(y, -cfg.v_precharge, cfg.vdd - cfg.v_precharge)


def step_nonlinearity(v: jax.Array, cfg: AnalogConfig) -> jax.Array:
    """Per-event charge-step compression factor g(V) ∈ (0, 1].

    Models the drain-current dependence on V_DS: steps shrink as the
    capacitor approaches either rail. v is the *swing* (v=0 at precharge).
    """
    if not cfg.enable_nonlinearity:
        return jnp.ones_like(v)
    half_swing = cfg.vdd / 2.0
    return jnp.clip(1.0 - (v / half_swing) ** 2, 0.05, 1.0)
