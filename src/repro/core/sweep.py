"""Batched co-design sweep engine (paper Fig 2 / Fig 4 / Table 1).

The paper's central contribution is the trade-off analysis across the three
analog MAC circuit configs (basic / isolation-switch / nullified) and the
integration time T_INTG. This module evaluates the FULL grid

    circuit-variant × T_INTG (× n_sub)

in ONE process. The variant axis is *generalized* (core/variant_grid.py):
a declarative axis registry expands any combination of ``circuit``,
``null_mismatch``, ``v_threshold`` and process-variation ``sigma`` into a
flat stacked variant list — a stacked leading config axis runs through the
leak linearization (leakage.LeakCoeffs carries the per-variant threshold
and sigma legs), the P²M forward paths, and the batched backbone
finetune+eval — so each outer cell is one jitted compile covering every
variant. Inside the jit the variant axis runs under ``lax.map`` (see
:func:`_map_cfgs`: a width-invariant per-variant program is what makes
sharded runs bit-identical). T_INTG and ``n_sub`` change tensor shapes,
so they stay in the outer python loop.

The stacked axis is also *mesh-shardable* (core/sweep_exec.py): pass a
``SweepExecutor(devices=n)`` and the jitted finetune/eval steps run under
``shard_map`` over a 1-D ``"cfg"`` mesh — one variant-shard per device,
``n_cfg`` padded up to the device count and unpadded when the records are
read back, record-for-record identical to the single-device run.

Protocol per grid point (mirrors codesign.py, paper §3):
  phase 1  pretrain the whole net once at the longest T_INTG, no circuit
           constraints (shared across ALL grid points);
  phase 2  per outer cell: constrain layer 1 under every variant at once,
           finetune the stacked variant axis in one jitted step (sharded
           over the mesh), then batch-evaluate accuracy / bandwidth /
           energy; retention-error surfaces come from the closed-form
           leak ODE.

Phase 2 comes in TWO protocols:

  ``protocol="frozen"``    the paper's protocol — layer 1 is frozen, only
                           the n_cfg backbones train (mapped per variant);
  ``protocol="unfrozen"``  each variant additionally learns its OWN
                           layer-1 weights: the layer-1 params gain a
                           stacked [n_cfg] axis and the jitted step
                           differentiates through the curvefit forward
                           (surrogate spike gradient, straight-through
                           quantizer), re-linearizing each variant's leak
                           from its current weights every step. Layer 1
                           may train at its own LR (SweepConfig.lr_p2m)
                           via :func:`joint_optimizer`.

``run_protocols`` runs both off one shared pretrain and
``protocols_artifact`` merges them into one ``p2m-codesign-sweep/v3``
artifact (per-record ``"variant"`` dict, see docs/sweep.md) so the
co-design optimum can be compared across protocols.
``codesign.run_sweep`` is a thin single-circuit wrapper over this engine.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import analog as analog_mod
from repro.core import energy as energy_mod
from repro.core import leakage, p2m_layer, snn, variant_grid
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.sweep_exec import P_CFG, P_REP, SweepExecutor
from repro.data import sources as sources_mod
from repro.optim import adamw, clip_by_global_norm
from repro.optim.optimizers import Optimizer, apply_updates

Params = dict

SCHEMA = "p2m-codesign-sweep/v1"
SCHEMA_V2 = "p2m-codesign-sweep/v2"
SCHEMA_V3 = "p2m-codesign-sweep/v3"
PROTOCOLS = ("frozen", "unfrozen")
RETENTION_V0 = 0.2     # probe swing (V) for the Fig 4a retention surfaces


def resolve_protocols(arg: str) -> tuple[str, ...]:
    """CLI protocol argument → protocol tuple ("both" expands to all)."""
    return PROTOCOLS if arg == "both" else (arg,)


def _check_protocol(protocol: str) -> None:
    if protocol not in PROTOCOLS:
        raise ValueError(f"unknown protocol {protocol!r} "
                         f"(expected one of {PROTOCOLS})")


# ---------------------------------------------------------------------------
# the grid
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepGrid:
    """The co-design grid: circuits × every registered variant axis.

    Each axis field holds the value tuple to sweep; an EMPTY tuple means
    the axis is not swept (variants keep the base config's value). Axis
    semantics live in the registry (core/variant_grid.py): ``null_mismatch``
    expands only the NULLIFIED circuit (configs (a)/(b) have no nullifier,
    so mismatch variants would be duplicates); ``v_threshold``/``sigma``
    expand every circuit; ``n_sub`` is shape-changing and joins T_INTG in
    the outer python loop instead of the stacked axis.
    """
    circuits: tuple[CircuitConfig, ...] = (
        CircuitConfig.BASIC, CircuitConfig.SWITCH, CircuitConfig.NULLIFIED)
    t_intg_grid_ms: tuple[float, ...] = (1.0, 10.0, 100.0, 1000.0)
    null_mismatch: tuple[float, ...] = (0.06,)
    v_threshold: tuple[float, ...] = ()
    sigma: tuple[float, ...] = ()
    n_sub: tuple[int, ...] = ()


def paper_grid() -> SweepGrid:
    """All three circuits × the paper's T_INTG grid."""
    return SweepGrid()


def fast_grid() -> SweepGrid:
    return SweepGrid(t_intg_grid_ms=(10.0, 1000.0))


def expand_leak_configs(grid: SweepGrid, base: LeakageConfig
                        ) -> tuple[LeakageConfig, ...]:
    """Flatten (circuits × active stacked axes) into the stacked config
    axis — registry-driven, see :func:`variant_grid.expand_variants`."""
    return variant_grid.expand_variants(grid, base)


def config_label(lc: LeakageConfig) -> str:
    return variant_grid.variant_label(lc)


# ---------------------------------------------------------------------------
# batched layer-1 → backbone plumbing
# ---------------------------------------------------------------------------

# one utility, one home: replicate a pytree onto a leading config axis
_stack_tree = p2m_layer.stack_p2m_params


def _map_cfgs(fn: Callable, *stacked):
    """Run ``fn`` over the leading [n_cfg] axis of the stacked arguments
    with ``lax.map`` (scan), not ``vmap``.

    The per-variant program is then IDENTICAL at every execution width —
    a single device mapping n_cfg variants and a mesh shard mapping
    n_cfg/devices each run the same compiled body per variant, which is
    what makes sharded and unsharded sweeps bit-for-bit identical (XLA
    tiles width-batched conv gradients differently per width, so a vmapped
    body would drift at ~1e-8/step between device counts). Cross-variant
    parallelism comes from the cfg mesh; within a variant the batch/time/
    spatial axes keep the hardware busy.
    """
    return lax.map(lambda args: fn(*args), stacked)


def _layer1_coarse(p2m_params: Params, events: jax.Array, model_cfg,
                   leak_cfgs: tuple[LeakageConfig, ...]
                   ) -> tuple[jax.Array, dict]:
    """P²M layer under every circuit config + pool + coarsen.

    events [B, T, n_sub, H, W, Cin] → coarse [n_cfg, B, Tc, H/2, W/2, F]
    plus the per-config spike statistics the energy model needs.

    Mode-dispatching (scan/curvefit/kernel) stacked forward — the physics
    validator path. The ENGINE's jitted steps use the coeffs-based
    :func:`_layer1_coarse_one` under :func:`_map_cfgs` instead, which is
    what lets the stacked axis shard over a device mesh (the per-variant numerics travel
    as arrays, not as a python tuple baked into the trace).
    """
    cfg = model_cfg.p2m
    spikes, _ = p2m_layer.p2m_apply_stacked(p2m_params, events, cfg,
                                            leak_cfgs)
    G, B, T = spikes.shape[:3]
    tb = spikes.reshape((G * B * T,) + spikes.shape[3:])
    tb = snn.max_pool(tb)
    spikes_p = tb.reshape((G, B, T) + tb.shape[1:])
    group = model_cfg.coarsen_group()
    coarse = p2m_layer.coarsen_spikes(
        spikes_p.reshape((G * B, T) + spikes_p.shape[3:]), group)
    coarse = coarse.reshape((G, B, T // group) + coarse.shape[2:])
    k = cfg.kernel_size
    # spike + MAC counts on the post-pool map, matching the historical
    # codesign.model_apply accounting (the 2x pool happens in-pixel, so the
    # pooled spikes are what leaves the sensor) — single-circuit engine
    # runs must reproduce its records
    out_elems = float(B * T) * float(math.prod(spikes_p.shape[3:]))
    l1 = {
        "spikes/p2m": lax.stop_gradient(
            jnp.sum(spikes_p, axis=tuple(range(1, spikes_p.ndim)))),  # [G]
        "events/in": lax.stop_gradient(jnp.sum(events)),           # scalar
        "macs/p2m": jnp.asarray(out_elems * k * k * cfg.in_channels,
                                jnp.float32),                      # scalar
    }
    return coarse, l1


def _layer1_coarse_one(p2m_params: Params, events: jax.Array, model_cfg,
                       coeffs: leakage.LeakCoeffs
                       ) -> tuple[jax.Array, dict]:
    """Single-config differentiable P²M layer → pool → coarsen.

    The variant enters only through numeric ``coeffs`` (leak linearization,
    comparator threshold, process-variation sigma), so this function is
    vmap-able over a stacked config axis, shard_map-able over the cfg mesh,
    AND differentiable w.r.t. the layer-1 params — the leak linearization
    is recomputed from the current (quantized) weights on every call.
    Per-config mirror of :func:`_layer1_coarse`; the spike/MAC accounting
    matches it so both protocols feed identical bandwidth/energy
    bookkeeping.
    """
    spikes, _ = p2m_layer.p2m_forward_curvefit_coeffs(p2m_params, events,
                                                      model_cfg.p2m, coeffs)
    return _pool_coarsen_l1(spikes, events, model_cfg)


def _pool_coarsen_l1(spikes: jax.Array, events: jax.Array, model_cfg
                     ) -> tuple[jax.Array, dict]:
    """Shared tail of the single-config layer-1 paths: 2x pool, coarsen to
    the backbone grid, and the spike/MAC bookkeeping contract."""
    cfg = model_cfg.p2m
    B, T = spikes.shape[:2]
    tb = spikes.reshape((B * T,) + spikes.shape[2:])
    tb = snn.max_pool(tb)
    spikes_p = tb.reshape((B, T) + tb.shape[1:])
    coarse = p2m_layer.coarsen_spikes(spikes_p, model_cfg.coarsen_group())
    k = cfg.kernel_size
    out_elems = float(B * T) * float(math.prod(spikes_p.shape[2:]))
    l1 = {
        "spikes/p2m": lax.stop_gradient(jnp.sum(spikes_p)),        # scalar
        "events/in": lax.stop_gradient(jnp.sum(events)),           # scalar
        "macs/p2m": jnp.asarray(out_elems * k * k * cfg.in_channels,
                                jnp.float32),                      # scalar
    }
    return coarse, l1


def _layer1_coarse_frozen(p2m_params: Params, events: jax.Array, model_cfg,
                          co_s: leakage.LeakCoeffs
                          ) -> tuple[jax.Array, dict]:
    """Frozen-protocol stacked layer 1: ideal conv ONCE, per-variant reduce.

    With shared (frozen) layer-1 weights the expensive im2col conv of the
    curvefit forward is variant-independent, so it is hoisted OUT of the
    per-variant ``_map_cfgs`` loop — only the [n_sub, C_out] decay
    reduction, transfer curve, comparator and pooling run per variant
    (PR-1's "one conv + n_cfg cheap einsums" shape, now width-invariant
    and mesh-shardable: the hoisted conv is replicated, identical on every
    device). Returns (coarse [n_cfg, ...], l1 stats stacked [n_cfg]).
    """
    cfg = model_cfg.p2m
    w_q = p2m_layer.effective_weights(p2m_params, cfg)
    ideal = p2m_layer.curvefit_ideal(events, cfg, w_q)

    def per_cfg(co):
        lk = leakage.leak_params_from_coeffs(w_q, co)
        v_pre = p2m_layer.curvefit_reduce(p2m_params, cfg, ideal, lk,
                                          events.shape[0])
        spikes = snn.spike_fn(v_pre - co.v_threshold)
        return _pool_coarsen_l1(spikes, events, model_cfg)

    return _map_cfgs(per_cfg, co_s)


def _merge_grouped_l1(l1_s: dict) -> dict:
    """per-variant-mapped l1 stats → the engine contract: per-config
    spikes [G], config-independent events/MACs as scalars."""
    return {"spikes/p2m": l1_s["spikes/p2m"],
            "events/in": l1_s["events/in"][0],
            "macs/p2m": l1_s["macs/p2m"][0]}


def joint_optimizer(opt_backbone: Optimizer, opt_p2m: Optimizer) -> Optimizer:
    """Per-group optimizer for the unfrozen joint update: the layer-1 leaf
    group steps with ``opt_p2m`` (``SweepConfig.lr_p2m``), the backbone
    group with ``opt_backbone``. With identical member optimizers the
    update math matches a single optimizer over the joint tree leaf-for-
    leaf (AdamW state is per-leaf; only the state *structure* changes), so
    ``lr_p2m=None ≡ lr`` is a pure refactor of the PR-2 behavior."""
    def init(params: Params) -> Params:
        return {"p2m": opt_p2m.init(params["p2m"]),
                "backbone": opt_backbone.init(params["backbone"])}

    def update(grads, state, params):
        up_p, st_p = opt_p2m.update(grads["p2m"], state["p2m"],
                                    params["p2m"])
        up_b, st_b = opt_backbone.update(grads["backbone"],
                                         state["backbone"],
                                         params["backbone"])
        return ({"p2m": up_p, "backbone": up_b},
                {"p2m": st_p, "backbone": st_b})

    return Optimizer(init=init, update=update)


def _check_curvefit(model_cfg, protocol: str) -> None:
    if model_cfg.p2m.mode != "curvefit":
        raise ValueError(
            f"the batched {protocol} step trains through the curvefit "
            f"forward (the coeffs-based path that vectorizes and shards "
            f"over the variant axis); got p2m.mode={model_cfg.p2m.mode!r}. "
            f"Use p2m_apply_stacked for scan/kernel physics validation.")


def make_batched_finetune_step(model_cfg, leak_cfgs: tuple[LeakageConfig, ...],
                               opt, protocol: str = "frozen",
                               executor: SweepExecutor | None = None
                               ) -> Callable:
    """One jitted phase-2 step over all n_cfg circuit variants at once.

    Unified signature for both protocols::

        p2m_ps, bb_params_s, opt_state_s, state_s, metrics, l1 = step(
            p2m_ps, bb_params_s, opt_state_s, state_s, events, labels)

    ``protocol="frozen"`` (paper §3): ``p2m_ps`` is the SHARED layer-1
    params, returned untouched — its stacked forward runs outside the
    gradient and only the backbones update (mapped per variant).
    ``opt_state_s`` is
    the backbone-only optimizer state.

    ``protocol="unfrozen"``: ``p2m_ps`` carries a leading [n_cfg] axis and
    the update is a JOINT per-variant step on ``{"p2m", "backbone"}`` — each
    variant differentiates through its own curvefit layer-1 forward
    (surrogate spike gradient, straight-through quantizer), re-linearizing
    its leak from the current weights inside the jitted step.
    ``opt_state_s`` is the joint optimizer state (``opt`` may be a
    :func:`joint_optimizer` for a split layer-1 LR).

    With a sharded ``executor`` the step body runs under ``shard_map``
    over the 1-D cfg mesh: every stacked argument/output is partitioned on
    its leading axis, events/labels (and the shared frozen layer-1 params)
    are replicated. The caller must pass stacked trees padded to
    ``executor.padded_size(n_cfg)`` lanes (see ``run_grid``); the variant
    coefficients are padded here. The body is IDENTICAL with and without
    sharding, which is what makes sharded and single-device sweeps
    record-for-record comparable.
    """
    _check_protocol(protocol)
    _check_curvefit(model_cfg, protocol)
    ex = executor or SweepExecutor()
    bb_cfg = model_cfg.backbone
    coeffs_s = leakage.stacked_leak_coeffs(leak_cfgs,
                                           model_cfg.p2m.v_threshold)
    coeffs_s = ex.pad_stacked(coeffs_s, len(leak_cfgs))

    if protocol == "frozen":
        def bb_loss(bb_params, state, coarse, labels):
            logits, new_state, aux = snn.spiking_cnn_apply(
                bb_params, state, coarse, bb_cfg, train=True)
            loss = snn.cross_entropy(logits, labels)
            return loss, (new_state, aux, logits)

        def inner(co_s, p2m_params, bb_params_s, opt_state_s, state_s,
                  events, labels):
            coarse_s, l1_s = _layer1_coarse_frozen(p2m_params, events,
                                                   model_cfg, co_s)
            coarse_s = lax.stop_gradient(coarse_s)

            def per_cfg(bb_p, o_s, st, coarse):
                (loss, (new_st, aux, logits)), grads = jax.value_and_grad(
                    bb_loss, has_aux=True)(bb_p, st, coarse, labels)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                updates, o_s = opt.update(grads, o_s, bb_p)
                bb_p = apply_updates(bb_p, updates)
                metrics = {"loss": loss, "gnorm": gnorm,
                           "acc": snn.accuracy(logits, labels)}
                return bb_p, o_s, new_st, metrics

            bb_params_s, opt_state_s, state_s, metrics = _map_cfgs(
                per_cfg, bb_params_s, opt_state_s, state_s, coarse_s)
            return bb_params_s, opt_state_s, state_s, metrics, l1_s

        inner = ex.shard(
            inner,
            in_specs=(P_CFG, P_REP, P_CFG, P_CFG, P_CFG, P_REP, P_REP),
            out_specs=(P_CFG, P_CFG, P_CFG, P_CFG, P_CFG))
        jitted = jax.jit(inner)

        def step(p2m_params, bb_params_s, opt_state_s, state_s, events,
                 labels):
            bb_params_s, opt_state_s, state_s, metrics, l1_s = jitted(
                coeffs_s, p2m_params, bb_params_s, opt_state_s, state_s,
                events, labels)
            return (p2m_params, bb_params_s, opt_state_s, state_s, metrics,
                    _merge_grouped_l1(l1_s))

        return step

    def joint_loss(joint, state, events, labels, coeffs):
        coarse, l1 = _layer1_coarse_one(joint["p2m"], events, model_cfg,
                                        coeffs)
        logits, new_state, aux = snn.spiking_cnn_apply(
            joint["backbone"], state, coarse, bb_cfg, train=True)
        loss = snn.cross_entropy(logits, labels)
        return loss, (new_state, aux, logits, l1)

    def inner(co_s, p2m_params_s, bb_params_s, opt_state_s, state_s,
              events, labels):
        def per_cfg(p2m_p, bb_p, o_s, st, coeffs):
            joint = {"p2m": p2m_p, "backbone": bb_p}
            (loss, (new_st, aux, logits, l1)), grads = jax.value_and_grad(
                joint_loss, has_aux=True)(joint, st, events, labels, coeffs)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            updates, o_s = opt.update(grads, o_s, joint)
            joint = apply_updates(joint, updates)
            metrics = {"loss": loss, "gnorm": gnorm,
                       "acc": snn.accuracy(logits, labels)}
            return joint["p2m"], joint["backbone"], o_s, new_st, metrics, l1

        return _map_cfgs(per_cfg, p2m_params_s, bb_params_s, opt_state_s,
                         state_s, co_s)

    inner = ex.shard(
        inner,
        in_specs=(P_CFG, P_CFG, P_CFG, P_CFG, P_CFG, P_REP, P_REP),
        out_specs=(P_CFG, P_CFG, P_CFG, P_CFG, P_CFG, P_CFG))
    jitted = jax.jit(inner)

    def step(p2m_params_s, bb_params_s, opt_state_s, state_s, events,
             labels):
        (p2m_params_s, bb_params_s, opt_state_s, state_s, metrics,
         l1_s) = jitted(coeffs_s, p2m_params_s, bb_params_s, opt_state_s,
                        state_s, events, labels)
        return (p2m_params_s, bb_params_s, opt_state_s, state_s, metrics,
                _merge_grouped_l1(l1_s))

    return step


def make_batched_eval(model_cfg, leak_cfgs: tuple[LeakageConfig, ...],
                      protocol: str = "frozen",
                      executor: SweepExecutor | None = None) -> Callable:
    """Jitted batched eval: per-config accuracy/loss + backbone aux + the
    layer-1 spike statistics feeding bandwidth/energy.

    With ``protocol="unfrozen"`` the first argument carries per-config
    layer-1 params (leading [n_cfg] axis) and the whole forward maps over
    the variant axis;
    the returned (metrics, aux, l1) contract is identical either way. A
    sharded ``executor`` partitions the stacked axis over the cfg mesh
    exactly like :func:`make_batched_finetune_step`.
    """
    _check_protocol(protocol)
    _check_curvefit(model_cfg, protocol)
    ex = executor or SweepExecutor()
    bb_cfg = model_cfg.backbone
    coeffs_s = leakage.stacked_leak_coeffs(leak_cfgs,
                                           model_cfg.p2m.v_threshold)
    coeffs_s = ex.pad_stacked(coeffs_s, len(leak_cfgs))

    if protocol == "frozen":
        def inner(co_s, p2m_params, bb_params_s, state_s, events, labels):
            coarse_s, l1_s = _layer1_coarse_frozen(p2m_params, events,
                                                   model_cfg, co_s)

            def per_cfg(bb_p, st, coarse, l1):
                logits, _, aux = snn.spiking_cnn_apply(
                    bb_p, st, coarse, bb_cfg, train=False)
                return {"acc": snn.accuracy(logits, labels),
                        "loss": snn.cross_entropy(logits, labels)}, aux, l1

            return _map_cfgs(per_cfg, bb_params_s, state_s, coarse_s, l1_s)
    else:
        def inner(co_s, p2m_params_s, bb_params_s, state_s, events, labels):
            def per_cfg(p2m_p, bb_p, st, coeffs):
                coarse, l1 = _layer1_coarse_one(p2m_p, events, model_cfg,
                                                coeffs)
                logits, _, aux = snn.spiking_cnn_apply(
                    bb_p, st, coarse, bb_cfg, train=False)
                return {"acc": snn.accuracy(logits, labels),
                        "loss": snn.cross_entropy(logits, labels)}, aux, l1

            return _map_cfgs(per_cfg, p2m_params_s, bb_params_s, state_s,
                             co_s)

    p2m_spec = P_REP if protocol == "frozen" else P_CFG
    inner = ex.shard(inner,
                     in_specs=(P_CFG, p2m_spec, P_CFG, P_CFG, P_REP, P_REP),
                     out_specs=(P_CFG, P_CFG, P_CFG))
    jitted = jax.jit(inner)

    def ev(p2m_ps, bb_params_s, state_s, events, labels):
        metrics, aux, l1_s = jitted(coeffs_s, p2m_ps, bb_params_s, state_s,
                                    events, labels)
        return metrics, aux, _merge_grouped_l1(l1_s)

    return ev


# ---------------------------------------------------------------------------
# phase 1 (shared pretrain)
# ---------------------------------------------------------------------------

def pretrain_backbone(key: jax.Array, data_cfg, model_cfg, sweep,
                      log: Any = print) -> tuple[Params, dict, jax.Array]:
    """Phase-1 pretrain at the longest T_INTG with an IDEAL (no-leak)
    circuit — shared by every grid point. ``data_cfg`` is any
    :class:`~repro.data.sources.EventSource` (or a bare synthetic
    ``EventStreamConfig``, wrapped on entry)."""
    from repro.core import codesign

    source = sources_mod.as_source(data_cfg)
    t_long = max(sweep.t_intg_grid_ms)
    pre_cfg = replace(
        model_cfg,
        p2m=replace(model_cfg.p2m, t_intg_ms=t_long, mode="curvefit",
                    leak=replace(model_cfg.p2m.leak,
                                 circuit=CircuitConfig.IDEAL)))
    params, state = codesign.model_init(key, pre_cfg)
    opt = adamw(sweep.lr)
    opt_state = opt.init(params)
    step_fn = codesign.make_train_step(pre_cfg, opt, freeze_p2m=False)
    for i in range(sweep.pretrain_steps):
        key, kb = jax.random.split(key)
        ev, labels = source.sample_batch(kb, sweep.batch_size, t_long,
                                         n_sub=pre_cfg.p2m.n_sub)
        params, opt_state, state, m, _ = step_fn(params, opt_state, state,
                                                 ev, labels)
        if i % 10 == 0:
            log(f"[pretrain] step {i} loss={float(m['loss']):.3f} "
                f"acc={float(m['acc']):.3f}")
    return params, state, key


# ---------------------------------------------------------------------------
# the grid run
# ---------------------------------------------------------------------------

@dataclass
class GridResult:
    """Everything one sweep produced: flat records (one per
    (variant, T_INTG, n_sub) cell), the retention surface, and grid meta.
    Records are always UNPADDED — a sharded run's mesh-padding lanes are
    dropped when the records are built.

    ``final_params`` (``run_grid(keep_params=True)``) holds each outer
    cell's trained weights, keyed by ``(t_intg_ms, n_sub)``:
    ``{"p2m": ..., "backbone": ..., "state": ...}`` with backbone/state
    stacked on the unpadded ``[n_cfg]`` variant axis (p2m too under the
    unfrozen protocol; shared/unstacked when frozen). This is the seam
    the streaming deployment handshake (repro.stream.deploy) slices one
    variant's servable checkpoint out of — it is NOT part of the JSON
    artifact."""
    records: list[dict]
    retention: dict
    labels: tuple[str, ...]
    grid: SweepGrid
    protocol: str = "frozen"
    final_params: dict[tuple[float, int], dict] = field(default_factory=dict)

    def to_artifact(self, extra_meta: dict | None = None) -> dict:
        return {
            "schema": SCHEMA_V3,
            "protocol": self.protocol,
            "grid": {
                "circuits": [c.value for c in self.grid.circuits],
                "t_intg_grid_ms": list(self.grid.t_intg_grid_ms),
                "null_mismatch": list(self.grid.null_mismatch),
                "labels": list(self.labels),
                "axes": variant_grid.active_axes(self.grid),
                "axis_values": variant_grid.grid_axis_values(self.grid),
            },
            "retention": self.retention,
            "records": self.records,
            **(extra_meta or {}),
        }


def _normalize(records: list[dict]) -> None:
    """Per (config label, n_sub) series, normalize bandwidth + per-step
    train time to the longest-T point and compute the energy improvement
    against that series' single conventional reference (paper Fig 2 right —
    the digital backend always integrates at the accuracy-optimal long
    T)."""
    by_series: dict[tuple, list[dict]] = {}
    for r in records:
        by_series.setdefault((r["label"], r["n_sub"]), []).append(r)
    for rs in by_series.values():
        base = max(rs, key=lambda r: r["t_intg_ms"])
        e_conv_ref = base["backend_energy_conventional_j"]
        for r in rs:
            r["bandwidth_norm"] = (r["bandwidth_ratio"] /
                                   max(base["bandwidth_ratio"], 1e-12))
            r["train_time_norm"] = (r["train_time_per_step_s"] /
                                    max(base["train_time_per_step_s"], 1e-12))
            r["energy_improvement"] = e_conv_ref / max(
                r["backend_energy_p2m_j"], 1e-30)


def run_grid(data_cfg, model_cfg,
             sweep, grid: SweepGrid, log: Any = print, *,
             protocol: str = "frozen",
             pretrained: tuple | None = None,
             executor: SweepExecutor | None = None,
             eval_data=None,
             keep_params: bool = False) -> GridResult:
    """Run the batched co-design sweep. ``data_cfg`` is any
    :class:`~repro.data.sources.EventSource` — file-backed
    (DVS128-Gesture / N-MNIST) or synthetic (a bare
    ``events.EventStreamConfig`` is wrapped on entry) — ``model_cfg`` is a
    codesign.P2MModelConfig, ``sweep`` a codesign.SweepConfig (its
    ``t_intg_grid_ms`` is superseded by ``grid.t_intg_grid_ms``).

    ``protocol`` selects the phase-2 variant: ``"frozen"`` (paper §3 —
    layer 1 fixed, backbones finetune) or ``"unfrozen"`` (each circuit
    variant jointly learns its own layer-1 weights + backbone, with
    ``sweep.lr_p2m`` on the layer-1 leaf group when set). The phase-1
    pretrain and the batch/eval key streams are identical across protocols
    for a given seed, so records are directly comparable. ``pretrained``
    optionally injects a shared ``(params, state, key)`` phase-1 result
    (see :func:`run_protocols`). ``executor`` shards the stacked variant
    axis over a device mesh (``SweepExecutor(devices=n)``); the records
    are identical to the single-device run. ``eval_data`` optionally
    draws the accuracy-eval batches from a DIFFERENT source than the
    finetune batches — pass a file-backed dataset's held-out split
    (``resolve_dataset(..., split="val")``) so record accuracies are
    measured out-of-sample; ``None`` keeps the synthetic-generator
    behavior (train and eval sample the same stream).
    ``keep_params=True`` additionally retains each cell's trained
    weights on ``GridResult.final_params`` so a variant can be deployed
    to the online serving path (see :class:`GridResult`).
    """
    _check_protocol(protocol)
    source = sources_mod.as_source(data_cfg)
    eval_source = (sources_mod.as_source(eval_data)
                   if eval_data is not None else source)
    ex = executor or SweepExecutor()
    leak_cfgs = expand_leak_configs(grid, model_cfg.p2m.leak)
    labels = tuple(config_label(lc) for lc in leak_cfgs)
    G = len(leak_cfgs)
    G_pad = ex.padded_size(G)
    t_grid = grid.t_intg_grid_ms
    cells = variant_grid.outer_cells(grid, model_cfg.p2m.n_sub)

    sweep = replace(sweep, t_intg_grid_ms=t_grid)
    if pretrained is None:
        key = jax.random.PRNGKey(sweep.seed)
        pre_params, pre_state, key = pretrain_backbone(
            key, source, model_cfg, sweep, log)
    else:
        pre_params, pre_state, key = pretrained

    # retention surface from the closed-form leak ODE (Fig 4a): the
    # pretrained layer-1 kernel decides config (a)'s drift direction/rate.
    w_q = analog_mod.quantize_weights(pre_params["p2m"]["w"],
                                      model_cfg.p2m.analog)
    surface = leakage.retention_surface(w_q, leak_cfgs, t_grid,
                                        v0=RETENTION_V0)          # [G, n_t]
    retention = {
        "t_grid_ms": list(t_grid),
        "v0": RETENTION_V0,
        "mean_abs_error_v": {lab: [float(x) for x in row]
                             for lab, row in zip(labels, surface)},
    }

    opt = adamw(sweep.lr)
    lr_p2m = getattr(sweep, "lr_p2m", None)
    opt_unfrozen = joint_optimizer(
        opt, adamw(sweep.lr if lr_p2m is None else lr_p2m))
    records: list[dict] = []
    final_params: dict[tuple[float, int], dict] = {}
    for t_ms, ns in cells:
        ti = t_grid.index(t_ms)
        cfg_t = replace(
            model_cfg,
            p2m=replace(model_cfg.p2m, t_intg_ms=t_ms, n_sub=ns,
                        mode="curvefit"))
        if protocol == "unfrozen":
            # layer 1 gains a stacked [n_cfg] axis: every circuit variant
            # starts from the shared pretrain and learns its own copy,
            # jointly with its backbone (per-group optimizer state so
            # layer 1 can step at sweep.lr_p2m). G_pad lanes: the mesh
            # executor's padding lanes train real-but-discarded copies.
            p2m_ps = p2m_layer.stack_p2m_params(pre_params["p2m"], G_pad)
            bb_params_s = _stack_tree(pre_params["backbone"], G_pad)
            opt_state_s = jax.vmap(opt_unfrozen.init)(
                {"p2m": p2m_ps, "backbone": bb_params_s})
            opt_t = opt_unfrozen
        else:
            p2m_ps = {k: jnp.copy(v) for k, v in pre_params["p2m"].items()}
            bb_params_s = _stack_tree(pre_params["backbone"], G_pad)
            opt_state_s = jax.vmap(opt.init)(bb_params_s)
            opt_t = opt
        state_s = _stack_tree(pre_state, G_pad)
        step_fn = make_batched_finetune_step(cfg_t, leak_cfgs, opt_t,
                                             protocol=protocol, executor=ex)
        # warmup step: exclude jit compile from the train-time measurement
        # (the paper's training-time column is steady-state epochs)
        key, kw = jax.random.split(key)
        ev_w, lab_w = source.sample_batch(kw, sweep.batch_size, t_ms,
                                          n_sub=ns)
        p2m_ps, bb_params_s, opt_state_s, state_s, m, _ = step_fn(
            p2m_ps, bb_params_s, opt_state_s, state_s, ev_w, lab_w)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(sweep.finetune_steps):
            key, kb = jax.random.split(key)
            ev, lab = source.sample_batch(kb, sweep.batch_size, t_ms,
                                          n_sub=ns)
            p2m_ps, bb_params_s, opt_state_s, state_s, m, _ = step_fn(
                p2m_ps, bb_params_s, opt_state_s, state_s, ev, lab)
        jax.block_until_ready(m["loss"])
        train_s = time.perf_counter() - t0

        if protocol == "unfrozen":
            # re-linearize each variant's leak around its LEARNED kernel
            # (padding lanes dropped): the co-design point of the unfrozen
            # protocol is that circuit (a)'s drift direction/rate is now a
            # trained quantity.
            w_q_s = analog_mod.quantize_weights(p2m_ps["w"][:G],
                                                cfg_t.p2m.analog)
            lk_s = leakage.grouped_leak_params(w_q_s, leak_cfgs)
            # per-variant learned-kernel retention SURFACE over the whole
            # T grid (satellite of the frozen-kernel top-level surface);
            # one linearization serves every T point and the scalar column
            learned_surface = jnp.stack(
                [jnp.mean(leakage.retention_error(lk_s, RETENTION_V0, t),
                          axis=-1) for t in t_grid], axis=1)   # [G, n_t]
            ret_t = learned_surface[:, ti]                     # [G]

        if keep_params:
            # unpad the mesh lanes; frozen layer-1 params stay shared
            unpad = lambda tree: jax.tree.map(lambda v: v[:G], tree)  # noqa: E731
            final_params[(t_ms, ns)] = {
                "p2m": (unpad(p2m_ps) if protocol == "unfrozen"
                        else p2m_ps),
                "backbone": unpad(bb_params_s),
                "state": unpad(state_s),
            }

        # batched eval: accuracy + spike statistics for bandwidth/energy
        eval_fn = make_batched_eval(cfg_t, leak_cfgs, protocol=protocol,
                                    executor=ex)
        accs = [[] for _ in range(G)]
        l1_spikes = [0.0] * G
        in_events = 0.0
        macs = 0.0
        aux_sum: list[dict | None] = [None] * G
        for _ in range(sweep.eval_batches):
            key, kb = jax.random.split(key)
            ev, lab = eval_source.sample_batch(kb, sweep.batch_size, t_ms,
                                               n_sub=ns)
            metrics, aux, l1 = eval_fn(p2m_ps, bb_params_s, state_s,
                                       ev, lab)
            in_events += float(l1["events/in"])
            macs += float(l1["macs/p2m"])
            # unpad: only the first G of the G_pad mesh lanes are real
            for g in range(G):
                accs[g].append(float(metrics["acc"][g]))
                l1_spikes[g] += float(l1["spikes/p2m"][g])
                aux_g = {k: float(v[g]) for k, v in aux.items()}
                aux_sum[g] = aux_g if aux_sum[g] is None else {
                    k: aux_sum[g][k] + v for k, v in aux_g.items()}

        for g, (lc, lab_g) in enumerate(zip(leak_cfgs, labels)):
            bw = energy_mod.bandwidth_ratio(l1_spikes[g], in_events)
            e_conv = energy_mod.backend_energy_conventional(aux_sum[g], macs)
            e_p2m = energy_mod.backend_energy_p2m(aux_sum[g], l1_spikes[g],
                                                  macs)
            if protocol == "unfrozen":
                ret_g = float(ret_t[g])
                surf_row = learned_surface[g]
            else:
                ret_g = float(surface[g, ti])
                surf_row = surface[g]
            rec = {
                "label": lab_g,
                "circuit": lc.circuit.value,
                "null_mismatch": lc.null_mismatch,
                "protocol": protocol,
                "t_intg_ms": t_ms,
                "n_sub": ns,
                "variant": variant_grid.variant_dict(
                    lc, v_threshold_default=model_cfg.p2m.v_threshold,
                    n_sub=ns),
                "accuracy": sum(accs[g]) / len(accs[g]),
                "train_time_s": train_s,
                "train_time_per_step_s": train_s / sweep.finetune_steps,
                "bandwidth_ratio": bw,
                "backend_energy_conventional_j": e_conv,
                "backend_energy_p2m_j": e_p2m,
                "sensor_energy_p2m_j": energy_mod.sensor_energy_p2m(macs),
                "layer1_spikes": l1_spikes[g],
                "input_events": in_events,
                "retention_err_v": ret_g,
                "retention_surface_v": [float(x) for x in surf_row],
            }
            records.append(rec)
            log(f"[sweep {protocol} t={t_ms}ms cfg={lab_g}] "
                f"acc={rec['accuracy']:.3f} bw={bw:.4f} "
                f"ret={rec['retention_err_v'] * 1e3:.2f}mV "
                f"train={train_s:.1f}s")

    _normalize(records)
    return GridResult(records=records, retention=retention, labels=labels,
                      grid=grid, protocol=protocol,
                      final_params=final_params)


def run_protocols(data_cfg, model_cfg,
                  sweep, grid: SweepGrid,
                  protocols: tuple[str, ...] = PROTOCOLS,
                  log: Any = print,
                  executor: SweepExecutor | None = None,
                  eval_data=None,
                  keep_params: bool = False) -> dict[str, GridResult]:
    """Run the grid under several phase-2 protocols off ONE shared phase-1
    pretrain. ``data_cfg`` is any event source and ``eval_data`` an
    optional held-out eval source (see :func:`run_grid`). The
    post-pretrain PRNG key is reused for every protocol, so
    each one sees identical finetune/eval batches — accuracy differences
    between records are the protocol, not the data."""
    for p in protocols:
        _check_protocol(p)
    data_cfg = sources_mod.as_source(data_cfg)
    sweep = replace(sweep, t_intg_grid_ms=grid.t_intg_grid_ms)
    key = jax.random.PRNGKey(sweep.seed)
    pretrained = pretrain_backbone(key, data_cfg, model_cfg, sweep, log)
    return {p: run_grid(data_cfg, model_cfg, sweep, grid, log=log,
                        protocol=p, pretrained=pretrained, executor=executor,
                        eval_data=eval_data, keep_params=keep_params)
            for p in protocols}


def protocols_artifact(results: dict[str, GridResult],
                       extra_meta: dict | None = None) -> dict:
    """Merge per-protocol grid results into ONE ``p2m-codesign-sweep/v3``
    artifact: same grid/retention metadata, records concatenated across
    protocols (each record carries its ``"protocol"`` field and its
    ``"variant"`` dict)."""
    first = next(iter(results.values()))
    art = first.to_artifact()
    del art["protocol"]
    return {**art,
            "schema": SCHEMA_V3,
            "protocols": list(results),
            "records": [r for res in results.values() for r in res.records],
            **(extra_meta or {})}


# ---------------------------------------------------------------------------
# canonical paper-scale setup (shared by launch/sweep.py and examples)
# ---------------------------------------------------------------------------

def paper_setup(fast: bool = False, hw: int = 16,
                dataset: str = "synthetic-gesture",
                data_root: str | None = None):
    """Small-but-real defaults reproducing the paper's directional claims
    on CPU in minutes: an event source (synthetic analytic stream by
    default; ``dataset="dvs128"``/``"nmnist"`` + ``data_root`` select the
    file-backed loaders, see docs/datasets.md) + the P²M model sized to
    it (class count from the source). Short-recording datasets (real
    N-MNIST spans ~300 ms) shrink the backbone coarse window to the
    stream duration and drop T_INTG grid points that no longer fit."""
    from repro.core.codesign import P2MModelConfig, SweepConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig

    data = sources_mod.resolve_dataset(dataset, hw=hw, data_root=data_root)
    coarse_ms = min(1000.0, data.duration_ms)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16),
                                  input_hw=(hw, hw), fc_hidden=64,
                                  n_classes=data.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=coarse_ms)
    sweep_cfg = SweepConfig(
        batch_size=2 if fast else 4,
        pretrain_steps=4 if fast else 30,
        finetune_steps=2 if fast else 6,
        eval_batches=2 if fast else 4,
        dataset=dataset, data_root=data_root)
    grid = fast_grid() if fast else paper_grid()
    t_ok = fit_t_grid(grid.t_intg_grid_ms, data.duration_ms, coarse_ms)
    if not t_ok:
        raise ValueError(
            f"no T_INTG grid point fits dataset {dataset!r} "
            f"(duration {data.duration_ms:g} ms, coarse window "
            f"{coarse_ms:g} ms); pass --t-intg values that divide both")
    grid = replace(grid, t_intg_grid_ms=t_ok)
    return data, model, sweep_cfg, grid


def fit_t_grid(t_grid_ms: Sequence[float], duration_ms: float,
               coarse_ms: float) -> tuple[float, ...]:
    """The T_INTG grid points that divide both the stream duration and
    the backbone coarse window — short-recording datasets (real N-MNIST
    ≈ 300 ms) drop the points that no longer fit. The single home of
    this filter (paper_setup, benchmarks/table1, benchmarks/fig2)."""
    return tuple(t for t in t_grid_ms
                 if _divides(t, coarse_ms) and _divides(t, duration_ms))


def _divides(t_ms: float, span_ms: float) -> bool:
    n = span_ms / t_ms
    return abs(n - round(n)) < 1e-6 and round(n) >= 1
