"""Mesh-sharded execution of stacked embarrassingly-parallel axes.

Two batched axes in this repo are embarrassingly parallel — every element
runs the same program with different numerics — and both shard the same
way, so one executor abstraction serves both:

  * the sweep engine's stacked ``[n_cfg]`` circuit-variant axis
    (:class:`SweepExecutor`, 1-D ``"cfg"`` mesh) — each device
    finetunes/evaluates ``n_cfg / n_devices`` variants, events and the
    shared layer-1 params are replicated;
  * the serving engine's ``[capacity]`` lane axis
    (``repro.stream.shard.LaneExecutor``, 1-D ``"lane"`` mesh) — each
    device folds/reads out ``capacity / n_devices`` serving lanes.

:class:`MeshExecutor` holds the shared machinery: the 1-D mesh over the
first ``devices`` local devices, ``shard_map`` wrapping with pytree-prefix
in/out specs, and leading-axis padding up to a device multiple (padded
lanes compute real-but-discarded work; callers read back only the first
``n`` lanes, so sharded and single-device runs stay bit-for-bit
identical).

On CPU CI the mesh comes from forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.sweep --grid fast --devices 8

``devices=1`` (the default) is the exact pre-sharding path: no mesh, no
padding, plain ``jax.jit``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

CFG_AXIS = "cfg"
# PartitionSpec shorthands for in/out spec trees: one stacked-variant spec,
# one replicated spec (pytree prefixes — a single spec covers a whole
# params/opt-state subtree).
P_CFG = PartitionSpec(CFG_AXIS)
P_REP = PartitionSpec()


@dataclass(frozen=True)
class MeshExecutor:
    """Execution policy for one stacked embarrassingly-parallel axis.

    ``devices=1`` → single-device (no shard_map, no padding). ``devices=n``
    → 1-D ``axis`` mesh over the first n local devices.
    """
    devices: int = 1
    axis: str = CFG_AXIS

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")

    @property
    def is_sharded(self) -> bool:
        return self.devices > 1

    @property
    def p_axis(self) -> PartitionSpec:
        """Spec for leaves stacked on this executor's axis."""
        return PartitionSpec(self.axis)

    @property
    def p_rep(self) -> PartitionSpec:
        """Spec for replicated leaves."""
        return P_REP

    @cached_property
    def mesh(self) -> Mesh:
        avail = jax.devices()
        if self.devices > len(avail):
            raise ValueError(
                f"executor wants {self.devices} devices but only "
                f"{len(avail)} are visible; on CPU force host devices with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{self.devices}")
        return Mesh(np.asarray(avail[: self.devices]), (self.axis,))

    def padded_size(self, n_cfg: int) -> int:
        """Smallest multiple of the device count >= n_cfg."""
        return math.ceil(n_cfg / self.devices) * self.devices

    def pad_stacked(self, tree: Any, n_cfg: int) -> Any:
        """Pad every leaf's leading [n_cfg] axis to ``padded_size(n_cfg)``
        by repeating the last variant (real work, discarded on read-back)."""
        pad = self.padded_size(n_cfg) - n_cfg
        if pad == 0:
            return tree
        return jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), tree)

    def shard(self, fn, in_specs: Sequence, out_specs):
        """shard_map ``fn`` over the 1-D mesh (identity when devices=1).

        ``in_specs``/``out_specs`` are pytree prefixes of
        :attr:`p_axis` / :attr:`p_rep`. The body is already differentiated
        (the sweep engine's steps take grads inside), so no shard_map
        transpose is ever needed and replication checking is disabled.
        """
        if not self.is_sharded:
            return fn
        return shard_map(fn, mesh=self.mesh, in_specs=tuple(in_specs),
                         out_specs=out_specs, check_rep=False)


@dataclass(frozen=True)
class SweepExecutor(MeshExecutor):
    """The sweep engine's executor: the stacked circuit-variant axis on
    the 1-D ``"cfg"`` mesh (the :class:`MeshExecutor` defaults)."""


def make_executor(devices: int | None) -> SweepExecutor:
    """CLI entry: ``devices=None`` → single-device executor.

    Validates the device count EAGERLY (builds the mesh up front) so a bad
    ``--devices`` fails before any compute — not after a paper-scale
    phase-1 pretrain has already run.
    """
    ex = SweepExecutor(devices=devices or 1)
    if ex.is_sharded:
        _ = ex.mesh
    return ex
