"""Mesh-sharded execution of the sweep engine's stacked variant axis.

The circuit-variant axis of the batched finetune/eval steps is
embarrassingly parallel: every variant runs the same program on the same
batch with different numeric coefficients. :class:`SweepExecutor` maps
that stacked ``[n_cfg]`` axis onto a 1-D ``"cfg"`` device mesh with
``shard_map`` — each device finetunes/evaluates ``n_cfg / n_devices``
variants, events and the shared layer-1 params are replicated, and all
stacked outputs come back sharded on the same axis.

``n_cfg`` is padded up to a multiple of the device count by repeating the
last variant (the padded lanes compute real-but-discarded work); the
engine reads back only the first ``n_cfg`` lanes when it builds
``GridResult`` records, so sharded and single-device runs produce
record-for-record identical artifacts.

On CPU CI the mesh comes from forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.sweep --grid fast --devices 8

``devices=1`` (the default) is the exact pre-sharding path: no mesh, no
padding, plain ``jax.jit``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

CFG_AXIS = "cfg"
# PartitionSpec shorthands for in/out spec trees: one stacked-variant spec,
# one replicated spec (pytree prefixes — a single spec covers a whole
# params/opt-state subtree).
P_CFG = PartitionSpec(CFG_AXIS)
P_REP = PartitionSpec()


@dataclass(frozen=True)
class SweepExecutor:
    """Execution policy for the stacked variant axis.

    ``devices=1`` → single-device (no shard_map, no padding). ``devices=n``
    → 1-D ``"cfg"`` mesh over the first n local devices.
    """
    devices: int = 1

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")

    @property
    def is_sharded(self) -> bool:
        return self.devices > 1

    @cached_property
    def mesh(self) -> Mesh:
        avail = jax.devices()
        if self.devices > len(avail):
            raise ValueError(
                f"executor wants {self.devices} devices but only "
                f"{len(avail)} are visible; on CPU force host devices with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{self.devices}")
        return Mesh(np.asarray(avail[: self.devices]), (CFG_AXIS,))

    def padded_size(self, n_cfg: int) -> int:
        """Smallest multiple of the device count >= n_cfg."""
        return math.ceil(n_cfg / self.devices) * self.devices

    def pad_stacked(self, tree: Any, n_cfg: int) -> Any:
        """Pad every leaf's leading [n_cfg] axis to ``padded_size(n_cfg)``
        by repeating the last variant (real work, discarded on read-back)."""
        pad = self.padded_size(n_cfg) - n_cfg
        if pad == 0:
            return tree
        return jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), tree)

    def shard(self, fn, in_specs: Sequence, out_specs):
        """shard_map ``fn`` over the cfg mesh (identity when devices=1).

        ``in_specs``/``out_specs`` are pytree prefixes of
        :data:`P_CFG` / :data:`P_REP`. The body is already differentiated
        (the engine's steps take grads inside), so no shard_map transpose
        is ever needed and replication checking is disabled.
        """
        if not self.is_sharded:
            return fn
        return shard_map(fn, mesh=self.mesh, in_specs=tuple(in_specs),
                         out_specs=out_specs, check_rep=False)


def make_executor(devices: int | None) -> SweepExecutor:
    """CLI entry: ``devices=None`` → single-device executor.

    Validates the device count EAGERLY (builds the mesh up front) so a bad
    ``--devices`` fails before any compute — not after a paper-scale
    phase-1 pretrain has already run.
    """
    ex = SweepExecutor(devices=devices or 1)
    if ex.is_sharded:
        _ = ex.mesh
    return ex
