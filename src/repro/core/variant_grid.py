"""Declarative variant-axis registry for the co-design sweep grid.

The paper's deliverable is a trade-off surface over circuit config ×
T_INTG, but the real design space is wider: the Tri-Design follow-up
(arXiv:2304.02968) sweeps technology/circuit knobs like comparator
threshold and process variation. This module generalizes the engine's
hard-coded circuit × null_mismatch expansion into a REGISTRY of variant
axes, each declaring

  * how a value applies to a :class:`~repro.core.leakage.LeakageConfig`
    (``apply``),
  * which circuits it is meaningful for (``applies_to`` — e.g. nullifier
    mismatch only exists on circuit (c)),
  * how it labels a variant (``label_part``) and reports into the
    per-record ``"variant"`` dict of the v3 artifact (``value_of``),
  * the default value grid the sweep CLI uses when ``--axes <name>``
    activates the axis without explicit values (``cli_defaults``).

Axes come in two execution classes:

  ``stacked=True``   values only change *numbers* (leak linearization,
                     comparator threshold) — they expand into the flat
                     stacked ``[n_cfg]`` variant axis that the batched
                     finetune/eval vectorizes and the mesh executor
                     shards (one jit covers every variant);
  ``stacked=False``  values change tensor *shapes* (``n_sub`` — event
                     sub-slots per window) — they join T_INTG in the
                     outer python loop, one compile per cell.

Adding an axis = adding one registry entry (plus, if it is a new leakage
knob, the corresponding ``LeakageConfig`` field and its fold into
``LeakCoeffs``); the sweep engine, labels, artifact schema, and CLI pick
it up from the registry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Sequence

from repro.core.leakage import (
    CircuitConfig, LeakageConfig, resolve_v_threshold,
)


@dataclass(frozen=True)
class VariantAxis:
    """One sweepable knob of the circuit-variant grid."""
    name: str                                  # SweepGrid field / artifact key
    apply: Callable[[LeakageConfig, Any], LeakageConfig]
    value_of: Callable[[LeakageConfig], Any]   # value stored on a variant
    label_part: Callable[[LeakageConfig], str | None]  # None → no suffix
    cli_defaults: tuple                        # grid used by --axes <name>
    applies_to: Callable[[LeakageConfig], bool] = lambda lc: True
    stacked: bool = True                       # False → outer python loop
    help: str = ""

    @property
    def cli(self) -> str:
        return self.name.replace("_", "-")


def _fmt(v: float) -> str:
    return f"{v:g}"


# Registry order is label order and expansion order — null_mismatch first so
# the pre-registry labels ("c@m=0.06") are reproduced exactly for default
# grids.
AXES: tuple[VariantAxis, ...] = (
    VariantAxis(
        name="null_mismatch",
        apply=lambda lc, m: replace(lc, null_mismatch=m),
        value_of=lambda lc: lc.null_mismatch,
        label_part=lambda lc: (f"m={_fmt(lc.null_mismatch)}"
                               if lc.circuit == CircuitConfig.NULLIFIED
                               else None),
        applies_to=lambda lc: lc.circuit == CircuitConfig.NULLIFIED,
        cli_defaults=(0.02, 0.06, 0.2),
        help="nullifier current-mismatch fraction (circuit (c) only)"),
    VariantAxis(
        name="v_threshold",
        apply=lambda lc, v: replace(lc, v_threshold=v),
        value_of=lambda lc: lc.v_threshold,
        label_part=lambda lc: (f"vt={_fmt(lc.v_threshold)}"
                               if lc.v_threshold is not None else None),
        cli_defaults=(0.01, 0.02),
        help="comparator threshold override (V); unset → model default"),
    VariantAxis(
        name="sigma",
        apply=lambda lc, s: replace(lc, sigma=s),
        value_of=lambda lc: lc.sigma,
        label_part=lambda lc: (f"s={_fmt(lc.sigma)}" if lc.sigma else None),
        cli_defaults=(0.0, 0.1),
        help="process-variation sigma on the per-filter leak taus"),
    VariantAxis(
        name="n_sub",
        apply=lambda lc, n: lc,       # shape axis: lives on P2MConfig
        value_of=lambda lc: None,     # filled by the engine per outer cell
        label_part=lambda lc: None,
        cli_defaults=(2, 4),
        stacked=False,
        help="event sub-slots per integration window (shape-changing: "
             "joins T_INTG in the outer loop)"),
)

STACKED_AXES: tuple[VariantAxis, ...] = tuple(a for a in AXES if a.stacked)
OUTER_AXES: tuple[VariantAxis, ...] = tuple(a for a in AXES if not a.stacked)


def axis(name: str) -> VariantAxis:
    """Registry lookup by field name or kebab-case CLI name."""
    key = name.replace("-", "_")
    for a in AXES:
        if a.name == key:
            return a
    raise KeyError(f"unknown variant axis {name!r} "
                   f"(registered: {[a.name for a in AXES]})")


def expand_variants(grid, base: LeakageConfig) -> tuple[LeakageConfig, ...]:
    """Flatten circuits × every active stacked axis into the flat variant
    list that becomes the stacked ``[n_cfg]`` engine axis.

    ``grid`` carries one tuple of values per axis name (empty → axis not
    swept, variants keep ``base``'s value). An axis only multiplies the
    circuits it applies to — e.g. mismatch variants of circuits (a)/(b)
    would be duplicates, so ``applies_to`` skips them.
    """
    out: list[LeakageConfig] = []
    for c in grid.circuits:
        variants = [replace(base, circuit=c)]
        for ax in STACKED_AXES:
            values = tuple(getattr(grid, ax.name, ()) or ())
            if not values:
                continue
            nxt: list[LeakageConfig] = []
            for lc in variants:
                if ax.applies_to(lc):
                    nxt.extend(ax.apply(lc, v) for v in values)
                else:
                    nxt.append(lc)
            variants = nxt
        out.extend(variants)
    return tuple(out)


def variant_label(lc: LeakageConfig) -> str:
    """Human/record label: circuit value + one ``@``-joined suffix per axis
    that deviates from the un-swept default (registry order)."""
    parts = [lc.circuit.value]
    for ax in STACKED_AXES:
        p = ax.label_part(lc)
        if p is not None:
            parts.append(p)
    return "@".join(parts)


def variant_dict(lc: LeakageConfig, *, v_threshold_default: float,
                 n_sub: int) -> dict:
    """The per-record ``"variant"`` dict of the v3 artifact: every
    registered axis resolved to the value this record actually ran with."""
    out: dict[str, Any] = {"circuit": lc.circuit.value}
    for ax in STACKED_AXES:
        out[ax.name] = ax.value_of(lc)
    out["v_threshold"] = resolve_v_threshold(lc, v_threshold_default)
    out["n_sub"] = n_sub
    return out


def outer_cells(grid, default_n_sub: int) -> tuple[tuple[float, int], ...]:
    """The outer (shape-changing) python loop: T_INTG × n_sub cells."""
    n_subs = tuple(getattr(grid, "n_sub", ()) or (default_n_sub,))
    return tuple((t, ns) for t in grid.t_intg_grid_ms for ns in n_subs)


def active_axes(grid) -> list[str]:
    """Names of the registry axes this grid sweeps (non-empty value tuple),
    for the artifact's grid metadata."""
    return [a.name for a in AXES if tuple(getattr(grid, a.name, ()) or ())]


def grid_axis_values(grid) -> dict[str, list]:
    """Axis → value-list mapping for the v3 artifact's grid block."""
    return {a.name: list(getattr(grid, a.name, ()) or []) for a in AXES}


def check_values(name: str, values: Sequence[Any]) -> tuple:
    """Validate CLI-provided axis values (registry-level sanity only)."""
    ax = axis(name)
    vals = tuple(values)
    if ax.name == "n_sub":
        vals = tuple(int(v) for v in vals)
        if any(v < 1 for v in vals):
            raise ValueError("n_sub values must be >= 1")
    elif any(float(v) < 0 for v in vals):
        raise ValueError(f"{ax.name} values must be >= 0")
    return vals
