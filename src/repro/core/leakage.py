"""Leakage models for the three MAC circuit configs (paper §4, Fig 3/4).

Config (a) — basic unit: the kernel capacitor C_K leaks *through the weight
transistors*. pFET (positive) weights source current → pull V_C toward VDD;
nFET (negative) weights sink → pull toward GND. So both the rate and the
asymptotic voltage are kernel-dependent:

    V_inf(kernel) = VDD * sum(|w+|) / (sum(|w+|) + sum(|w-|))
    tau_a(kernel) = tau0_a / mean(|w|)      (bigger devices leak faster)

Config (b) — + isolation switch M_SW: the path through the weight transistors
is cut after each event; what remains is the switch's own subthreshold leak,
weight-independent, toward GND, with a much longer time constant.

Config (c) — + nullifying current source I_NULL: a kernel-dependent current
of equal magnitude and opposite direction is injected, cancelling the residual
leak up to a mismatch fraction. Net drift is (b)'s drift scaled by the
mismatch (a few %), making ~10 ms retention feasible — the paper's co-design
sweet spot.

All three reduce to a linear ODE  dV/dt = -(V - V_inf)/tau  between events,
integrated exactly with exp(-dt/tau) decay factors. Time constants below are
fit to reproduce Fig 4 qualitatively: (a) saturates within ~10 ms, (b) leaks
visibly at 1–10 ms, (c) holds at 10 ms and degrades by 100 ms.
"""
from __future__ import annotations

import enum
import functools
from dataclasses import dataclass, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# default comparator threshold on the swing (V) — the single source for
# P2MConfig.v_threshold and per-variant overrides (LeakageConfig.v_threshold)
DEFAULT_V_THRESHOLD = 0.015
# seed of the frozen per-filter process-variation draw behind the sigma axis
_TAU_SIGMA_SEED = 0x5159


class CircuitConfig(enum.Enum):
    BASIC = "a"            # Fig 3a — leak through weight transistors
    SWITCH = "b"           # Fig 3b — + M_SW isolation switch
    NULLIFIED = "c"        # Fig 3c — + I_NULL nullifying current source
    IDEAL = "ideal"        # no leakage (algorithm-only reference)


@dataclass(frozen=True)
class LeakageConfig:
    circuit: CircuitConfig = CircuitConfig.NULLIFIED
    vdd: float = 0.8
    v_precharge: float = 0.4
    # config (a): leak through weight transistors
    tau0_a_ms: float = 1.2          # tau at mean |w| = 1
    # config (b): switch subthreshold leak (toward GND). The isolation
    # switch cuts the dominant (weight-transistor) path; the residual
    # subthreshold current is ~50x smaller → tau ~50x config (a)'s.
    # Fit to Fig 4: visible drift at 1-10 ms, far from saturated.
    tau_b_ms: float = 60.0
    # config (c): nullifier cancels (b)-style leak up to mismatch
    null_mismatch: float = 0.06     # 6% residual current mismatch
    w_eps: float = 1e-3
    # --- sweepable variant axes (core/variant_grid.py) -------------------
    # comparator threshold override for THIS variant; None falls back to
    # the model-level P2MConfig.v_threshold (the pre-variant-grid behavior)
    v_threshold: float | None = None
    # process-variation sigma on the leak time constants: each filter's tau
    # is scaled by exp(sigma * z_f) with a frozen per-filter draw z_f —
    # sigma = 0 reproduces the unperturbed linearization exactly
    sigma: float = 0.0


@dataclass(frozen=True)
class LeakParams:
    """Per-kernel leak linearization: dV/dt = -(V - v_inf)/tau.

    ``v_inf`` is expressed in *swing* coordinates (0 = precharge level), and
    both fields broadcast against a trailing filter axis. Registered as a
    pytree so the batched sweep paths can ``vmap`` over a stacked leading
    circuit-config axis (see :func:`stacked_leak_params`).
    """
    v_inf: jax.Array     # asymptotic swing per filter
    tau_ms: jax.Array    # time constant per filter (ms)


jax.tree_util.register_dataclass(
    LeakParams, data_fields=["v_inf", "tau_ms"], meta_fields=[])


@dataclass(frozen=True)
class LeakCoeffs:
    """Numeric (branch-free) encoding of one :class:`LeakageConfig`.

    The python branch on ``cfg.circuit`` in :func:`leak_coeffs` is folded
    into these scalars once, so :func:`leak_params_from_coeffs` is a single
    jnp expression — differentiable w.r.t. the kernel weights and
    ``vmap``-able over a stacked config axis. This is what lets the
    unfrozen phase-2 protocol re-linearize each circuit's leak from its
    *current* layer-1 weights inside a jitted, vmapped train step.
    """
    is_basic: jax.Array      # 1.0 for config (a): kernel-dependent leak
    vdd: jax.Array
    v_precharge: jax.Array
    tau0_a_ms: jax.Array
    w_eps: jax.Array
    tau_const: jax.Array     # tau for the weight-independent circuits
    v_inf_const: jax.Array   # v_inf for the weight-independent circuits
    v_threshold: jax.Array   # comparator threshold of THIS variant (V)
    sigma: jax.Array         # process-variation sigma on the leak taus


jax.tree_util.register_dataclass(
    LeakCoeffs,
    data_fields=["is_basic", "vdd", "v_precharge", "tau0_a_ms", "w_eps",
                 "tau_const", "v_inf_const", "v_threshold", "sigma"],
    meta_fields=[])


def resolve_v_threshold(cfg: LeakageConfig,
                        default: float = DEFAULT_V_THRESHOLD) -> float:
    """Per-variant comparator threshold: the LeakageConfig override when
    set, else the model-level default (P2MConfig.v_threshold)."""
    return default if cfg.v_threshold is None else cfg.v_threshold


def leak_coeffs(cfg: LeakageConfig,
                default_v_threshold: float = DEFAULT_V_THRESHOLD
                ) -> LeakCoeffs:
    """Fold one config's circuit branch into numeric coefficients."""
    if cfg.circuit == CircuitConfig.BASIC:
        is_basic, tau_const, v_inf_const = 1.0, jnp.inf, 0.0
    elif cfg.circuit == CircuitConfig.SWITCH:
        # weight-independent subthreshold leak toward GND
        is_basic, tau_const, v_inf_const = 0.0, cfg.tau_b_ms, -cfg.v_precharge
    elif cfg.circuit == CircuitConfig.NULLIFIED:
        # residual = (b) leak scaled by mismatch → tau lengthens by 1/mismatch
        is_basic = 0.0
        tau_const = cfg.tau_b_ms / max(cfg.null_mismatch, 1e-6)
        v_inf_const = -cfg.v_precharge
    elif cfg.circuit == CircuitConfig.IDEAL:
        is_basic, tau_const, v_inf_const = 0.0, jnp.inf, 0.0
    else:  # pragma: no cover
        raise ValueError(cfg.circuit)
    f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
    return LeakCoeffs(is_basic=f32(is_basic), vdd=f32(cfg.vdd),
                      v_precharge=f32(cfg.v_precharge),
                      tau0_a_ms=f32(cfg.tau0_a_ms), w_eps=f32(cfg.w_eps),
                      tau_const=f32(tau_const), v_inf_const=f32(v_inf_const),
                      v_threshold=f32(resolve_v_threshold(
                          cfg, default_v_threshold)),
                      sigma=f32(cfg.sigma))


def stacked_leak_coeffs(cfgs: Sequence[LeakageConfig],
                        default_v_threshold: float = DEFAULT_V_THRESHOLD
                        ) -> LeakCoeffs:
    """Coefficients for several configs, stacked on a leading [n_cfg] axis."""
    per = [leak_coeffs(c, default_v_threshold) for c in cfgs]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


@functools.lru_cache(maxsize=None)
def _tau_sigma_units(n_filters: int) -> np.ndarray:
    """Frozen per-filter standard-normal draw behind the process-variation
    sigma axis. A fixed seed keeps every variant (and every grid run)
    perturbing the same "die": sigma scales a shared variation pattern, so
    sigma = 0 is exactly the unperturbed circuit and two variants differing
    only in sigma see proportional tau shifts. Drawn with numpy (not
    jax.random) so the constant is safe to build inside a jit trace."""
    z = np.random.default_rng(_TAU_SIGMA_SEED).standard_normal(n_filters)
    return np.asarray(z, np.float32)


def leak_params_from_coeffs(w: jax.Array, co: LeakCoeffs) -> LeakParams:
    """Branch-free leak linearization from kernel weights.

    ``w`` has shape [..., n_filters]; reduction runs over all leading axes.
    Differentiable w.r.t. ``w`` (config (a)'s v_inf/tau depend on the
    kernel; the other circuits contribute zero weight gradient through the
    ``where`` selects) and vmap-able over a stacked config axis of ``co``.
    Process variation (``co.sigma``) scales each filter's tau by
    ``exp(sigma * z_f)`` with the frozen draw from :func:`_tau_sigma_units`
    — log-normal tau spread, exact identity at sigma = 0.
    """
    reduce_axes = tuple(range(w.ndim - 1))
    pos = jnp.sum(jnp.maximum(w, 0.0), axis=reduce_axes)
    neg = jnp.sum(jnp.maximum(-w, 0.0), axis=reduce_axes)
    mean_abs = jnp.mean(jnp.abs(w), axis=reduce_axes)

    basic = co.is_basic > 0.5
    # config (a): kernel-dependent direction — pFETs pull to VDD, nFETs to GND
    v_inf_basic = co.vdd * pos / (pos + neg + co.w_eps) - co.v_precharge
    tau_basic = co.tau0_a_ms / jnp.maximum(mean_abs, co.w_eps)
    v_inf = jnp.where(basic, v_inf_basic, co.v_inf_const)
    tau = jnp.where(basic, tau_basic, co.tau_const)
    tau = tau * jnp.exp(co.sigma * _tau_sigma_units(w.shape[-1]))
    return LeakParams(v_inf=v_inf, tau_ms=tau)


def kernel_leak_params(w: jax.Array, cfg: LeakageConfig) -> LeakParams:
    """Compute per-filter leak linearization from kernel weights.

    ``w`` has shape [..., n_filters]; reduction runs over all leading axes
    (the receptive field / input channels of each filter).
    """
    return leak_params_from_coeffs(w, leak_coeffs(cfg))


def stacked_leak_params(w: jax.Array, cfgs: Sequence[LeakageConfig]
                        ) -> LeakParams:
    """Leak linearizations for several circuit configs, stacked on axis 0.

    Returns ``LeakParams`` whose fields have shape ``[n_cfg, ...filters]`` —
    the leading axis is the circuit-config axis that the batched sweep
    engine (core/sweep.py) and the multi-config Pallas kernel grid iterate
    over. ``leak_step``/``decay_factor``/``retention_error`` all broadcast
    against it unchanged.
    """
    per = [kernel_leak_params(w, c) for c in cfgs]
    return LeakParams(v_inf=jnp.stack([p.v_inf for p in per]),
                      tau_ms=jnp.stack([p.tau_ms for p in per]))


def grouped_leak_params(w_s: jax.Array, cfgs: Sequence[LeakageConfig]
                        ) -> LeakParams:
    """Leak linearizations for PER-CONFIG kernel weights.

    ``w_s`` has a leading ``[n_cfg]`` axis — one kernel per circuit config,
    the unfrozen phase-2 state where each config learns its own layer-1
    weights. Returns stacked ``LeakParams`` like :func:`stacked_leak_params`
    but with config ``i`` linearized around ``w_s[i]``. Differentiable
    w.r.t. ``w_s``.
    """
    assert w_s.shape[0] == len(cfgs), (w_s.shape, len(cfgs))
    return jax.vmap(leak_params_from_coeffs)(w_s, stacked_leak_coeffs(cfgs))


def paper_circuits() -> tuple[LeakageConfig, ...]:
    """The paper's three MAC circuit configs (Fig 3a/3b/3c) with the
    defaults used throughout the repo — the single home for these
    constants (benchmarks and examples must not rebuild them ad hoc)."""
    return (LeakageConfig(circuit=CircuitConfig.BASIC),
            LeakageConfig(circuit=CircuitConfig.SWITCH),
            LeakageConfig(circuit=CircuitConfig.NULLIFIED))


def with_mismatch(cfg: LeakageConfig, mismatch: float) -> LeakageConfig:
    """A copy of ``cfg`` with the nullifier mismatch overridden."""
    return replace(cfg, null_mismatch=mismatch)


def decay_factor(tau_ms: jax.Array, dt_ms: float | jax.Array) -> jax.Array:
    """exp(-dt/tau), safe at tau = inf. Vectorizes elementwise, so stacked
    ``[n_cfg, F]`` time constants from :func:`stacked_leak_params` work
    unchanged."""
    return jnp.where(jnp.isinf(tau_ms), 1.0, jnp.exp(-dt_ms / jnp.maximum(tau_ms, 1e-9)))


def leak_step(v: jax.Array, params: LeakParams, dt_ms: float | jax.Array) -> jax.Array:
    """Integrate the leak ODE exactly over dt: V ← V_inf + (V - V_inf)e^{-dt/τ}."""
    a = decay_factor(params.tau_ms, dt_ms)
    return params.v_inf + (v - params.v_inf) * a


def retention_error(params: LeakParams, v0: jax.Array, t_ms: float) -> jax.Array:
    """|V(t) - V(0)| with no input drive — the Fig 4a experiment."""
    return jnp.abs(leak_step(v0, params, t_ms) - v0)


def retention_traces(w: jax.Array, cfgs: Sequence[LeakageConfig],
                     ts_ms: jax.Array, v0: float | jax.Array = 0.2
                     ) -> jax.Array:
    """Undriven voltage traces V(t) for each circuit config (Fig 4a).

    Returns ``[n_cfg, n_t, F]`` voltages starting from swing ``v0``.
    """
    lk = stacked_leak_params(w, cfgs)
    v0 = jnp.broadcast_to(jnp.asarray(v0, jnp.float32), lk.v_inf.shape)

    def at_t(t):
        return leak_step(v0, lk, t)              # [n_cfg, F]

    return jnp.moveaxis(jax.vmap(at_t)(jnp.asarray(ts_ms)), 0, 1)


def retention_surface(w: jax.Array, cfgs: Sequence[LeakageConfig],
                      t_grid_ms: Sequence[float], v0: float = 0.2
                      ) -> jax.Array:
    """Mean retention error |V(t)-V(0)| per (config, T_INTG) — the
    ``[n_cfg, n_t]`` surface the sweep artifact reports."""
    traces = retention_traces(w, cfgs, jnp.asarray(list(t_grid_ms)), v0)
    return jnp.mean(jnp.abs(traces - v0), axis=-1)
