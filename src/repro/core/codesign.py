"""The co-design harness: the paper's full model (P²M layer-1 + spiking-CNN
backbone) and the T_INTG trade-off sweep (Table 1 + Fig 2).

Training protocol (paper §3):
  phase 1  pretrain the whole spiking CNN at a *long* integration time
           (coarse grid, no P²M circuit constraints) — cheap, few timesteps;
  phase 2  impose the P²M constraints on layer 1 at the target (short)
           T_INTG, freeze layer 1, and finetune layers ≥ 2 on the coarse
           grid fed by layer-1 spike counts.

The batched engine in ``repro.core.sweep`` additionally offers an
*unfrozen* phase 2 (``protocol="unfrozen"``) where layer 1 trains jointly
with the backbone through the differentiable curvefit forward — see
``run_sweep``'s ``protocol`` argument.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import p2m_layer, snn
from repro.core.leakage import CircuitConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.optim import clip_by_global_norm
from repro.optim.optimizers import apply_updates

Params = dict


@dataclass(frozen=True)
class P2MModelConfig:
    """Full paper model: P²M first layer + digital spiking backbone."""
    p2m: P2MConfig = field(default_factory=P2MConfig)
    backbone: SpikingCNNConfig = field(default_factory=lambda: SpikingCNNConfig(
        first_layer_external=True))
    coarse_window_ms: float = 1000.0     # backbone integration time (paper: ~s)

    def coarsen_group(self) -> int:
        g = self.coarse_window_ms / self.p2m.t_intg_ms
        assert abs(g - round(g)) < 1e-6, (self.coarse_window_ms, self.p2m.t_intg_ms)
        return int(round(g))


def model_init(key: jax.Array, cfg: P2MModelConfig) -> tuple[Params, dict]:
    k1, k2 = jax.random.split(key)
    p2m_params = p2m_layer.p2m_init(k1, cfg.p2m)
    bb_params, bb_state = snn.spiking_cnn_init(k2, cfg.backbone)
    return {"p2m": p2m_params, "backbone": bb_params}, bb_state


def model_apply(params: Params, state: dict, events: jax.Array,
                cfg: P2MModelConfig, *, train: bool
                ) -> tuple[jax.Array, dict, dict]:
    """events: [B, T_fine, n_sub, H, W, 2] at the P²M fine grid."""
    spikes1, v_pre = p2m_layer.p2m_apply(params["p2m"], events, cfg.p2m)
    # first layer's own 2x pool (keeps pixel pitch parity with the backbone)
    B, T = spikes1.shape[:2]
    tb = spikes1.reshape((B * T,) + spikes1.shape[2:])
    tb = snn.max_pool(tb)
    spikes1 = tb.reshape((B, T) + tb.shape[1:])
    coarse = p2m_layer.coarsen_spikes(spikes1, cfg.coarsen_group())
    logits, new_state, aux = snn.spiking_cnn_apply(
        params["backbone"], state, coarse, cfg.backbone, train=train)
    aux["spikes/p2m"] = jax.lax.stop_gradient(jnp.sum(spikes1))
    aux["events/in"] = jax.lax.stop_gradient(jnp.sum(events))
    k = cfg.p2m.kernel_size
    out_elems = jnp.prod(jnp.asarray(spikes1.shape[:2] + spikes1.shape[2:]))
    aux["macs/p2m"] = jax.lax.stop_gradient(
        out_elems.astype(jnp.float32) * k * k * cfg.p2m.in_channels)
    return logits, new_state, aux


# ---------------------------------------------------------------------------
# training steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: P2MModelConfig, opt, *, freeze_p2m: bool):
    """Returns jit-able train_step(params, opt_state, state, batch)."""

    def loss_fn(params, state, ev, labels):
        logits, new_state, aux = model_apply(params, state, ev, cfg, train=True)
        loss = snn.cross_entropy(logits, labels)
        return loss, (new_state, aux, logits)

    @jax.jit
    def step(params, opt_state, state, ev, labels):
        (loss, (new_state, aux, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, ev, labels)
        if freeze_p2m:
            grads = {**grads, "p2m": jax.tree.map(jnp.zeros_like, grads["p2m"])}
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        if freeze_p2m:
            # zero the *updates* too: AdamW weight decay would otherwise
            # shrink the frozen in-pixel weights every step
            updates = {**updates,
                       "p2m": jax.tree.map(jnp.zeros_like, updates["p2m"])}
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "gnorm": gnorm,
                   "acc": snn.accuracy(logits, labels)}
        return params, opt_state, new_state, metrics, aux

    return step


def make_eval_fn(cfg: P2MModelConfig):
    @jax.jit
    def ev_fn(params, state, ev, labels):
        logits, _, aux = model_apply(params, state, ev, cfg, train=False)
        return {"acc": snn.accuracy(logits, labels),
                "loss": snn.cross_entropy(logits, labels)}, aux
    return ev_fn


# ---------------------------------------------------------------------------
# the sweep (Table 1 / Fig 2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepConfig:
    t_intg_grid_ms: tuple[float, ...] = (1.0, 10.0, 100.0, 1000.0)
    batch_size: int = 8
    pretrain_steps: int = 40
    finetune_steps: int = 15
    eval_batches: int = 4
    lr: float = 2e-3
    # layer-1 LR for the unfrozen joint update (sweep.joint_optimizer):
    # the in-pixel kernel usually wants a gentler step than the backbone.
    # None → use ``lr`` (exactly the single-optimizer joint update).
    lr_p2m: float | None = None
    seed: int = 0
    # dataset selection (repro.data.sources.resolve_dataset): a name from
    # sources.DATASETS; file-backed names need data_root. Used when the
    # caller passes no explicit data_cfg/EventSource (run_sweep below).
    dataset: str = "synthetic-gesture"
    data_root: str | None = None


def run_sweep(data_cfg: Any = None,
              model_cfg: P2MModelConfig | None = None,
              sweep: SweepConfig = SweepConfig(),
              circuit: CircuitConfig = CircuitConfig.NULLIFIED,
              log: Any = print,
              protocol: str = "frozen",
              devices: int | None = None,
              eval_data: Any = None) -> list[dict]:
    """Run the co-design T_INTG sweep for ONE circuit config. Returns one
    record per grid point with accuracy, wall-clock train time, bandwidth
    ratio, and backend energies.

    ``data_cfg`` is any ``repro.data.sources.EventSource`` or a synthetic
    ``EventStreamConfig``; pass ``None`` to resolve it from
    ``sweep.dataset`` / ``sweep.data_root`` (the resolution follows the
    model's backbone input grid).

    ``protocol`` picks the phase-2 variant: ``"frozen"`` (paper §3, layer 1
    fixed after phase 1) or ``"unfrozen"`` (layer 1 trains jointly with the
    backbone through the differentiable curvefit forward).

    This is a single-circuit wrapper over the batched engine in
    ``repro.core.sweep`` — the same vectorized path that sweeps all circuit
    configs at once; here the stacked config axis just has length 1. The
    normalization semantics are the engine's: bandwidth and per-step train
    time are normalized to the longest-T point, and the energy improvement
    is computed against a SINGLE conventional reference (the digital
    backend always integrates at the accuracy-optimal long T — paper Fig 2
    right: the P²M advantage grows with T_INTG).

    ``devices`` shards the stacked config axis over a 1-D device mesh
    (core/sweep_exec.py) — with a single circuit the axis has length 1, so
    this only matters when the caller expands mismatch/threshold/sigma
    variants through the model config.

    ``eval_data`` optionally draws the accuracy-eval batches from a
    held-out source (``resolve_eval_dataset``) so record accuracies are
    out-of-sample — same semantics as ``sweep.run_grid(eval_data=...)``.
    """
    from repro.core import sweep as sweep_engine
    from repro.core.sweep_exec import make_executor
    from repro.data import sources as sources_mod

    if model_cfg is None:
        model_cfg = P2MModelConfig()
    if data_cfg is None:
        data_cfg = sources_mod.resolve_dataset(
            sweep.dataset, hw=model_cfg.backbone.input_hw[0],
            data_root=sweep.data_root)
    mcfg = replace(model_cfg,
                   p2m=replace(model_cfg.p2m,
                               leak=replace(model_cfg.p2m.leak,
                                            circuit=circuit)))
    grid = sweep_engine.SweepGrid(
        circuits=(circuit,),
        t_intg_grid_ms=tuple(sweep.t_intg_grid_ms),
        null_mismatch=(mcfg.p2m.leak.null_mismatch,))
    result = sweep_engine.run_grid(data_cfg, mcfg, sweep, grid, log=log,
                                   protocol=protocol,
                                   executor=make_executor(devices),
                                   eval_data=eval_data)
    return result.records
