"""The co-design harness: the paper's full model (P²M layer-1 + spiking-CNN
backbone) and the T_INTG trade-off sweep (Table 1 + Fig 2).

Training protocol (paper §3):
  phase 1  pretrain the whole spiking CNN at a *long* integration time
           (coarse grid, no P²M circuit constraints) — cheap, few timesteps;
  phase 2  impose the P²M constraints on layer 1 at the target (short)
           T_INTG, freeze layer 1, and finetune layers ≥ 2 on the coarse
           grid fed by layer-1 spike counts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import energy as energy_mod
from repro.core import p2m_layer, snn
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as events_mod
from repro.optim import adamw, clip_by_global_norm
from repro.optim.optimizers import apply_updates

Params = dict


@dataclass(frozen=True)
class P2MModelConfig:
    """Full paper model: P²M first layer + digital spiking backbone."""
    p2m: P2MConfig = field(default_factory=P2MConfig)
    backbone: SpikingCNNConfig = field(default_factory=lambda: SpikingCNNConfig(
        first_layer_external=True))
    coarse_window_ms: float = 1000.0     # backbone integration time (paper: ~s)

    def coarsen_group(self) -> int:
        g = self.coarse_window_ms / self.p2m.t_intg_ms
        assert abs(g - round(g)) < 1e-6, (self.coarse_window_ms, self.p2m.t_intg_ms)
        return int(round(g))


def model_init(key: jax.Array, cfg: P2MModelConfig) -> tuple[Params, dict]:
    k1, k2 = jax.random.split(key)
    p2m_params = p2m_layer.p2m_init(k1, cfg.p2m)
    bb_params, bb_state = snn.spiking_cnn_init(k2, cfg.backbone)
    return {"p2m": p2m_params, "backbone": bb_params}, bb_state


def model_apply(params: Params, state: dict, events: jax.Array,
                cfg: P2MModelConfig, *, train: bool
                ) -> tuple[jax.Array, dict, dict]:
    """events: [B, T_fine, n_sub, H, W, 2] at the P²M fine grid."""
    spikes1, v_pre = p2m_layer.p2m_apply(params["p2m"], events, cfg.p2m)
    # first layer's own 2x pool (keeps pixel pitch parity with the backbone)
    B, T = spikes1.shape[:2]
    tb = spikes1.reshape((B * T,) + spikes1.shape[2:])
    tb = snn.max_pool(tb)
    spikes1 = tb.reshape((B, T) + tb.shape[1:])
    coarse = p2m_layer.coarsen_spikes(spikes1, cfg.coarsen_group())
    logits, new_state, aux = snn.spiking_cnn_apply(
        params["backbone"], state, coarse, cfg.backbone, train=train)
    aux["spikes/p2m"] = jax.lax.stop_gradient(jnp.sum(spikes1))
    aux["events/in"] = jax.lax.stop_gradient(jnp.sum(events))
    k = cfg.p2m.kernel_size
    out_elems = jnp.prod(jnp.asarray(spikes1.shape[:2] + spikes1.shape[2:]))
    aux["macs/p2m"] = jax.lax.stop_gradient(
        out_elems.astype(jnp.float32) * k * k * cfg.p2m.in_channels)
    return logits, new_state, aux


# ---------------------------------------------------------------------------
# training steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: P2MModelConfig, opt, *, freeze_p2m: bool):
    """Returns jit-able train_step(params, opt_state, state, batch)."""

    def loss_fn(params, state, ev, labels):
        logits, new_state, aux = model_apply(params, state, ev, cfg, train=True)
        loss = snn.cross_entropy(logits, labels)
        return loss, (new_state, aux, logits)

    @jax.jit
    def step(params, opt_state, state, ev, labels):
        (loss, (new_state, aux, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, ev, labels)
        if freeze_p2m:
            grads = {**grads, "p2m": jax.tree.map(jnp.zeros_like, grads["p2m"])}
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        if freeze_p2m:
            # zero the *updates* too: AdamW weight decay would otherwise
            # shrink the frozen in-pixel weights every step
            updates = {**updates,
                       "p2m": jax.tree.map(jnp.zeros_like, updates["p2m"])}
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "gnorm": gnorm,
                   "acc": snn.accuracy(logits, labels)}
        return params, opt_state, new_state, metrics, aux

    return step


def make_eval_fn(cfg: P2MModelConfig):
    @jax.jit
    def ev_fn(params, state, ev, labels):
        logits, _, aux = model_apply(params, state, ev, cfg, train=False)
        return {"acc": snn.accuracy(logits, labels),
                "loss": snn.cross_entropy(logits, labels)}, aux
    return ev_fn


# ---------------------------------------------------------------------------
# the sweep (Table 1 / Fig 2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepConfig:
    t_intg_grid_ms: tuple[float, ...] = (1.0, 10.0, 100.0, 1000.0)
    batch_size: int = 8
    pretrain_steps: int = 40
    finetune_steps: int = 15
    eval_batches: int = 4
    lr: float = 2e-3
    seed: int = 0


def run_sweep(data_cfg: events_mod.EventStreamConfig,
              model_cfg: P2MModelConfig,
              sweep: SweepConfig,
              circuit: CircuitConfig = CircuitConfig.NULLIFIED,
              log: Any = print) -> list[dict]:
    """Run the co-design T_INTG sweep. Returns one record per grid point with
    accuracy, wall-clock train time, bandwidth ratio, and backend energies.
    """
    key = jax.random.PRNGKey(sweep.seed)
    records = []

    # --- phase 1: pretrain once at the longest T_INTG (coarse == fine) -----
    t_long = sweep.t_intg_grid_ms[-1]
    pre_cfg = replace(
        model_cfg,
        p2m=replace(model_cfg.p2m, t_intg_ms=t_long, mode="curvefit",
                    leak=replace(model_cfg.p2m.leak, circuit=CircuitConfig.IDEAL)))
    params, state = model_init(key, pre_cfg)
    opt = adamw(sweep.lr)
    opt_state = opt.init(params)
    step_fn = make_train_step(pre_cfg, opt, freeze_p2m=False)
    for i in range(sweep.pretrain_steps):
        key, kb = jax.random.split(key)
        ev, labels = events_mod.sample_batch(kb, data_cfg, sweep.batch_size,
                                             t_long, n_sub=pre_cfg.p2m.n_sub)
        params, opt_state, state, m, _ = step_fn(params, opt_state, state, ev, labels)
        if i % 10 == 0:
            log(f"[pretrain] step {i} loss={float(m['loss']):.3f} "
                f"acc={float(m['acc']):.3f}")
    pre_params, pre_state = params, state

    # --- phase 2: per-T_INTG constrain layer-1, freeze, finetune backbone --
    for t_ms in sweep.t_intg_grid_ms:
        cfg_t = replace(
            model_cfg,
            p2m=replace(model_cfg.p2m, t_intg_ms=t_ms, mode="curvefit",
                        leak=replace(model_cfg.p2m.leak, circuit=circuit)))
        params = jax.tree.map(jnp.copy, pre_params)
        state = jax.tree.map(jnp.copy, pre_state)
        opt_state = opt.init(params)
        step_fn = make_train_step(cfg_t, opt, freeze_p2m=True)
        # warmup step: exclude jit compile from the train-time measurement
        # (the paper's training-time column is steady-state epochs)
        key, kw = jax.random.split(key)
        ev_w, lab_w = events_mod.sample_batch(kw, data_cfg, sweep.batch_size,
                                              t_ms, n_sub=cfg_t.p2m.n_sub)
        params, opt_state, state, m, _ = step_fn(params, opt_state, state,
                                                 ev_w, lab_w)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(sweep.finetune_steps):
            key, kb = jax.random.split(key)
            ev, labels = events_mod.sample_batch(kb, data_cfg, sweep.batch_size,
                                                 t_ms, n_sub=cfg_t.p2m.n_sub)
            params, opt_state, state, m, _ = step_fn(
                params, opt_state, state, ev, labels)
        jax.block_until_ready(m["loss"])
        train_s = time.perf_counter() - t0

        # eval: accuracy + spike statistics for bandwidth/energy
        eval_fn = make_eval_fn(cfg_t)
        accs, l1_spikes, in_events, macs, aux_sum = [], 0.0, 0.0, 0.0, None
        for _ in range(sweep.eval_batches):
            key, kb = jax.random.split(key)
            ev, labels = events_mod.sample_batch(kb, data_cfg, sweep.batch_size,
                                                 t_ms, n_sub=cfg_t.p2m.n_sub)
            m, aux = eval_fn(params, state, ev, labels)
            accs.append(float(m["acc"]))
            l1_spikes += float(aux["spikes/p2m"])
            in_events += float(aux["events/in"])
            macs += float(aux["macs/p2m"])
            aux_f = {k: float(v) for k, v in aux.items()}
            aux_sum = aux_f if aux_sum is None else {
                k: aux_sum[k] + v for k, v in aux_f.items()}

        bw = energy_mod.bandwidth_ratio(l1_spikes, in_events)
        e_conv = energy_mod.backend_energy_conventional(aux_sum, macs)
        e_p2m = energy_mod.backend_energy_p2m(aux_sum, l1_spikes, macs)
        e_sensor = energy_mod.sensor_energy_p2m(macs)
        rec = {
            "sensor_energy_p2m_j": e_sensor,
            "t_intg_ms": t_ms,
            "circuit": circuit.value,
            "accuracy": sum(accs) / len(accs),
            "train_time_s": train_s,
            "train_time_per_step_s": train_s / sweep.finetune_steps,
            "bandwidth_ratio": bw,
            "backend_energy_conventional_j": e_conv,
            "backend_energy_p2m_j": e_p2m,
            "layer1_spikes": l1_spikes,
            "input_events": in_events,
        }
        log(f"[sweep t={t_ms}ms] acc={rec['accuracy']:.3f} "
            f"bw={bw:.4f} train={train_s:.1f}s")
        records.append(rec)

    # normalize bandwidth + training time to the longest-T point (paper's 1x)
    # and compute the energy improvement against a SINGLE conventional
    # reference: the digital backend has no leakage constraint, so it always
    # integrates at the accuracy-optimal long T — the energy advantage of
    # P²M then *grows* with T_INTG (paper Fig 2 right: 2.4x→6.25x), because
    # the short-T P²M points pay more analog windows + spike transmissions.
    base = records[-1]
    e_conv_ref = base["backend_energy_conventional_j"]
    for r in records:
        r["bandwidth_norm"] = r["bandwidth_ratio"] / max(base["bandwidth_ratio"], 1e-12)
        r["train_time_norm"] = (r["train_time_per_step_s"] /
                                max(base["train_time_per_step_s"], 1e-12))
        r["energy_improvement"] = e_conv_ref / max(r["backend_energy_p2m_j"],
                                                   1e-30)
    return records
