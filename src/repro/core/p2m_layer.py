"""The P²M in-pixel first layer (paper §2 + §4).

Physics of the modeled circuit, per output filter at each spatial site:

  * between events the kernel capacitor leaks:  V ← V_inf + (V-V_inf)e^{-dt/τ}
    (τ, V_inf per circuit config — see leakage.py);
  * each arriving event pulses the weight transistors: ΔV = dv_unit · Σ w·s,
    compressed by the voltage-dependent step non-linearity g(V);
  * after T_INTG the voltage is compared with a threshold → binary activation.

Two functionally-equivalent implementations are provided:

  ``mode="scan"``      exact event-driven integration with lax.scan over the
                       sub-step grid — the *hardware simulator* (also the
                       oracle for the Pallas kernel in kernels/p2m_conv).
  ``mode="curvefit"``  the paper's algorithmic model: a *linear* conv of the
                       leak-weighted event sum pushed through the fitted
                       transfer curve + process variation. This is what the
                       network trains through (cheap, differentiable); the
                       scan model validates it.

The layer runs at a *fine* time grid (integration time T_INTG ms per output
step, subdivided into n_sub event slots); its binary outputs are then summed
onto the backbone's coarse grid (paper §3: "we utilize a long integration
time ... from the second layer").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import analog, leakage
from repro.core.analog import AnalogConfig
from repro.core.leakage import LeakageConfig
from repro.core.snn import spike_fn

Params = dict


@dataclass(frozen=True)
class P2MConfig:
    in_channels: int = 2             # DVS ON/OFF
    out_channels: int = 16           # "fewer channels in the first layer"
    kernel_size: int = 3
    stride: int = 1
    t_intg_ms: float = 10.0          # integration time per output activation
    n_sub: int = 8                   # event sub-slots per integration window
    # comparator threshold on the swing (V). ~1.5 weighted events at
    # dv_unit=10mV: low enough that sub-10ms windows re-fire during event
    # bursts — the mechanism behind the paper's Fig-2 bandwidth trend
    # (output spikes increase as T_INTG shrinks). This is the model-level
    # DEFAULT: a sweep variant overrides it per config via
    # LeakageConfig.v_threshold (the stacked v_threshold axis).
    v_threshold: float = leakage.DEFAULT_V_THRESHOLD
    analog: AnalogConfig = field(default_factory=AnalogConfig)
    leak: LeakageConfig = field(default_factory=LeakageConfig)
    mode: str = "curvefit"           # "curvefit" | "scan" | "kernel"

    @property
    def dt_ms(self) -> float:
        return self.t_intg_ms / self.n_sub


def p2m_init(key: jax.Array, cfg: P2MConfig) -> Params:
    k = cfg.kernel_size
    fan_in = k * k * cfg.in_channels
    w = jax.random.normal(key, (k, k, cfg.in_channels, cfg.out_channels)) * (
        2.0 / fan_in) ** 0.5
    pv = analog.sample_process_variation(
        jax.random.fold_in(key, 1), cfg.out_channels, cfg.analog)
    return {"w": w, "pv_gain": pv["gain"], "pv_offset": pv["offset"]}


def _conv(x: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def effective_weights(params: Params, cfg: P2MConfig) -> jax.Array:
    """Quantized (transistor-geometry) weights, straight-through grads."""
    return analog.quantize_weights(params["w"], cfg.analog)


def stacked_thetas(cfg: P2MConfig, leak_cfgs: tuple[LeakageConfig, ...],
                   ndim: int) -> jax.Array:
    """Per-variant comparator thresholds, shaped [n_cfg, 1, ..., 1] to
    broadcast against an ``ndim``-dimensional stacked voltage tensor.

    The threshold lives on the VARIANT axis now (the v_threshold sweep
    axis): each LeakageConfig may override the model-level
    ``cfg.v_threshold`` default.
    """
    th = jnp.asarray([leakage.resolve_v_threshold(lc, cfg.v_threshold)
                      for lc in leak_cfgs], jnp.float32)
    return th.reshape((len(leak_cfgs),) + (1,) * (ndim - 1))


def _forward_scan_lk(params: Params, events: jax.Array, cfg: P2MConfig,
                     w_q: jax.Array, lk: leakage.LeakParams) -> jax.Array:
    """Scan-mode voltage integration for one explicit leak linearization.

    Shared body for the single-config path (lk from ``cfg.leak``) and the
    stacked multi-circuit path (vmapped over a leading config axis of lk).
    Returns v_pre [B, T_out, H', W', C_out].
    """
    B, T_out, n_sub = events.shape[:3]

    def window(ev_win):  # ev_win: [n_sub, B, H, W, C_in]
        h_out = ev_win.shape[2] // cfg.stride
        w_out = ev_win.shape[3] // cfg.stride
        v0 = jnp.zeros((B, h_out, w_out, cfg.out_channels))

        def sub_step(v, ev_t):
            v = leakage.leak_step(v, lk, cfg.dt_ms)
            ideal = _conv(ev_t, w_q, cfg.stride) * cfg.analog.dv_unit
            step = ideal * analog.step_nonlinearity(v, cfg.analog)
            step = step * params["pv_gain"]
            v = jnp.clip(v + step,
                         -cfg.analog.v_precharge,
                         cfg.analog.vdd - cfg.analog.v_precharge)
            return v, None

        v, _ = lax.scan(sub_step, v0, ev_win)
        v = v + params["pv_offset"]
        return v

    # [B, T_out, n_sub, H, W, C] → [T_out, n_sub, B, H, W, C]
    ev = jnp.moveaxis(events, (1, 2), (0, 1))
    v_pre = lax.map(window, ev)                      # [T_out, B, H', W', C_out]
    return jnp.moveaxis(v_pre, 0, 1)                 # [B, T_out, ...]


def p2m_forward_scan(params: Params, events: jax.Array, cfg: P2MConfig
                     ) -> tuple[jax.Array, jax.Array]:
    """Exact event-driven integration (hardware simulator).

    events: [B, T_out, n_sub, H, W, C_in] event counts per sub-slot.
    Returns (spikes [B, T_out, H', W', C_out], v_pre [same]) where v_pre is
    the pre-comparator voltage at the end of each integration window.
    """
    w_q = effective_weights(params, cfg)
    lk = leakage.kernel_leak_params(w_q, cfg.leak)
    v_pre = _forward_scan_lk(params, events, cfg, w_q, lk)
    theta = leakage.resolve_v_threshold(cfg.leak, cfg.v_threshold)
    spikes = spike_fn(v_pre - theta)
    return spikes, v_pre


def p2m_forward_scan_stacked(params: Params, events: jax.Array,
                             cfg: P2MConfig,
                             leak_cfgs: tuple[LeakageConfig, ...]
                             ) -> tuple[jax.Array, jax.Array]:
    """Scan-mode integration under several circuit configs at once.

    Returns (spikes, v_pre), both [n_cfg, B, T_out, H', W', C_out]. The
    quantized weights / conv are config-independent; only the leak
    linearization varies, so the vmap re-runs just the voltage recursion.
    """
    w_q = effective_weights(params, cfg)
    lk = leakage.stacked_leak_params(w_q, leak_cfgs)      # [n_cfg, F]
    v_pre = jax.vmap(
        lambda l: _forward_scan_lk(params, events, cfg, w_q, l))(lk)
    spikes = spike_fn(v_pre - stacked_thetas(cfg, leak_cfgs, v_pre.ndim))
    return spikes, v_pre


def curvefit_ideal(events: jax.Array, cfg: P2MConfig, w_q: jax.Array
                   ) -> jax.Array:
    """The curve-fit model's per-sub-slot ideal conv — the expensive,
    VARIANT-INDEPENDENT half of the forward.

    events [B, T_out, n_sub, H, W, C_in] → ideal [B·T_out, n_sub, H', W',
    C_out]. Split out so the sweep engine's frozen protocol can compute it
    ONCE per step and reduce it per variant with
    :func:`curvefit_reduce` (each variant only changes the [n_sub, C_out]
    decay weights and the transfer-curve inputs).
    """
    B, T_out, n_sub = events.shape[:3]
    tb = events.reshape((B * T_out * n_sub,) + events.shape[3:])
    ideal = _conv(tb, w_q, cfg.stride) * cfg.analog.dv_unit
    return ideal.reshape((B * T_out, n_sub) + ideal.shape[1:])


def window_decay(lk: leakage.LeakParams, n_sub: int, dt_ms: float
                 ) -> tuple[jax.Array, jax.Array]:
    """One integration window's leak weighting: per-sub-slot decay
    weights ``a^(n_sub-1-k)`` (a = e^(−dt/τ) per filter) and the window
    drift toward ``V_inf``. THE single source of this math — shared by
    the offline curve-fit reduce below and the online streaming
    accumulator (repro.stream.accumulator), whose readout-boundary
    parity depends on both paths weighting identically.

    Returns ``(decay_w [n_sub, C_out], drift [C_out])``.
    """
    a = leakage.decay_factor(lk.tau_ms, dt_ms)                 # [C_out]
    k = jnp.arange(n_sub)
    decay_w = a[None, :] ** (n_sub - 1 - k)[:, None]           # [n_sub, C]
    drift = jnp.sum(1.0 - decay_w, axis=0) * lk.v_inf / n_sub  # [C]
    return decay_w, drift


def curvefit_reduce(params: Params, cfg: P2MConfig, ideal: jax.Array,
                    lk: leakage.LeakParams, batch: int) -> jax.Array:
    """The cheap, per-variant half of the curve-fit forward: leak-decay
    weighting of the precomputed ideal conv + the fitted transfer curve.

    ``ideal`` is :func:`curvefit_ideal`'s output; ``lk`` fields are
    per-filter ``[C_out]``. Returns v_pre [B, T_out, H', W', C_out].
    """
    decay_w, drift = window_decay(lk, ideal.shape[1], cfg.dt_ms)
    x = jnp.einsum("bk...c,kc->b...c", ideal, decay_w) + drift
    pv = {"gain": params["pv_gain"], "offset": params["pv_offset"]}
    v_pre = analog.transfer_curve(x, cfg.analog, pv)
    return v_pre.reshape((batch, ideal.shape[0] // batch) + v_pre.shape[1:])


def _curvefit_from_lk(params: Params, events: jax.Array, cfg: P2MConfig,
                      w_q: jax.Array, lk: leakage.LeakParams) -> jax.Array:
    """Single-config curve-fit body for one explicit leak linearization.

    ``lk`` fields are per-filter ``[C_out]``. Returns v_pre
    [B, T_out, H', W', C_out]. Fully differentiable w.r.t. ``w_q`` and the
    leak params — the seam the unfrozen phase-2 protocol trains through.
    """
    ideal = curvefit_ideal(events, cfg, w_q)
    return curvefit_reduce(params, cfg, ideal, lk, events.shape[0])


def p2m_forward_curvefit_coeffs(params: Params, events: jax.Array,
                                cfg: P2MConfig, coeffs: leakage.LeakCoeffs
                                ) -> tuple[jax.Array, jax.Array]:
    """Single-config curve-fit forward, re-linearizing the leak from the
    *current* (quantized) weights via branch-free coefficients.

    Unlike :func:`p2m_forward_curvefit` (which takes ``cfg.leak`` and
    branches on the circuit in python), the circuit here is encoded in
    ``coeffs``, so this function vmaps over a stacked config axis and is
    differentiable w.r.t. ``params`` end-to-end, including the
    kernel-dependent leak of circuit (a).
    """
    w_q = effective_weights(params, cfg)
    lk = leakage.leak_params_from_coeffs(w_q, coeffs)
    v_pre = _curvefit_from_lk(params, events, cfg, w_q, lk)
    spikes = spike_fn(v_pre - coeffs.v_threshold)
    return spikes, v_pre


def p2m_forward_curvefit_grouped(params_s: Params, events: jax.Array,
                                 cfg: P2MConfig,
                                 leak_cfgs: tuple[LeakageConfig, ...]
                                 ) -> tuple[jax.Array, jax.Array]:
    """Curve-fit forward with PER-CONFIG layer-1 params (unfrozen phase 2).

    Every leaf of ``params_s`` carries a leading ``[n_cfg]`` axis — one
    learned copy per circuit config. Returns (spikes, v_pre), both
    [n_cfg, B, T_out, H', W', C_out]. Each config's leak linearization is
    recomputed from its own weights, so ``jax.grad`` through this function
    gives each config an independent layer-1 gradient (surrogate gradient
    through the spike nonlinearity, straight-through through the weight
    quantizer).
    """
    coeffs = leakage.stacked_leak_coeffs(leak_cfgs, cfg.v_threshold)
    return jax.vmap(
        lambda p, co: p2m_forward_curvefit_coeffs(p, events, cfg, co)
    )(params_s, coeffs)


def stack_p2m_params(params: Params, n_cfg: int) -> Params:
    """Replicate layer-1 params onto a leading [n_cfg] config axis — the
    starting point of the unfrozen phase-2 finetune (every circuit config
    starts from the shared phase-1 pretrained kernel)."""
    return jax.tree.map(lambda x: jnp.stack([x] * n_cfg), params)


def p2m_forward_curvefit(params: Params, events: jax.Array, cfg: P2MConfig
                         ) -> tuple[jax.Array, jax.Array]:
    """The paper's trainable model: leak-weighted linear conv → curve fit.

    The exact solution of the leak ODE for impulse drive at sub-slot k with
    readout at slot n is a decay weight a^(n-k) (a = e^{-dt/τ̄}); we fold the
    kernel-dependent τ into a single mean decay per filter and push the
    weighted sum through the fitted non-linearity (paper §2: curve-fitting
    function accounting for non-linearity, non-ideality, process variation).
    """
    spikes, v_pre = p2m_forward_curvefit_stacked(params, events, cfg,
                                                 (cfg.leak,))
    return spikes[0], v_pre[0]


def p2m_forward_curvefit_stacked(params: Params, events: jax.Array,
                                 cfg: P2MConfig,
                                 leak_cfgs: tuple[LeakageConfig, ...]
                                 ) -> tuple[jax.Array, jax.Array]:
    """Curve-fit model under a stacked circuit-config axis.

    The vmap runs :func:`_curvefit_from_lk` over the leak params only —
    the per-sub-slot ideal conv does not depend on the mapped axis, so it
    stays unbatched (computed ONCE) and each config reduces it with its
    own [n_sub, C_out] decay weights: sweeping n_cfg circuits costs one
    conv plus n_cfg cheap einsums.
    Returns (spikes, v_pre), both [n_cfg, B, T_out, H', W', C_out].
    """
    w_q = effective_weights(params, cfg)
    lk = leakage.stacked_leak_params(w_q, leak_cfgs)          # [n_cfg, C_out]
    v_pre = jax.vmap(
        lambda l: _curvefit_from_lk(params, events, cfg, w_q, l))(lk)
    spikes = spike_fn(v_pre - stacked_thetas(cfg, leak_cfgs, v_pre.ndim))
    return spikes, v_pre


def p2m_apply(params: Params, events: jax.Array, cfg: P2MConfig,
              ) -> tuple[jax.Array, jax.Array]:
    """Dispatch on cfg.mode. events: [B, T_out, n_sub, H, W, C_in]."""
    if cfg.mode == "scan":
        return p2m_forward_scan(params, events, cfg)
    if cfg.mode == "curvefit":
        return p2m_forward_curvefit(params, events, cfg)
    if cfg.mode == "kernel":
        from repro.kernels.p2m_conv import ops as p2m_ops
        return p2m_ops.p2m_conv(params, events, cfg)
    raise ValueError(f"unknown mode {cfg.mode}")


def p2m_apply_stacked(params: Params, events: jax.Array, cfg: P2MConfig,
                      leak_cfgs: tuple[LeakageConfig, ...]
                      ) -> tuple[jax.Array, jax.Array]:
    """Batched dispatch on cfg.mode over a circuit-config axis.

    events: [B, T_out, n_sub, H, W, C_in] → (spikes, v_pre), both
    [n_cfg, B, T_out, H', W', C_out]. ``leak_cfgs`` overrides ``cfg.leak``;
    mode "kernel" runs the multi-config Pallas grid, "scan"/"curvefit" the
    vectorized XLA paths.
    """
    if cfg.mode == "scan":
        return p2m_forward_scan_stacked(params, events, cfg, leak_cfgs)
    if cfg.mode == "curvefit":
        return p2m_forward_curvefit_stacked(params, events, cfg, leak_cfgs)
    if cfg.mode == "kernel":
        from repro.kernels.p2m_conv import ops as p2m_ops
        return p2m_ops.p2m_conv_multi(params, events, cfg, leak_cfgs)
    raise ValueError(f"unknown mode {cfg.mode}")


def coarsen_spikes(spikes: jax.Array, group: int) -> jax.Array:
    """Sum fine-grid binary spikes onto the backbone's coarse grid.

    spikes: [B, T_fine, ...] → [B, T_fine//group, ...] (multi-bit counts).
    """
    B, T = spikes.shape[:2]
    assert T % group == 0, (T, group)
    return spikes.reshape((B, T // group, group) + spikes.shape[2:]).sum(axis=2)
