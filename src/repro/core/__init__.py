"""The paper's contribution: P²M in-pixel analog first layer for neuromorphic
vision sensors, its circuit-level leakage models, and the hardware-algorithm
co-design sweep."""
from repro.core.analog import AnalogConfig  # noqa: F401
from repro.core.leakage import CircuitConfig, LeakageConfig  # noqa: F401
from repro.core.p2m_layer import P2MConfig, p2m_apply, p2m_init  # noqa: F401
from repro.core.snn import LIFConfig, SpikingCNNConfig  # noqa: F401
