"""pjit train-step builders for the LM-family archs.

``build_train_step`` returns (jitted_fn, arg ShapeDtypeStructs) so the same
artifact serves real training (feed arrays) and the multi-pod dry-run
(``.lower(*specs).compile()``).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, ShapeConfig
from repro.models import encdec, lm
from repro.optim import adamw, clip_by_global_norm
from repro.optim.optimizers import apply_updates
from repro.sharding import rules

PyTree = Any


def _loss_for(cfg: LMConfig):
    return encdec.loss_fn if cfg.is_encdec else lm.loss_fn


def make_batch_specs(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh
                     ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs (with shardings) for one global training batch."""
    B, S = shape.global_batch, shape.seq_len
    specs = rules.input_pspecs(cfg, shape, mesh)
    cdt = jnp.dtype(cfg.compute_dtype)
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=NamedSharding(mesh, specs["tokens"])),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=NamedSharding(mesh, specs["labels"])),
    }
    if cfg.family == "vlm":
        out["img_embed"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.vision_dim), cdt,
            sharding=NamedSharding(mesh, specs["img_embed"]))
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), cdt,
            sharding=NamedSharding(mesh, specs["frames"]))
    return out


def param_structs(cfg: LMConfig, mesh: Mesh) -> tuple[PyTree, PyTree]:
    """(ShapeDtypeStruct tree with shardings, pspec tree) — no allocation."""
    init = encdec.init_params if cfg.is_encdec else lm.init_params
    shapes = jax.eval_shape(partial(init, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = rules.param_pspecs(shapes, cfg, mesh)
    with_sharding = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return with_sharding, pspecs


def opt_structs(opt, param_structs_tree: PyTree, param_pspecs: PyTree,
                cfg: LMConfig, mesh: Mesh) -> tuple[PyTree, PyTree]:
    shapes = jax.eval_shape(opt.init, param_structs_tree)
    moment_specs = rules.zero1_pspecs(param_pspecs, param_structs_tree, mesh, cfg)
    specs = {"mu": moment_specs, "nu": moment_specs, "step": P()}
    sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return sds, specs


def build_train_step(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh,
                     lr: float = 3e-4, grad_clip: float = 1.0,
                     donate: bool = True, grad_accum: int = 1):
    """Returns (jitted_step, (params_sds, opt_sds, batch_sds)).

    ``grad_accum > 1`` splits the global batch into that many microbatches
    scanned inside the step (mean-of-gradients — bit-exact in expectation
    with the single-shot step). Two users: activation-memory relief for the
    big archs, and the elastic planner (ft/elastic.py), whose re-mesh plans
    restore the exact global batch on fewer chips via accumulation.
    """
    opt = adamw(lr)
    loss_fn = _loss_for(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)

    def step(params, opt_state, batch):
        if grad_accum > 1:
            B = shape.global_batch
            assert B % grad_accum == 0, (B, grad_accum)
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, B // grad_accum)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                g_sum, loss_sum = carry
                (loss, _), g = grads_of(params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (g_sum, loss_sum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g_sum, loss_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, g_sum)
            loss = loss_sum / grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, gnorm=gnorm)
        return params, opt_state, metrics

    p_sds, p_specs = param_structs(cfg, mesh)
    o_sds, o_specs = opt_structs(opt, p_sds, p_specs, cfg, mesh)
    b_sds = make_batch_specs(cfg, shape, mesh)

    out_shardings = (
        jax.tree.map(lambda s: s.sharding, p_sds,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
        jax.tree.map(lambda s: s.sharding, o_sds,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
        NamedSharding(mesh, P()),
    )
    jitted = jax.jit(step,
                     donate_argnums=(0, 1) if donate else (),
                     out_shardings=out_shardings)
    return jitted, (p_sds, o_sds, b_sds), opt
