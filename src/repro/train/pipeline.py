"""Pipeline parallelism (GPipe fill–drain) over a "pipe" mesh axis.

Why a third parallelism kind: at 1000+ nodes the (data × model) plane hits
diminishing returns — TP beyond one pod's ICI reach is collective-bound and
DP multiplies optimizer memory. Splitting the *layer stack* into S stages
multiplies reachable model size by S with only point-to-point
(collective-permute) traffic between neighbours, which maps exactly onto
TPU ICI links.

Implementation (pure JAX, shard_map-friendly):

  * stage-stacked params: every leaf is [S, n_layers/S, ...], sharded
    P("pipe", ...) — each pipe group holds one stage's layers;
  * the schedule runs T = M + S − 1 ticks (M = microbatches). At tick t,
    stage s processes microbatch (t − s); activations hop s → s+1 via
    ``jax.lax.ppermute``. The classic rotating-buffer formulation keeps
    the loop body identical per tick (scan-able, SPMD-uniform);
  * loss is computed on the LAST stage's slots and psum'd; ``jax.grad``
    differentiates straight through the ppermute schedule — the reverse
    schedule (activations flow backward) emerges from AD, no hand-written
    backward pass.

This module is self-contained on top of models/lm._dense_block_fwd — the
PP mesh (pipe, data, model) is an additional deployment mode, exercised by
its own dry-run entry (launch/dryrun_pp.py) and subprocess tests; the
assigned 40-cell sweep stays on the spec meshes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.models import lm
from repro.nn import layers as L

Params = dict


def make_pp_mesh(pipe: int = 4, data: int = 8, model: int = 8) -> Mesh:
    """(pipe, data, model) mesh — pipe stages map to ICI-neighbour groups."""
    return jax.make_mesh((pipe, data, model), ("pipe", "data", "model"))


def stage_params(key: jax.Array, cfg: LMConfig, n_stages: int) -> Params:
    """Init dense-family params with blocks reshaped [S, L/S, ...]."""
    assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
    params = lm.init_params(key, cfg)
    per = cfg.n_layers // n_stages
    params["blocks"] = jax.tree.map(
        lambda x: x.reshape((n_stages, per) + x.shape[1:]), params["blocks"])
    return params


def stage_pspecs(params: Params, cfg: LMConfig, mesh: Mesh) -> Params:
    """blocks shard over "pipe" (stage-major); embed/final replicate over
    pipe and follow the usual TP rules on their own axes."""
    from repro.sharding import rules

    def drop_stage_dim(x):
        # works for arrays and ShapeDtypeStructs alike
        return jax.ShapeDtypeStruct(x.shape[1:], x.dtype)

    base = rules.param_pspecs({**params, "blocks": jax.tree.map(
        drop_stage_dim, params["blocks"])}, cfg, mesh)

    def prepend_pipe(spec: P) -> P:
        return P("pipe", *tuple(spec))

    return {**base,
            "blocks": jax.tree.map(
                lambda s: prepend_pipe(s), base["blocks"],
                is_leaf=lambda x: isinstance(x, P))}


def _block_stack_fwd(h: jax.Array, stage_blocks: Params, cfg: LMConfig
                     ) -> jax.Array:
    """Run one stage's [L/S, ...] blocks over h (dense family)."""
    def body(hh, bp):
        hh, _ = lm._dense_block_fwd(hh, bp, cfg, None)
        return hh, None
    h, _ = lax.scan(body, h, stage_blocks)
    return h


def pipeline_apply(params: Params, tokens: jax.Array, labels: jax.Array,
                   cfg: LMConfig, mesh: Mesh, n_microbatches: int
                   ) -> jax.Array:
    """Mean CE loss of the pipelined forward. tokens/labels [B, T].

    Embedding and the LM head run on every stage (cheap, replicated over
    pipe) but only the first/last stage's results are *used*; the interior
    transformer stack — the expensive part — is stage-parallel.
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))["pipe"]
    M = n_microbatches
    B = tokens.shape[0]
    assert B % M == 0, (B, M)

    def staged(blocks_stage, embed, final_norm, tok_mb, lab_mb):
        """shard_map body: runs on ONE pipe group. blocks_stage is this
        stage's [L/S, ...] params; embed/final_norm replicate; tok/lab are
        [M, B/M(/data), T]."""
        sid = lax.axis_index("pipe")
        T = M + S - 1
        # drop the size-1 pipe-shard dim: local view is [1, L/S, ...]
        blocks_stage = jax.tree.map(lambda x: x[0], blocks_stage)

        # rotating slot: each stage keeps one in-flight activation
        h0 = jnp.zeros(tok_mb.shape[1:] + (cfg.d_model,), L.cdt(cfg))

        def tick(carry, t):
            slot, acc_loss, acc_cnt = carry
            mb = t - sid                       # microbatch this stage sees
            active = (mb >= 0) & (mb < M)

            # stage 0 ingests a fresh microbatch (embedding)
            tok_t = tok_mb[jnp.clip(t, 0, M - 1)]
            fresh = L.embed_apply(embed, tok_t, cfg)
            h_in = jnp.where((sid == 0) & active, fresh, slot)

            # the stage's block stack
            h_out = _block_stack_fwd(h_in, blocks_stage, cfg)
            h_out = jnp.where(active, h_out, slot)

            # last stage computes loss for its finished microbatch
            lab_t = lab_mb[jnp.clip(t - (S - 1), 0, M - 1)]
            hn = L.rmsnorm(h_out, final_norm, cfg.norm_eps)
            ce = L.chunked_cross_entropy(embed, hn, lab_t, cfg)
            take = (sid == S - 1) & active
            acc_loss = acc_loss + jnp.where(take, ce, 0.0)
            acc_cnt = acc_cnt + jnp.where(take, 1.0, 0.0)

            # hop activations to the next stage (ring; last→0 is ignored)
            slot = lax.ppermute(h_out, "pipe",
                                [(i, (i + 1) % S) for i in range(S)])
            return (slot, acc_loss, acc_cnt), None

        (slot, loss_sum, cnt), _ = lax.scan(
            tick, (h0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(T))
        # combine over BOTH the pipe stages (only the last contributes) and
        # the data shards (each computed its local microbatch mean); every
        # member then holds the same global mean loss
        loss = lax.psum(loss_sum, ("pipe", "data")) / jnp.maximum(
            lax.psum(cnt, ("pipe", "data")), 1.0)
        return loss[None]

    tok_mb = tokens.reshape(M, B // M, tokens.shape[1])
    lab_mb = labels.reshape(M, B // M, labels.shape[1])

    embed_specs = jax.tree.map(lambda _: P(), params["embed"])
    fn = shard_map(
        staged, mesh=mesh,
        in_specs=(P("pipe"), embed_specs, P(),
                  P(None, "data", None), P(None, "data", None)),
        out_specs=P("pipe"),
        check_rep=False)
    losses = fn(params["blocks"], params["embed"], params["final_norm"],
                tok_mb, lab_mb)
    return jnp.mean(losses)


def build_pp_train_step(cfg: LMConfig, mesh: Mesh, *, n_microbatches: int,
                        lr: float = 3e-4):
    """pjit'd PP train step (loss + SGD update on the stage params)."""

    def step(params, tokens, labels):
        def loss_fn(p):
            return pipeline_apply(p, tokens, labels, cfg, mesh,
                                  n_microbatches)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                              params, grads)
        return params, loss

    return jax.jit(step)
