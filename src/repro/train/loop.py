"""The production training loop: data cursor, checkpoint/restart, straggler
monitoring, preemption handling, metrics.

Composes the pieces that are individually unit-tested:

  train/steps.build_train_step   pjit'd step (params/opt donated)
  data/tokens.TokenLoader        step-keyed batches → exact restart replay
  checkpoint.CheckpointManager   async atomic checkpoints + retention
  ft.StragglerMonitor            per-step EMA/kσ outlier flags
  ft.PreemptionGuard             SIGTERM → drain + final checkpoint

The loop is deliberately synchronous-SPMD shaped: one jitted step per
iteration, everything else (I/O, monitors) off the critical path. On a real
pod this file is what each host runs; on CPU the examples run it with a tiny
config and a host mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import LMConfig, ShapeConfig
from repro.data.tokens import TokenLoader, TokenStreamConfig
from repro.ft import PreemptionGuard, StragglerMonitor
from repro.train.steps import build_train_step

PyTree = Any


@dataclass
class LoopConfig:
    total_steps: int = 200
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "artifacts/ckpt"
    ckpt_keep: int = 2
    ckpt_async: bool = True
    lr: float = 3e-4
    seed: int = 0
    straggler_k_sigma: float = 4.0
    on_straggler: str = "log"       # log | checkpoint


@dataclass
class LoopResult:
    final_step: int
    losses: list = field(default_factory=list)
    straggler_flags: int = 0
    preempted: bool = False
    restored_from: int | None = None


def init_train_state(cfg: LMConfig, mesh, step_artifacts) -> tuple[PyTree, PyTree]:
    """Materialize params + opt state with the shardings the step expects."""
    p_sds, o_sds, _ = step_artifacts
    from repro.models import encdec, lm
    init = encdec.init_params if cfg.is_encdec else lm.init_params

    p_shardings = jax.tree.map(lambda s: s.sharding, p_sds,
                               is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    params = jax.jit(lambda k: init(k, cfg),
                     out_shardings=p_shardings)(jax.random.PRNGKey(0))
    return params, p_shardings


def run(cfg: LMConfig, shape: ShapeConfig, mesh, loop: LoopConfig,
        log: Callable[[str], None] = print,
        extra_batch_fn: Callable[[dict], dict] | None = None) -> LoopResult:
    """Train ``cfg`` on the synthetic token stream. Restartable: if a
    committed checkpoint exists under ``loop.ckpt_dir`` it resumes from it
    (params, opt state, data cursor)."""
    result = LoopResult(final_step=0)

    with mesh:
        step_fn, (p_sds, o_sds, b_sds), opt = build_train_step(
            cfg, shape, mesh, lr=loop.lr)
        params, p_shardings = init_train_state(cfg, mesh, (p_sds, o_sds, b_sds))
        o_shardings = jax.tree.map(
            lambda s: s.sharding, o_sds,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        opt_state = jax.jit(opt.init, out_shardings=o_shardings)(params)

        data_cfg = TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=loop.seed)
        loader = TokenLoader(data_cfg)

        ckpt = CheckpointManager(loop.ckpt_dir, every_steps=loop.ckpt_every,
                                 keep=loop.ckpt_keep)
        restored = ckpt.restore(shardings={"params": p_shardings,
                                           "opt": o_shardings})
        start_step = 0
        if restored is not None:
            tree, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            start_step = int(extra.get("step", 0))
            loader.seek(start_step)
            result.restored_from = start_step
            log(f"[loop] restored from step {start_step}")

        monitor = StragglerMonitor(k_sigma=loop.straggler_k_sigma)
        with PreemptionGuard() as guard:
            for step in range(start_step, loop.total_steps):
                t0 = time.perf_counter()
                _, batch = next(loader)
                if extra_batch_fn is not None:
                    batch = extra_batch_fn(batch)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0

                if monitor.observe(step, dt):
                    result.straggler_flags += 1
                    log(f"[loop] step {step}: straggler flagged "
                        f"({dt:.3f}s vs mean {monitor.mean_s:.3f}s)")
                    if loop.on_straggler == "checkpoint":
                        ckpt.save(step + 1, {"params": params, "opt": opt_state},
                                  extra={"step": step + 1},
                                  blocking=not loop.ckpt_async)

                if step % loop.log_every == 0:
                    log(f"[loop] step {step} loss={loss:.4f} "
                        f"gnorm={float(metrics['gnorm']):.3f} dt={dt:.3f}s")
                result.losses.append(loss)

                if ckpt.should_save(step + 1):
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              extra={"step": step + 1},
                              blocking=not loop.ckpt_async)

                if guard.preempted:
                    log(f"[loop] preempted at step {step}; draining")
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              extra={"step": step + 1}, blocking=True)
                    result.preempted = True
                    result.final_step = step + 1
                    return result

                result.final_step = step + 1

        ckpt.wait()
    return result
