"""Paper Fig 4: pre-activation voltage traces for circuit configs (a)/(b)/(c).

(4a) retention with no input events at T_INTG = 10 ms;
(4b-d) driven traces at T_INTG ∈ {1, 10, 100} ms vs the IDEAL (no-leak)
voltage, for random 3×3 kernels — reporting the mean |ΔV| from ideal per
(config, T) plus the full traces in the JSON artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import leakage
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig, p2m_forward_scan_stacked, \
    p2m_forward_scan, p2m_init

from benchmarks.common import emit, save_json


def retention_traces(t_ms: float = 10.0, n_points: int = 50) -> dict:
    """Fig 4a: V(t) under no drive, starting from a stored value.

    Uses the shared engine API (leakage.retention_traces over
    leakage.paper_circuits) — the circuit constants live in leakage.py only.
    """
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (3, 3, 2, 8)) * 0.5
    v0 = 0.2
    ts = jnp.linspace(0.0, t_ms, n_points)
    cfgs = leakage.paper_circuits()
    traces = leakage.retention_traces(w, cfgs, ts, v0)     # [n_cfg, n_t, F]
    out = {"t_ms": ts.tolist()}
    for c, vs in zip(cfgs, traces):
        out[c.circuit.value] = vs.tolist()
        final_err = float(jnp.mean(jnp.abs(vs[-1] - v0)))
        emit(f"fig4a/config_{c.circuit.value}", None,
             f"dV_at_{t_ms}ms={final_err * 1e3:.2f}mV")
    return out


def driven_error(t_grid=(1.0, 10.0, 100.0)) -> dict:
    """Fig 4b-d: |V_pre − V_ideal| at the comparator for driven input.

    One stacked scan covers all three circuits per T_INTG (the batched
    engine path) instead of a python loop per config.
    """
    out = {}
    key = jax.random.PRNGKey(1)
    leak_cfgs = leakage.paper_circuits()
    for t_ms in t_grid:
        cfg = P2MConfig(out_channels=8, n_sub=4, t_intg_ms=t_ms, mode="scan")
        params = p2m_init(key, cfg)
        ev = jax.random.poisson(jax.random.fold_in(key, 7), 0.3,
                                (2, 2, 4, 12, 12, 2)).astype(jnp.float32)
        _, v_all = p2m_forward_scan_stacked(params, ev, cfg, leak_cfgs)
        cfg_i = P2MConfig(out_channels=8, n_sub=4, t_intg_ms=t_ms,
                          mode="scan",
                          leak=LeakageConfig(circuit=CircuitConfig.IDEAL))
        _, v_i = p2m_forward_scan(params, ev, cfg_i)
        row = {}
        for c, v in zip(leak_cfgs, v_all):
            err_mv = float(jnp.mean(jnp.abs(v - v_i))) * 1e3
            row[c.circuit.value] = err_mv
            emit(f"fig4bcd/t{int(t_ms)}ms/config_{c.circuit.value}", None,
                 f"mean_err={err_mv:.2f}mV")
        out[f"t{int(t_ms)}ms"] = row
    return out


def variant_retention(t_grid=(1.0, 10.0, 100.0)) -> dict:
    """Tri-Design-style variant surface: expand the registry's stacked axes
    (mismatch × process-variation sigma) over circuit (c) via
    ``variant_grid.expand_variants`` and report the retention-error surface
    per variant — the physics behind the sweep engine's wider grid."""
    from repro.core import sweep as engine
    from repro.core.variant_grid import variant_label

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (3, 3, 2, 8)) * 0.5
    grid = engine.SweepGrid(circuits=(CircuitConfig.NULLIFIED,),
                            null_mismatch=(0.02, 0.06, 0.2),
                            sigma=(0.0, 0.1))
    cfgs = engine.expand_leak_configs(grid, LeakageConfig())
    surf = leakage.retention_surface(w, cfgs, t_grid)       # [n_cfg, n_t]
    out = {"t_grid_ms": list(t_grid)}
    for lc, row in zip(cfgs, surf):
        lab = variant_label(lc)
        out[lab] = [float(x) for x in row]
        emit(f"fig4a/variant_{lab}", None,
             f"dV_at_{t_grid[-1]:g}ms={float(row[-1]) * 1e3:.2f}mV")
    return out


def run(fast: bool = False) -> dict:
    out = {"retention": retention_traces(),
           "driven": driven_error((1.0, 10.0) if fast else (1.0, 10.0, 100.0)),
           "variants": variant_retention((1.0, 10.0) if fast
                                         else (1.0, 10.0, 100.0))}
    save_json("fig4", out)
    return out


if __name__ == "__main__":
    run()
