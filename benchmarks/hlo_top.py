"""Rank HLO computations/instructions by roofline contribution — the
'profiler' of the dry-run world (§Perf: the profile is lowered.as_text()).

    PYTHONPATH=src python -m benchmarks.hlo_top artifacts/dryrun/X.hlo [N]

Uses the same loop-aware cost model as the roofline report: per-computation
direct bytes/flops × the product of enclosing while trip counts.
"""
from __future__ import annotations

import re
import sys
from collections import defaultdict

from repro.roofline.hlo import (_CALLS_RE, _COLLECTIVE_KINDS, _TO_APPLY_RE,
                                _TRIP_RE, _WHILE_RE, HloCostModel, _bytes_of)


class Profiler(HloCostModel):
    def scales(self) -> dict[str, float]:
        """computation → how many times it executes per step."""
        entry = None
        for name in self.comps:
            if entry is None:
                entry = name
        # find real ENTRY: the one nobody calls
        called = set()
        for comp, instrs in self.comps.items():
            for ins in instrs:
                for m in re.finditer(r"(?:calls|to_apply|condition|body)="
                                     r"%?([\w\.\-]+)", ins.rest + ins.line):
                    called.add(m.group(1))
        roots = [c for c in self.comps if c not in called]
        scale: dict[str, float] = defaultdict(float)
        for r in roots:
            scale[r] = 1.0

        # propagate in call order (iterate to fixpoint; DAG so bounded)
        for _ in range(60):
            changed = False
            for comp, instrs in self.comps.items():
                s = scale.get(comp, 0.0)
                if s == 0.0:
                    continue
                for ins in instrs:
                    mult = s
                    if ins.opcode == "while":
                        tm = _TRIP_RE.search(ins.line)
                        trips = int(tm.group(1)) if tm else 1
                        wm = _WHILE_RE.search(ins.rest)
                        if wm:
                            for target in wm.groups():
                                if scale.get(target, 0.0) < mult * trips:
                                    scale[target] = mult * trips
                                    changed = True
                    else:
                        for m in re.finditer(r"(?:calls|to_apply)="
                                             r"%?([\w\.\-]+)", ins.rest):
                            if scale.get(m.group(1), 0.0) < mult:
                                scale[m.group(1)] = mult
                                changed = True
            if not changed:
                break
        return dict(scale)

    def direct_rows(self):
        """(bytes, flops, comp, instr-label) for non-fused boundary instrs."""
        scale = self.scales()
        rows = []
        colls = []
        for comp, instrs in self.comps.items():
            s = scale.get(comp, 0.0)
            if s == 0.0 or comp in self._fused:
                continue
            table = self._table_for(comp)
            for ins in instrs:
                if ins.opcode in ("while", "parameter", "constant",
                                  "get-tuple-element", "tuple", "bitcast"):
                    continue
                b = (_bytes_of(ins.type_str) +
                     self._operand_bytes(ins, table)) * s
                if b > 0:
                    rows.append((b, comp, s,
                                 f"{ins.opcode:16} {ins.type_str[:58]}"))
                if any(ins.opcode.startswith(k) for k in _COLLECTIVE_KINDS) \
                        and not ins.opcode.endswith("-done"):
                    nb = self._operand_bytes(ins, table) or _bytes_of(
                        ins.type_str)
                    colls.append((nb * s, comp, s,
                                  f"{ins.opcode:18} {ins.type_str[:52]}"))
        return rows, colls


def main() -> int:
    path = sys.argv[1]
    topn = int(sys.argv[2]) if len(sys.argv) > 2 else 25
    prof = Profiler(open(path).read())
    rows, colls = prof.direct_rows()
    rows.sort(key=lambda r: -r[0])
    total = sum(r[0] for r in rows)
    print(f"== {path} ==")
    print(f"total boundary bytes (x trips): {total:.3e}")
    for b, comp, s, label in rows[:topn]:
        print(f"  {b:11.3e} ({100 * b / total:5.1f}%) x{s:<7.0f} {label}  "
              f"[{comp[:30]}]")
    if colls:
        colls.sort(key=lambda r: -r[0])
        ctot = sum(r[0] for r in colls)
        print(f"\n== collectives: {ctot:.3e} B ==")
        for b, comp, s, label in colls[:15]:
            print(f"  {b:11.3e} ({100 * b / ctot:5.1f}%) x{s:<7.0f} {label}  "
                  f"[{comp[:30]}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
