"""Paper Fig 2: normalized bandwidth (left) and backend energy improvement
(right) vs T_INTG for both datasets. Uses the same sweep machinery as
Table 1 but reports the bandwidth/energy columns (they come from the same
records; a separate artifact keeps one benchmark per paper figure).

``data_root`` swaps both columns onto the file-backed datasets — the same
plumbing as ``table1_acc_traintime`` (a directory holding ``DvsGesture``
AEDAT files and an N-MNIST tree; held-out eval split when it exists;
metric keys gain a ``file/`` prefix so the synthetic series stays
continuous). Short recordings (real N-MNIST ≈ 300 ms) shrink the grid to
the T_INTG points that fit the stream.
"""
from __future__ import annotations

from benchmarks.common import emit, save_json
from benchmarks.table1_acc_traintime import GRID, _data, _model

from repro.core import codesign
from repro.core import sweep as engine
from repro.core.codesign import SweepConfig


def run(fast: bool = False, data_root: str | None = None) -> dict:
    t_grid = GRID if not fast else (10.0, 1000.0)
    out = {}
    src_tag = "" if data_root is None else "file/"
    for kind in ("gesture", "nmnist"):
        hw = 24 if kind == "gesture" else 20
        data, eval_data = _data(kind, hw, data_root)
        # short recordings shrink the coarse window and drop T points
        # that no longer fit the stream (table1 parity)
        coarse = min(1000.0, data.duration_ms)
        t_ok = engine.fit_t_grid(t_grid, data.duration_ms, coarse)
        sweep = SweepConfig(
            t_intg_grid_ms=t_ok,
            batch_size=4, pretrain_steps=12 if not fast else 3,
            finetune_steps=4 if not fast else 2,
            eval_batches=8 if not fast else 2, lr=2e-3, seed=1)
        recs = codesign.run_sweep(
            data, _model(hw, 11 if kind == "gesture" else 10, coarse),
            sweep, log=lambda *_: None, eval_data=eval_data)
        out[kind] = recs
        for r in recs:
            emit(f"fig2/{src_tag}{kind}/t{int(r['t_intg_ms'])}ms", None,
                 f"bw_norm={r['bandwidth_norm']:.3f};"
                 f"energy_impr={r['energy_improvement']:.2f}x")
    save_json("fig2", out)
    return out


if __name__ == "__main__":
    run()
