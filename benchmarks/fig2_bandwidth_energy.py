"""Paper Fig 2: normalized bandwidth (left) and backend energy improvement
(right) vs T_INTG for both datasets. Uses the same sweep machinery as
Table 1 but reports the bandwidth/energy columns (they come from the same
records; a separate artifact keeps one benchmark per paper figure)."""
from __future__ import annotations

from benchmarks.common import emit, save_json
from benchmarks.table1_acc_traintime import GRID, _data, _model

from repro.core import codesign
from repro.core.codesign import SweepConfig


def run(fast: bool = False) -> dict:
    sweep = SweepConfig(
        t_intg_grid_ms=GRID if not fast else (10.0, 1000.0),
        batch_size=4, pretrain_steps=12 if not fast else 3,
        finetune_steps=4 if not fast else 2,
        eval_batches=8 if not fast else 2, lr=2e-3, seed=1)
    out = {}
    for kind in ("gesture", "nmnist"):
        hw = 24 if kind == "gesture" else 20
        recs = codesign.run_sweep(_data(kind, hw), _model(
            hw, 11 if kind == "gesture" else 10), sweep,
            log=lambda *_: None)
        out[kind] = recs
        for r in recs:
            emit(f"fig2/{kind}/t{int(r['t_intg_ms'])}ms", None,
                 f"bw_norm={r['bandwidth_norm']:.3f};"
                 f"energy_impr={r['energy_improvement']:.2f}x")
    save_json("fig2", out)
    return out


if __name__ == "__main__":
    run()
