"""Benchmark orchestrator — one benchmark per paper table/figure + kernel
µbenches + the roofline report.

    PYTHONPATH=src python -m benchmarks.run          # full
    PYTHONPATH=src python -m benchmarks.run --fast   # CI-scale

Emits ``name,us_per_call,derived`` CSV lines; JSON artifacts land in
artifacts/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids/steps (CI)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of benches to run, e.g. "
                         "'kernels,stream' "
                         "(table1|fig2|fig4|kernels|roofline|stream|"
                         "stream_adapt)")
    args = ap.parse_args()

    from benchmarks import (fig2_bandwidth_energy, fig4_leakage, kernel_bench,
                            roofline_report, stream_adapt, stream_serving,
                            table1_acc_traintime)

    benches = {
        "table1": table1_acc_traintime.run,
        "fig2": fig2_bandwidth_energy.run,
        "fig4": fig4_leakage.run,
        "kernels": kernel_bench.run,
        "roofline": roofline_report.run,
        "stream": stream_serving.run,
        "stream_adapt": stream_adapt.run,
    }
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in benches]
        if unknown:
            print(f"error: unknown bench(es) {unknown}; choose from "
                  f"{sorted(benches)}", file=sys.stderr)
            return 2
        benches = {n: benches[n] for n in names}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            fn(fast=args.fast)
            print(f"bench/{name},{(time.perf_counter() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"bench/{name},-,FAILED:{type(e).__name__}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
