"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

ARTIFACTS = Path("artifacts/bench")


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> tuple[float, object]:
    """(seconds per call, last result) with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def emit(name: str, us_per_call: float | None, derived: str) -> None:
    us = f"{us_per_call:.1f}" if us_per_call is not None else "-"
    print(f"{name},{us},{derived}", flush=True)


def save_json(name: str, payload: dict) -> Path:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    p = ARTIFACTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=float))
    return p
