"""Shared benchmark utilities.

Two artifact tiers (docs/benchmarks.md):

* ``save_json`` — full per-bench payloads under artifacts/bench/
  (gitignored scratch, whatever shape each bench wants);
* ``bench_record`` — the schema-versioned perf trajectory. One
  ``BENCH_<name>.json`` per bench family at the **repo root**, committed,
  so ``git log -p BENCH_kernels.json`` reads as a performance history.
  ``tools/check_bench.py`` validates the schema and diffs a fresh record
  against the committed one to flag regressions.
"""
from __future__ import annotations

import datetime
import json
import subprocess
import time
from pathlib import Path

import jax

ARTIFACTS = Path("artifacts/bench")
REPO = Path(__file__).resolve().parents[1]

#: Version tag of the BENCH_*.json trajectory record layout. Bump on any
#: backwards-incompatible change and teach tools/check_bench.py both.
BENCH_SCHEMA = "p2m-bench/v1"


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> tuple[float, object]:
    """(seconds per call, last result) with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def emit(name: str, us_per_call: float | None, derived: str) -> None:
    us = f"{us_per_call:.1f}" if us_per_call is not None else "-"
    print(f"{name},{us},{derived}", flush=True)


def save_json(name: str, payload: dict) -> Path:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    p = ARTIFACTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=float))
    return p


def _git_commit() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO, capture_output=True,
            text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def bench_entry(name: str, *, xla_us: float | None = None,
                kernel_us: float | None = None,
                max_err: float | None = None,
                meta: dict | None = None) -> dict:
    """One trajectory entry: oracle-path vs kernel-path timing + parity."""
    return {"name": name, "xla_us": xla_us, "kernel_us": kernel_us,
            "max_err": max_err, "meta": meta or {}}


def bench_record(name: str, entries: list[dict],
                 extra: dict | None = None, root: Path | None = None) -> Path:
    """Write the ``BENCH_<name>.json`` perf-trajectory record at repo root.

    ``entries`` come from :func:`bench_entry`. Timings are in µs per call;
    ``max_err`` is the kernel-vs-oracle parity at benchmark scale (the
    number CI gates on — timings on shared runners are context, not a
    contract). ``extra`` lands under ``"context"`` for bench-specific
    scalars (shapes, throughput).
    """
    record = {
        "schema": BENCH_SCHEMA,
        "name": name,
        "commit": _git_commit(),
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "generated": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "entries": entries,
    }
    if extra:
        record["context"] = extra
    p = (root or REPO) / f"BENCH_{name}.json"
    p.write_text(json.dumps(record, indent=2, default=float) + "\n")
    return p
