"""Fill docs/benchmarks.md's <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE -->
/ <!-- FLEET_TABLE --> markers from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path("artifacts/dryrun")
EXP = Path(__file__).resolve().parents[1] / "docs" / "benchmarks.md"

_LEVER = {
    "compute": "more per-chip work (larger microbatch) / fuse small ops",
    "memory": "fuse chains (TPU backend), bf16 activations, Pallas kernels",
    "collective": "increase DP fraction, overlap, compress cross-pod legs",
}


def recs():
    out = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if "shape" not in r:
            continue   # non-standard modes (e.g. the PP dry-run record)
        out.append(r)
    return out


def dryrun_table(rows):
    lines = ["| arch | shape | mesh | status | compile_s | per-dev args (GiB) | coll kinds |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        mesh = f"{r.get('pods', '?')}pod"
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                         f"{r['status']}{'' if r['status'] != 'skipped' else ' (per spec)'} | - | - | - |")
            continue
        mem = r["memory"].get(
            "per_device_args_bytes",
            r["memory"].get("per_device_total", 0)) / 2**30
        kinds = ",".join(f"{k}:{int(v)}" for k, v in sorted(
            r["collectives"]["count_by_kind"].items()))
        lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                     f"{r['compile_s']} | {mem:.2f} | {kinds} |")
    return "\n".join(lines)


def roofline_table(rows):
    lines = ["| arch | shape | mesh | compute_s | memory_s | coll_s | dominant "
             "| bound_s | model/HLO FLOPs | lever |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('pods','?')}pod "
            f"| {t['compute_s']:.4g} | {t['memory_s']:.4g} "
            f"| {t['collective_s']:.4g} | **{t['dominant']}** "
            f"| {t['roofline_bound_s']:.4g} "
            f"| {r.get('useful_flops_ratio', 0.0):.3f} "
            f"| {_LEVER[t['dominant']]} |")
    return "\n".join(lines)


def fleet_table():
    base_dir = Path("artifacts/dryrun_baseline_v0")
    if not base_dir.exists():
        return "(baseline artifacts not present)"
    base = {p.stem: json.loads(p.read_text())
            for p in base_dir.glob("*__1pod.json")}
    cur = {p.stem: json.loads(p.read_text())
           for p in DRYRUN.glob("*__1pod.json")}
    lines = ["| cell | baseline bound_s | final bound_s | speedup |",
             "|---|---|---|---|"]
    t0 = t1 = 0.0
    for k in sorted(cur):
        a, b = base.get(k), cur[k]
        if not a or a["status"] != "ok" or b["status"] != "ok":
            continue
        b0 = a["roofline"]["roofline_bound_s"]
        b1 = b["roofline"]["roofline_bound_s"]
        t0 += b0
        t1 += b1
        lines.append(f"| {k.replace('__1pod','').replace('__',' × ')} "
                     f"| {b0:.3f} | {b1:.3f} | {b0 / max(b1, 1e-12):.1f}× |")
    lines.append(f"| **TOTAL** | **{t0:.1f}** | **{t1:.1f}** "
                 f"| **{t0 / max(t1, 1e-12):.1f}×** |")
    return "\n".join(lines)


def main():
    rows = recs()
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    err = [r for r in rows if r["status"] == "error"]
    print(f"{len(ok)} ok / {len(skipped)} skipped / {len(err)} error")

    text = EXP.read_text()
    dr = (f"Summary: **{len(ok)} ok, {len(skipped)} skipped (per spec), "
          f"{len(err)} errors.**\n\n" + dryrun_table(rows))
    rf = roofline_table([r for r in rows if r.get("pods") == 1])
    text = text.replace("<!-- DRYRUN_TABLE -->",
                        "<!-- DRYRUN_TABLE -->\n\n" + dr, 1)
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        "<!-- ROOFLINE_TABLE -->\n\n" + rf +
                        "\n\n(1-pod mesh per spec; 2-pod records in "
                        "artifacts/dryrun/*2pod.json.)", 1)
    text = text.replace("<!-- FLEET_TABLE -->",
                        "<!-- FLEET_TABLE -->\n\n" + fleet_table(), 1)
    EXP.write_text(text)
    print(f"{EXP.name} updated")


if __name__ == "__main__":
    main()
