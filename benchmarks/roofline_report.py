"""Roofline report: aggregates artifacts/dryrun/*.json into the §Roofline
table (per arch × shape × mesh: three terms, dominant bound, useful-FLOP
ratio, one-line lever)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit, save_json

DRYRUN = Path("artifacts/dryrun")

_LEVER = {
    "compute": "raise MXU utilization: larger per-chip tiles/microbatch, "
               "fuse small ops into the matmuls",
    "memory": "cut HBM traffic: fuse elementwise chains, avoid remat of "
              "cheap ops, bf16 activations, keep scan carries on-chip",
    "collective": "reshard: increase data-parallel fraction, overlap "
                  "collectives with compute, int8-compress cross-pod legs",
}


def load_records(pods: int | None = None) -> list[dict]:
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or "shape" not in r:
            continue   # skips non-standard modes (e.g. the PP dry-run)
        if pods is not None and r.get("pods") != pods:
            continue
        recs.append(r)
    return recs


def as_markdown(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | pods | compute_s | memory_s | collective_s | "
        "dominant | bound_s | model/HLO flops | lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['pods']} "
            f"| {t['compute_s']:.4g} | {t['memory_s']:.4g} "
            f"| {t['collective_s']:.4g} | {t['dominant']} "
            f"| {t['roofline_bound_s']:.4g} "
            f"| {r.get('useful_flops_ratio', 0.0):.3f} "
            f"| {_LEVER[t['dominant']][:40]}… |")
    return "\n".join(lines)


def run(fast: bool = False) -> dict:
    recs = load_records()
    if not recs:
        emit("roofline/report", None, "no dry-run artifacts found")
        return {}
    summary = {}
    for r in recs:
        key = f"{r['arch']}__{r['shape']}__{r['pods']}pod"
        t = r["roofline"]
        summary[key] = {
            "dominant": t["dominant"],
            "bound_s": t["roofline_bound_s"],
            "compute_fraction": t["compute_fraction"],
            "useful_flops_ratio": r.get("useful_flops_ratio", 0.0),
        }
        emit(f"roofline/{key}", t["roofline_bound_s"] * 1e6,
             f"dominant={t['dominant']};"
             f"cf={t['compute_fraction']:.3f};"
             f"useful={r.get('useful_flops_ratio', 0.0):.3f}")
    md = as_markdown(recs)
    Path("artifacts/bench").mkdir(parents=True, exist_ok=True)
    Path("artifacts/bench/roofline_table.md").write_text(md)
    save_json("roofline_summary", summary)
    return summary


if __name__ == "__main__":
    run()
