"""Online streaming-inference benchmark: readout latency (p50/p99),
events/s and streams/s of the continuous-batching serving engine
(repro.stream.engine) over the synthetic event source — plus the paced
saturation load test.

Serving-path performance does not depend on trained weights, so the
deployment is a fresh init (repro.stream.deploy.fresh_deployment) — the
benchmark isolates the engine: host binning of replay chunks, the jitted
lane-batched fold/readout steps, and slot recycling. Two lane counts per
run show the micro-batching effect (same stream work, wider jitted
batch).

The **saturation sweep** serves under ``paced=True`` with a short
T_INTG deployment and doubles the concurrent-stream count (lane
capacity, every lane kept full) until the deadline-miss rate crosses 1%
— i.e. until the p99 readout lands past its T_INTG boundary. The knee
point (max concurrent streams at <1% miss) and its events/s (total and
per device) land in ``BENCH_stream_serving.json`` so
``tools/check_bench.py`` tracks the capacity trajectory — and flags
throughput drops — across commits (docs/benchmarks.md).

When more than one device is visible (real accelerators, or CPU CI's
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), the sweep runs
a second time with the lane axis mesh-sharded (repro.stream.shard) and a
multi-worker binning pool; those entries carry a ``_d{N}`` suffix so the
single-device trajectory stays comparable commit-to-commit.

The **mixed-variant paced** entries (``paced_mixed_c{cap}``) serve a
two-entry registry (nullified + basic leak variants, streams assigned
round-robin) under the real-time clock, committing PER-ENTRY serving
rates in each entry's meta — the trajectory for the multi-variant
deployment shape.
"""
from __future__ import annotations

from dataclasses import replace

import jax

from benchmarks.common import bench_entry, bench_record, emit, save_json

from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import sources as sources_mod
from repro.stream import deploy as deploy_mod
from repro.stream.engine import StreamEngine
from repro.stream.registry import Registry
from repro.stream.shard import make_lane_executor


def _model(hw: int, n_classes: int, t_intg_ms: float) -> P2MModelConfig:
    return P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=t_intg_ms,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16),
                                  input_hw=(hw, hw), fc_hidden=64,
                                  n_classes=n_classes,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)


def _saturation_sweep(fast: bool, hw: int, devices: int = 1,
                      bin_workers: int | None = None
                      ) -> tuple[dict, list[dict]]:
    """Paced load test: sweep concurrent streams (capacity, lanes kept
    full) until >=1% of readouts miss their T_INTG deadline; report the
    knee. The per-lane host cost (event generation + binning) is a
    near-constant fraction of stream real time, so a T_INTG long enough
    to amortize the fixed fold/readout dispatch (50 ms) saturates at a
    lane count any runner can reach — small on CPU, larger where the
    host keeps more lanes real-time.

    ``devices > 1`` runs the same sweep with the lane axis mesh-sharded
    and the binning pool multi-worker; entries/artifacts gain a
    ``_d{devices}`` suffix so the unsharded trajectory keeps its names.
    """
    t_intg_ms = 50.0
    tag = f"_d{devices}" if devices > 1 else ""
    executor = make_lane_executor(devices)
    source = sources_mod.resolve_dataset("synthetic-gesture", hw=hw,
                                         duration_ms=8 * t_intg_ms)
    base = _model(hw, source.n_classes, t_intg_ms)
    model = P2MModelConfig(p2m=base.p2m, backbone=base.backbone,
                           coarse_window_ms=4 * t_intg_ms)
    dep = deploy_mod.fresh_deployment(model, seed=0)
    # same capacity ladder sharded or not (small caps pad up to the mesh
    # width), so knee{tag} entries stay comparable across device counts
    caps = (1, 2, 4) if fast else (1, 2, 4, 8, 16)
    out = {}
    entries = []
    knee = None          # (streams, artifact) of the last <1%-miss run
    saturated = False
    for cap in caps:
        engine = StreamEngine(dep, capacity=cap, executor=executor,
                              bin_workers=bin_workers)
        # unpaced warmup at the measured stream count: pay the
        # per-capacity jit compiles (fold / readout / event generation)
        # AND the mid-serve admission path (the second stream cohort)
        # before the clock is load-bearing, so misses measure
        # steady-state serving, not compilation or first-touch costs
        engine.serve(source, 2 * cap, seed=0)
        report = engine.serve(source, 2 * cap, seed=0, paced=True)
        art = report.to_artifact()
        out[f"paced_c{cap}{tag}"] = art
        ddl = art["deadlines"]
        thr = art["throughput"]
        adm = art["admission"]
        emit(f"stream/saturation/c{cap}{tag}", None,
             f"streams={cap};miss_rate={ddl['miss_rate']:.4f};"
             f"p99_margin_ms={ddl['margin_ms']['p99']:.3f};"
             f"events_per_s={thr['events_per_s']:.0f};"
             f"per_device={thr['events_per_s_per_device']:.0f}")
        entries.append(bench_entry(
            f"paced_c{cap}{tag}",
            xla_us=art["latency_ms"]["readout_p50"] * 1e3,
            meta={"concurrent_streams": cap,
                  "miss_rate": ddl["miss_rate"],
                  "p99_margin_ms": ddl["margin_ms"]["p99"],
                  "events_per_s": thr["events_per_s"],
                  "events_per_s_per_device":
                      thr["events_per_s_per_device"],
                  "devices": devices,
                  "bin_workers": art["sharding"]["bin_workers"],
                  "n_shed": adm["n_shed"],
                  "n_deferred": adm["n_deferred"]}))
        if ddl["miss_rate"] < 0.01:
            knee = (cap, art)
        else:
            saturated = True
            break
    if knee is None:
        knee_streams, knee_p99, knee_p50_us = 0, 0.0, None
        knee_events = knee_events_dev = 0.0
    else:
        knee_streams = knee[0]
        knee_events = knee[1]["throughput"]["events_per_s"]
        knee_events_dev = knee[1]["throughput"]["events_per_s_per_device"]
        knee_p99 = knee[1]["deadlines"]["margin_ms"]["p99"]
        knee_p50_us = knee[1]["latency_ms"]["readout_p50"] * 1e3
    if not saturated:
        emit(f"stream/saturation/not_saturated{tag}", None,
             f"no >=1%-miss capacity within sweep (max {caps[-1]}); knee "
             f"is a lower bound")
    emit(f"stream/saturation/knee{tag}", None,
         f"max_streams_lt1pct_miss={knee_streams};"
         f"events_per_s={knee_events:.0f};"
         f"per_device={knee_events_dev:.0f};t_intg_ms={t_intg_ms}")
    entries.append(bench_entry(
        f"saturation_knee{tag}", xla_us=knee_p50_us,
        meta={"max_streams_lt1pct_miss": knee_streams,
              "events_per_s": knee_events,
              "events_per_s_per_device": knee_events_dev,
              "devices": devices,
              "p99_margin_ms": knee_p99,
              "t_intg_ms": t_intg_ms,
              "saturated": saturated}))
    return out, entries


def _mixed_paced(fast: bool, hw: int) -> tuple[dict, list[dict]]:
    """Paced serving over a MIXED-variant registry: two compat-equal
    leak variants (nullified + basic) co-resident on the lanes, streams
    assigned round-robin — the multi-variant deployment shape under the
    real-time clock. Each ``paced_mixed_c{cap}`` entry carries the
    PER-ENTRY serving rates in its meta (events/s, admitted/finished,
    misses per registry entry), so the trajectory records whether one
    variant starves the other as capacity grows."""
    t_intg_ms = 50.0
    source = sources_mod.resolve_dataset("synthetic-gesture", hw=hw,
                                         duration_ms=8 * t_intg_ms)
    base = _model(hw, source.n_classes, t_intg_ms)
    model = P2MModelConfig(p2m=base.p2m, backbone=base.backbone,
                           coarse_window_ms=4 * t_intg_ms)
    model_b = P2MModelConfig(
        p2m=replace(model.p2m,
                    leak=LeakageConfig(circuit=CircuitConfig.BASIC)),
        backbone=model.backbone, coarse_window_ms=model.coarse_window_ms)
    reg = Registry()
    reg.register("nullified", deploy_mod.fresh_deployment(model, seed=0))
    reg.register("basic", deploy_mod.fresh_deployment(model_b, seed=0))
    names = reg.names()
    variants = lambda sid: names[sid % len(names)]  # noqa: E731
    caps = (2,) if fast else (2, 4)
    out = {}
    entries = []
    for cap in caps:
        engine = StreamEngine(reg, capacity=cap, default_entry=names[0])
        # warmup: pay the jit compiles + admission path off the clock
        engine.serve(source, 2 * cap, seed=0, variants=variants)
        report = engine.serve(source, 2 * cap, seed=0, paced=True,
                              variants=variants)
        art = report.to_artifact()
        out[f"paced_mixed_c{cap}"] = art
        ddl, thr = art["deadlines"], art["throughput"]
        per_entry = {
            row["name"]: {"events_per_s": row["events_per_s"],
                          "n_admitted": row["n_admitted"],
                          "n_finished": row["n_finished"],
                          "n_misses": row["n_misses"]}
            for row in art["registry"]["entries"]}
        rates = ";".join(f"{n}={v['events_per_s']:.0f}ev/s"
                         for n, v in per_entry.items())
        emit(f"stream/paced_mixed/c{cap}", None,
             f"streams={cap};miss_rate={ddl['miss_rate']:.4f};{rates}")
        entries.append(bench_entry(
            f"paced_mixed_c{cap}",
            xla_us=art["latency_ms"]["readout_p50"] * 1e3,
            meta={"concurrent_streams": cap,
                  "miss_rate": ddl["miss_rate"],
                  "events_per_s": thr["events_per_s"],
                  "events_per_s_per_device":
                      thr["events_per_s_per_device"],
                  "entries": per_entry}))
    return out, entries


def run(fast: bool = False, hw: int = 16,
        t_intg_ms: float = 100.0) -> dict:
    source = sources_mod.resolve_dataset("synthetic-gesture", hw=hw)
    dep = deploy_mod.fresh_deployment(
        _model(hw, source.n_classes, t_intg_ms), seed=0)
    n_streams = 8 if fast else 32
    capacities = (2, 4) if fast else (4, 16)
    out = {}
    entries = []
    for capacity in capacities:
        engine = StreamEngine(dep, capacity=capacity)
        report = engine.serve(source, n_streams, seed=0)
        art = report.to_artifact()
        out[f"capacity{capacity}"] = art
        lat, thr = art["latency_ms"], art["throughput"]
        emit(f"stream/readout/c{capacity}", lat["readout_p50"] * 1e3,
             f"p50={lat['readout_p50']:.3f}ms;p99={lat['readout_p99']:.3f}ms;"
             f"mean={lat['readout_mean']:.3f}ms")
        emit(f"stream/fold/c{capacity}", lat["fold_p50"] * 1e3,
             f"p50={lat['fold_p50']:.3f}ms;p99={lat['fold_p99']:.3f}ms")
        emit(f"stream/throughput/c{capacity}", None,
             f"events_per_s={thr['events_per_s']:.0f};"
             f"streams_per_s={thr['streams_per_s']:.2f};"
             f"readouts_per_s={thr['readouts_per_s']:.1f}")
        entries.append(bench_entry(
            f"readout_c{capacity}", xla_us=lat["readout_p50"] * 1e3,
            meta={"p99_us": lat["readout_p99"] * 1e3}))
        entries.append(bench_entry(
            f"fold_c{capacity}", xla_us=lat["fold_p50"] * 1e3,
            meta={"p99_us": lat["fold_p99"] * 1e3,
                  "events_per_s": thr["events_per_s"]}))

    # same serve through the fused stream_fold kernel — the use_kernel
    # switch must not change a single prediction (oracle check), and its
    # fold latency lands next to the scan path's in the trajectory record
    cap = capacities[0]
    engine_k = StreamEngine(dep, capacity=cap, use_kernel=True)
    report_k = engine_k.serve(source, n_streams, seed=0)
    art_k = report_k.to_artifact()
    out[f"capacity{cap}_kernel"] = art_k
    lat_k = art_k["latency_ms"]
    base = out[f"capacity{cap}"]
    by_id = lambda art: {s["stream_id"]: s["prediction"]  # noqa: E731
                         for s in art["streams"]}
    p0, pk = by_id(base), by_id(art_k)
    mismatch = sum(1 for sid in p0 if p0[sid] != pk.get(sid))
    emit(f"stream/fold_kernel/c{cap}", lat_k["fold_p50"] * 1e3,
         f"p50={lat_k['fold_p50']:.3f}ms;pred_mismatch={mismatch}")
    entries.append(bench_entry(
        f"fold_kernel_c{cap}", xla_us=base["latency_ms"]["fold_p50"] * 1e3,
        kernel_us=lat_k["fold_p50"] * 1e3, max_err=float(mismatch),
        meta={"p99_us": lat_k["fold_p99"] * 1e3}))
    assert mismatch == 0, f"use_kernel changed {mismatch} predictions"

    # paced saturation load test → knee point (capacity trajectory)
    sat_out, sat_entries = _saturation_sweep(fast, hw)
    out.update(sat_out)
    entries.extend(sat_entries)

    # mixed-variant registry under the paced clock (per-entry rates)
    mixed_out, mixed_entries = _mixed_paced(fast, hw)
    out.update(mixed_out)
    entries.extend(mixed_entries)

    # mesh-sharded variant of the same sweep, when a mesh is available
    # (accelerators, or forced host devices on CPU CI) — per-device knee
    # next to the single-device one
    n_dev = min(8, jax.device_count())
    if n_dev > 1:
        sat_out_d, sat_entries_d = _saturation_sweep(
            fast, hw, devices=n_dev, bin_workers=max(2, n_dev))
        out.update(sat_out_d)
        entries.extend(sat_entries_d)

    save_json("stream_serving", out)
    bench_record("stream_serving", entries,
                 extra={"fast": fast, "n_streams": n_streams, "hw": hw,
                        "t_intg_ms": t_intg_ms})
    return out


if __name__ == "__main__":
    run()
