"""Kernel µbenchmarks: Pallas (interpret) vs the pure-XLA paths.

On this CPU container interpret-mode timings measure Python emulation, NOT
TPU performance — the meaningful outputs are (i) allclose vs oracle at
benchmark scale and (ii) the XLA-path timing (the production fallback).
How to read the numbers, the BENCH_kernels.json trajectory record this
module emits, and the regression gate are documented in docs/benchmarks.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_entry, bench_record, emit, save_json, timed


def bench_p2m(fast: bool = False) -> dict:
    from repro.core.p2m_layer import P2MConfig, p2m_forward_scan, p2m_init
    from repro.kernels.p2m_conv import ops

    hw = 24 if fast else 32
    cfg = P2MConfig(out_channels=8, n_sub=4)
    params = p2m_init(jax.random.PRNGKey(0), cfg)
    ev = jax.random.poisson(jax.random.PRNGKey(1), 0.3,
                            (2, 4, 4, hw, hw, 2)).astype(jnp.float32)
    t_xla, (s_ref, v_ref) = timed(
        jax.jit(lambda p, e: p2m_forward_scan(p, e, cfg)), params, ev)
    t_pal, (s_k, v_k) = timed(
        lambda p, e: ops.p2m_conv(p, e, cfg), params, ev)
    err = float(jnp.max(jnp.abs(v_k - v_ref)))
    emit("kernel/p2m_conv/xla_scan", t_xla * 1e6, f"hw={hw}")
    emit("kernel/p2m_conv/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err < 1e-4
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def bench_p2m_multi(fast: bool = False) -> dict:
    """Fused multi-config launch vs n_cfg separate single-config launches.

    The fusion claim of the circuit-grid axis (p2m_conv.py): all configs
    revisit the same patch tiles in ONE pallas_call, so the fused path
    should not cost n_cfg× the single-config path.
    """
    import dataclasses

    from repro.core.leakage import CircuitConfig, LeakageConfig
    from repro.core.p2m_layer import P2MConfig, p2m_init
    from repro.kernels.p2m_conv import ops

    hw = 16 if fast else 24
    circuits = (CircuitConfig.BASIC, CircuitConfig.SWITCH,
                CircuitConfig.NULLIFIED)
    leak_cfgs = tuple(LeakageConfig(circuit=c) for c in circuits)
    cfg = P2MConfig(out_channels=8, n_sub=4)
    params = p2m_init(jax.random.PRNGKey(0), cfg)
    ev = jax.random.poisson(jax.random.PRNGKey(1), 0.3,
                            (2, 4, 4, hw, hw, 2)).astype(jnp.float32)

    t_multi, (s_multi, v_multi) = timed(
        lambda p, e: ops.p2m_conv_multi(p, e, cfg, leak_cfgs), params, ev)

    def separate(p, e):
        outs = [ops.p2m_conv(p, e, dataclasses.replace(cfg, leak=lc))
                for lc in leak_cfgs]
        return (jnp.stack([o[0] for o in outs]),
                jnp.stack([o[1] for o in outs]))

    t_sep, (s_sep, v_sep) = timed(separate, params, ev)
    err = float(jnp.max(jnp.abs(v_multi - v_sep)))
    emit("kernel/p2m_conv_multi/fused", t_multi * 1e6,
         f"n_cfg={len(leak_cfgs)},hw={hw}")
    emit("kernel/p2m_conv_multi/separate_launches", t_sep * 1e6,
         f"max_err_vs_fused={err:.2e}")
    assert err < 1e-5
    assert bool(jnp.all(s_multi == s_sep))
    return {"fused_s": t_multi, "separate_s": t_sep, "max_err": err,
            "n_cfg": len(leak_cfgs)}


def bench_stream_fold(fast: bool = False) -> dict:
    """Serving fold: XLA scan (oracle) vs the fused stream_fold kernel.

    ``deposit`` mode must be bit-exact with the scan — that is the
    contract the streaming engine's ``use_kernel`` switch relies on
    (tests/test_stream_fold.py). ``mac`` mode is the fully-fused variant,
    parity-checked with tolerance.
    """
    from jax import lax

    from repro.core.p2m_layer import _conv
    from repro.kernels.stream_fold import ops as sf_ops

    hw = 16 if fast else 24
    B, S, F, k = (4, 4, 8, 3) if fast else (8, 8, 8, 3)
    key = jax.random.PRNGKey(0)
    frames = jax.random.poisson(key, 0.3, (B, S, hw, hw, 2)
                                ).astype(jnp.float32)
    w_q = jax.random.normal(jax.random.fold_in(key, 1), (k, k, 2, F)) * 0.1
    a = jnp.exp(-jax.random.uniform(jax.random.fold_in(key, 2), (F,)))
    x0 = jax.random.normal(jax.random.fold_in(key, 3), (B, hw, hw, F)) * 0.01
    dv_unit = 0.01

    def scan_fold(x, fr):
        def sub(x, ev):
            return x * a + _conv(ev, w_q, 1) * dv_unit, None
        x, _ = lax.scan(sub, x, jnp.moveaxis(fr, 1, 0))
        return x

    t_xla, ref = timed(jax.jit(scan_fold), x0, frames)
    t_dep, out_dep = timed(
        jax.jit(lambda x, fr: sf_ops.fold_chunk(
            x, fr, w_q, a, stride=1, dv_unit=dv_unit)), x0, frames)
    t_mac, out_mac = timed(
        jax.jit(lambda x, fr: sf_ops.fold_chunk(
            x, fr, w_q, a, stride=1, dv_unit=dv_unit, mode="mac")),
        x0, frames)
    err = float(jnp.max(jnp.abs(out_dep - ref)))
    mac_err = float(jnp.max(jnp.abs(out_mac - ref)))
    emit("kernel/stream_fold/xla_scan", t_xla * 1e6, f"B={B},S={S},hw={hw}")
    emit("kernel/stream_fold/pallas_deposit", t_dep * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    emit("kernel/stream_fold/pallas_mac", t_mac * 1e6,
         f"max_err_vs_oracle={mac_err:.2e}")
    assert err == 0.0, f"deposit fold must be bit-exact, got {err}"
    assert mac_err < 1e-4
    return {"xla_s": t_xla, "pallas_interpret_s": t_dep, "mac_s": t_mac,
            "max_err": err, "mac_err": mac_err}


def bench_lif(fast: bool = False) -> dict:
    from repro.kernels.lif.lif import lif_pallas
    from repro.kernels.lif.ref import lif_ref

    T, N = (32, 4096) if fast else (64, 16384)
    x = jax.random.normal(jax.random.PRNGKey(0), (T, N))
    t_xla, ref = timed(jax.jit(lif_ref), x)
    t_pal, out = timed(lambda x: lif_pallas(x), x)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel/lif/xla_scan", t_xla * 1e6, f"T={T},N={N}")
    emit("kernel/lif/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err == 0.0
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def bench_ssd(fast: bool = False) -> dict:
    from repro.kernels.ssd.ref import ssd_ref
    from repro.kernels.ssd.ssd import ssd_pallas
    from repro.nn.ssm import ssd_chunked

    b, s, h, p, g, n = (1, 256, 4, 32, 1, 16) if fast else (2, 512, 8, 64, 1, 32)
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))

    t_chunk, (y_c, _) = timed(
        jax.jit(lambda *a: ssd_chunked(*a, chunk=128)), x, dt, A, B, C)
    t_pal, (y_k, _) = timed(
        lambda *a: ssd_pallas(*a, chunk=128), x, dt, A, B, C)
    y_r, _ = ssd_ref(x, dt, A, B, C)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    rel = err / float(jnp.max(jnp.abs(y_r)))
    emit("kernel/ssd/xla_chunked", t_chunk * 1e6, f"s={s},h={h},p={p}")
    emit("kernel/ssd/pallas_interpret", t_pal * 1e6,
         f"rel_err_vs_oracle={rel:.2e}")
    assert rel < 1e-3
    return {"xla_s": t_chunk, "pallas_interpret_s": t_pal, "rel_err": rel}


def bench_flash(fast: bool = False) -> dict:
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_pallas)
    from repro.kernels.flash_attention.ref import attention_ref

    BH, S, d = (4, 256, 64) if fast else (8, 512, 64)
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (BH, S, d))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, d))
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, d))
    t_xla, ref = timed(jax.jit(lambda *a: attention_ref(*a, causal=True)),
                       q, kk, v)
    t_pal, out = timed(
        lambda *a: flash_attention_pallas(*a, causal=True), q, kk, v)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel/flash/xla_full", t_xla * 1e6, f"S={S},d={d}")
    emit("kernel/flash/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err < 5e-3
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def run(fast: bool = False) -> dict:
    out = {"p2m": bench_p2m(fast), "p2m_multi": bench_p2m_multi(fast),
           "lif": bench_lif(fast), "stream_fold": bench_stream_fold(fast),
           "ssd": bench_ssd(fast), "flash": bench_flash(fast)}
    save_json("kernels", out)

    def us(s):
        return None if s is None else s * 1e6

    bench_record("kernels", [
        bench_entry("p2m_conv", xla_us=us(out["p2m"]["xla_s"]),
                    kernel_us=us(out["p2m"]["pallas_interpret_s"]),
                    max_err=out["p2m"]["max_err"]),
        bench_entry("p2m_conv_multi", xla_us=us(out["p2m_multi"]["separate_s"]),
                    kernel_us=us(out["p2m_multi"]["fused_s"]),
                    max_err=out["p2m_multi"]["max_err"],
                    meta={"n_cfg": out["p2m_multi"]["n_cfg"]}),
        bench_entry("lif", xla_us=us(out["lif"]["xla_s"]),
                    kernel_us=us(out["lif"]["pallas_interpret_s"]),
                    max_err=out["lif"]["max_err"]),
        bench_entry("stream_fold", xla_us=us(out["stream_fold"]["xla_s"]),
                    kernel_us=us(out["stream_fold"]["pallas_interpret_s"]),
                    max_err=out["stream_fold"]["max_err"],
                    meta={"mac_us": us(out["stream_fold"]["mac_s"]),
                          "mac_err": out["stream_fold"]["mac_err"]}),
        bench_entry("ssd", xla_us=us(out["ssd"]["xla_s"]),
                    kernel_us=us(out["ssd"]["pallas_interpret_s"]),
                    max_err=out["ssd"]["rel_err"],
                    meta={"err_kind": "rel"}),
        bench_entry("flash_attention", xla_us=us(out["flash"]["xla_s"]),
                    kernel_us=us(out["flash"]["pallas_interpret_s"]),
                    max_err=out["flash"]["max_err"]),
    ], extra={"fast": fast})
    return out


if __name__ == "__main__":
    run()
