"""Kernel µbenchmarks: Pallas (interpret) vs the pure-XLA paths.

On this CPU container interpret-mode timings measure Python emulation, NOT
TPU performance — the meaningful outputs are (i) allclose vs oracle at
benchmark scale and (ii) the XLA-path timing (the production fallback).
TPU performance claims live in EXPERIMENTS.md §Roofline from the compiled
dry-run instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timed


def bench_p2m(fast: bool = False) -> dict:
    from repro.core.p2m_layer import P2MConfig, p2m_forward_scan, p2m_init
    from repro.kernels.p2m_conv import ops

    hw = 24 if fast else 32
    cfg = P2MConfig(out_channels=8, n_sub=4)
    params = p2m_init(jax.random.PRNGKey(0), cfg)
    ev = jax.random.poisson(jax.random.PRNGKey(1), 0.3,
                            (2, 4, 4, hw, hw, 2)).astype(jnp.float32)
    t_xla, (s_ref, v_ref) = timed(
        jax.jit(lambda p, e: p2m_forward_scan(p, e, cfg)), params, ev)
    t_pal, (s_k, v_k) = timed(
        lambda p, e: ops.p2m_conv(p, e, cfg), params, ev)
    err = float(jnp.max(jnp.abs(v_k - v_ref)))
    emit("kernel/p2m_conv/xla_scan", t_xla * 1e6, f"hw={hw}")
    emit("kernel/p2m_conv/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err < 1e-4
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def bench_lif(fast: bool = False) -> dict:
    from repro.kernels.lif.lif import lif_pallas
    from repro.kernels.lif.ref import lif_ref

    T, N = (32, 4096) if fast else (64, 16384)
    x = jax.random.normal(jax.random.PRNGKey(0), (T, N))
    t_xla, ref = timed(jax.jit(lif_ref), x)
    t_pal, out = timed(lambda x: lif_pallas(x), x)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel/lif/xla_scan", t_xla * 1e6, f"T={T},N={N}")
    emit("kernel/lif/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err == 0.0
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def bench_ssd(fast: bool = False) -> dict:
    from repro.kernels.ssd.ref import ssd_ref
    from repro.kernels.ssd.ssd import ssd_pallas
    from repro.nn.ssm import ssd_chunked

    b, s, h, p, g, n = (1, 256, 4, 32, 1, 16) if fast else (2, 512, 8, 64, 1, 32)
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))

    t_chunk, (y_c, _) = timed(
        jax.jit(lambda *a: ssd_chunked(*a, chunk=128)), x, dt, A, B, C)
    t_pal, (y_k, _) = timed(
        lambda *a: ssd_pallas(*a, chunk=128), x, dt, A, B, C)
    y_r, _ = ssd_ref(x, dt, A, B, C)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    rel = err / float(jnp.max(jnp.abs(y_r)))
    emit("kernel/ssd/xla_chunked", t_chunk * 1e6, f"s={s},h={h},p={p}")
    emit("kernel/ssd/pallas_interpret", t_pal * 1e6,
         f"rel_err_vs_oracle={rel:.2e}")
    assert rel < 1e-3
    return {"xla_s": t_chunk, "pallas_interpret_s": t_pal, "rel_err": rel}


def bench_flash(fast: bool = False) -> dict:
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_pallas)
    from repro.kernels.flash_attention.ref import attention_ref

    BH, S, d = (4, 256, 64) if fast else (8, 512, 64)
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (BH, S, d))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, d))
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, d))
    t_xla, ref = timed(jax.jit(lambda *a: attention_ref(*a, causal=True)),
                       q, kk, v)
    t_pal, out = timed(
        lambda *a: flash_attention_pallas(*a, causal=True), q, kk, v)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel/flash/xla_full", t_xla * 1e6, f"S={S},d={d}")
    emit("kernel/flash/pallas_interpret", t_pal * 1e6,
         f"max_err_vs_oracle={err:.2e}")
    assert err < 5e-3
    return {"xla_s": t_xla, "pallas_interpret_s": t_pal, "max_err": err}


def run(fast: bool = False) -> dict:
    out = {"p2m": bench_p2m(fast), "lif": bench_lif(fast),
           "ssd": bench_ssd(fast), "flash": bench_flash(fast)}
    save_json("kernels", out)
    return out


if __name__ == "__main__":
    run()
