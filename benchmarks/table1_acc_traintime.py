"""Paper Table 1: accuracy + normalized training time vs T_INTG, for the
DVS128-Gesture-like and NMNIST-like synthetic streams.

Reduced scale (CPU, synthetic data): the deliverable is the TREND —
accuracy non-decreasing and training time per step decreasing as T_INTG
grows — not the paper's absolute percentages (DESIGN.md §1).
"""
from __future__ import annotations

from dataclasses import replace

from repro.core import sweep as engine
from repro.core.codesign import P2MModelConfig, SweepConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod

from benchmarks.common import emit, save_json

GRID = (1.0, 10.0, 100.0, 1000.0)     # the paper's exact grid


def _model(hw: int, n_classes: int) -> P2MModelConfig:
    return P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=10.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16), input_hw=(hw, hw),
                                  fc_hidden=64, n_classes=n_classes,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)


def _data(kind: str, hw: int):
    if kind == "gesture":
        return replace(ev_mod.dvs_gesture_like(hw), duration_ms=2000.0)
    return replace(ev_mod.nmnist_like(hw), duration_ms=2000.0)


def run(fast: bool = False,
        protocols: tuple[str, ...] = ("frozen",),
        devices: int | None = None) -> dict:
    """``protocols`` extends the table across phase-2 protocols (shared
    pretrain per dataset). The default stays the paper's frozen protocol
    so the benchmark series remains comparable; pass
    ``("frozen", "unfrozen")`` to add the joint layer-1+backbone rows.
    ``devices`` shards the stacked variant axis over a cfg mesh
    (core/sweep_exec.py) — records are identical, only the wall-clock
    `table1/*` timing series moves, which is exactly what a mesh-scaling
    bench wants to read."""
    from repro.core.sweep_exec import make_executor

    sweep = SweepConfig(
        batch_size=4,
        pretrain_steps=30 if not fast else 4,
        finetune_steps=8 if not fast else 2,
        eval_batches=6 if not fast else 2,
        lr=2e-3)
    grid = engine.SweepGrid(circuits=(CircuitConfig.NULLIFIED,),
                            t_intg_grid_ms=GRID if not fast
                            else (10.0, 1000.0))
    executor = make_executor(devices)
    out = {}
    for kind in ("gesture", "nmnist"):
        hw = 24 if kind == "gesture" else 20
        results = engine.run_protocols(
            _data(kind, hw), _model(hw, 11 if kind == "gesture" else 10),
            sweep, grid, protocols=protocols, log=lambda *_: None,
            executor=executor)
        out[kind] = engine.protocols_artifact(results)
        for proto, result in results.items():
            # frozen keys stay protocol-less so the metric series is
            # continuous with pre-protocol runs
            tag = "" if proto == "frozen" else f"{proto}/"
            for r in result.records:
                emit(f"table1/{kind}/{tag}t{int(r['t_intg_ms'])}ms",
                     r["train_time_per_step_s"] * 1e6,
                     f"acc={r['accuracy']:.3f};"
                     f"train_norm={r['train_time_norm']:.2f}")
    save_json("table1", out)
    return out


if __name__ == "__main__":
    run()
