"""Paper Table 1: accuracy + normalized training time vs T_INTG, for the
DVS128-Gesture-like and NMNIST-like synthetic streams.

Reduced scale (CPU, synthetic data): the deliverable is the TREND —
accuracy non-decreasing and training time per step decreasing as T_INTG
grows — not the paper's absolute percentages (docs/architecture.md).
"""
from __future__ import annotations

from dataclasses import replace
from pathlib import Path

from repro.core import sweep as engine
from repro.core.codesign import P2MModelConfig, SweepConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod
from repro.data import sources as sources_mod

from benchmarks.common import emit, save_json

GRID = (1.0, 10.0, 100.0, 1000.0)     # the paper's exact grid


def _model(hw: int, n_classes: int,
           coarse_window_ms: float = 1000.0) -> P2MModelConfig:
    return P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=10.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16), input_hw=(hw, hw),
                                  fc_hidden=64, n_classes=n_classes,
                                  first_layer_external=True),
        coarse_window_ms=coarse_window_ms)


def _data(kind: str, hw: int, data_root: str | None = None):
    """(train source, eval source | None) per table column: the synthetic
    analytic streams by default; with ``data_root`` set, the file-backed
    DVS128-Gesture / N-MNIST loaders (repro.data.sources) on the paper's
    real recordings, evaluating on the held-out split when it exists."""
    if data_root is not None:
        sub = Path(data_root) / ("DvsGesture" if kind == "gesture"
                                 else "N-MNIST")
        name = "dvs128" if kind == "gesture" else "nmnist"
        root = str(sub if sub.is_dir() else data_root)
        train = sources_mod.resolve_dataset(name, hw=hw, data_root=root)
        ev_src, _ = sources_mod.resolve_eval_dataset(name, hw=hw,
                                                     data_root=root)
        return train, ev_src
    if kind == "gesture":
        return replace(ev_mod.dvs_gesture_like(hw), duration_ms=2000.0), None
    return replace(ev_mod.nmnist_like(hw), duration_ms=2000.0), None


def run(fast: bool = False,
        protocols: tuple[str, ...] = ("frozen",),
        devices: int | None = None,
        data_root: str | None = None) -> dict:
    """``protocols`` extends the table across phase-2 protocols (shared
    pretrain per dataset). The default stays the paper's frozen protocol
    so the benchmark series remains comparable; pass
    ``("frozen", "unfrozen")`` to add the joint layer-1+backbone rows.
    ``devices`` shards the stacked variant axis over a cfg mesh
    (core/sweep_exec.py) — records are identical, only the wall-clock
    `table1/*` timing series moves, which is exactly what a mesh-scaling
    bench wants to read. ``data_root`` swaps both columns onto the
    file-backed datasets (a directory holding ``DvsGesture`` AEDAT files
    for the gesture column and an N-MNIST tree for the nmnist column —
    metric keys gain a ``file/`` prefix so the synthetic series stays
    continuous)."""
    from repro.core.sweep_exec import make_executor

    sweep = SweepConfig(
        batch_size=4,
        pretrain_steps=30 if not fast else 4,
        finetune_steps=8 if not fast else 2,
        eval_batches=6 if not fast else 2,
        lr=2e-3)
    t_grid = GRID if not fast else (10.0, 1000.0)
    executor = make_executor(devices)
    out = {}
    src_tag = "" if data_root is None else "file/"
    for kind in ("gesture", "nmnist"):
        hw = 24 if kind == "gesture" else 20
        data, eval_data = _data(kind, hw, data_root)
        # short recordings (real N-MNIST ≈ 300 ms) shrink the coarse
        # window and drop T points that no longer fit the stream
        coarse = min(1000.0, data.duration_ms)
        t_ok = engine.fit_t_grid(t_grid, data.duration_ms, coarse)
        grid = engine.SweepGrid(circuits=(CircuitConfig.NULLIFIED,),
                                t_intg_grid_ms=t_ok)
        results = engine.run_protocols(
            data, _model(hw, 11 if kind == "gesture" else 10, coarse),
            sweep, grid, protocols=protocols, log=lambda *_: None,
            executor=executor, eval_data=eval_data)
        out[kind] = engine.protocols_artifact(results)
        for proto, result in results.items():
            # frozen keys stay protocol-less so the metric series is
            # continuous with pre-protocol runs
            tag = "" if proto == "frozen" else f"{proto}/"
            for r in result.records:
                emit(f"table1/{src_tag}{kind}/{tag}t{int(r['t_intg_ms'])}ms",
                     r["train_time_per_step_s"] * 1e6,
                     f"acc={r['accuracy']:.3f};"
                     f"train_norm={r['train_time_norm']:.2f}")
    save_json("table1", out)
    return out


if __name__ == "__main__":
    run()
