"""Online-adaptation benchmark: accuracy a frozen deployment LOSES under
injected leak drift, and how much of it per-lane adaptation
(repro.stream.adapt) wins back — plus the closed deployment loop
(harvest → delta checkpoint → re-register → re-serve).

The scenario is the paper's retention problem happening *after*
deployment: a model is trained and deployed against one leak
linearization, then the physical circuit drifts away from it
(``null_mismatch``/``sigma`` — nullifier residual grows, per-filter
process spread appears). Four serves tell the story:

  * ``clean_frozen``   — the deployed model on the leak it trained for;
  * ``drift_frozen``   — same weights, drifted leak: the accuracy floor;
  * ``drift_adapt``    — drifted leak with per-lane surrogate adaptation
    learning weight deltas from stream labels during serving; the
    committed ``meta.gap`` (adapted second-half accuracy minus frozen
    second-half accuracy on the SAME streams) is the recovery claim;
  * ``drift_readapted`` — the best adapted lane harvested into a delta
    checkpoint, validated, folded into a new deployment, registered
    beside its base, and re-served FROZEN — the adaptation loop closed
    through the registry.

A small 3-class synthetic task is trained in-process (NULLIFIED circuit,
T_INTG = coarse window = 200 ms so every window readout is an update
boundary); accuracies land in ``BENCH_stream_adapt.json`` meta so the
trajectory records the recovery gap commit-to-commit.
"""
from __future__ import annotations

from dataclasses import replace

import jax

from benchmarks.common import bench_entry, bench_record, emit, save_json

from repro.core import sweep as sweep_mod
from repro.core.leakage import CircuitConfig
from repro.data import events as events_mod
from repro.data.sources import SyntheticSource
from repro.stream import deploy as deploy_mod
from repro.stream.adapt import AdaptConfig
from repro.stream.engine import StreamEngine
from repro.stream.registry import Registry

# injected drift: the nullifier's residual-current mismatch grows ~6x
# past its design point and per-filter process spread appears — strong
# enough to cost the frozen deployment a large accuracy slice, mild
# enough that layer-1 weight deltas can compensate.
DRIFT = {"null_mismatch": 0.35, "sigma": 0.3}
LR_W = 1.0
N_CLASSES = 3
T_INTG_MS = 200.0
DURATION_MS = 2000.0


def _train_deployment(fast: bool, hw: int) -> deploy_mod.Deployment:
    """Train the benchmark deployment in-process: 3-class synthetic
    gesture, NULLIFIED circuit, coarse window == T_INTG (every readout
    is a backbone step, so adaptation updates at every window)."""
    data = SyntheticSource(replace(events_mod.dvs_gesture_like(hw),
                                   n_classes=N_CLASSES,
                                   duration_ms=DURATION_MS))
    _, model, sweep_cfg, grid = sweep_mod.paper_setup(fast=True, hw=hw)
    model = replace(model,
                    backbone=replace(model.backbone, n_classes=N_CLASSES),
                    coarse_window_ms=T_INTG_MS)
    sweep_cfg = replace(sweep_cfg, batch_size=8,
                        pretrain_steps=200 if fast else 300,
                        finetune_steps=10, eval_batches=6)
    grid = replace(grid, t_intg_grid_ms=(T_INTG_MS,),
                   circuits=(CircuitConfig.NULLIFIED,))
    res = sweep_mod.run_protocols(data, model, sweep_cfg, grid,
                                  protocols=("unfrozen",),
                                  log=lambda *_: None, eval_data=data,
                                  keep_params=True)["unfrozen"]
    rec = res.records[0]
    cell = (rec["t_intg_ms"], rec["n_sub"])
    g = list(res.labels).index(rec["label"])
    take = lambda tree: jax.tree.map(lambda v: v[g], tree)  # noqa: E731
    fp = res.final_params[cell]
    leak = deploy_mod.leak_config_from_variant(rec["variant"],
                                               model.p2m.leak)
    cfg = replace(model, p2m=replace(model.p2m, t_intg_ms=rec["t_intg_ms"],
                                     n_sub=rec["n_sub"], mode="curvefit",
                                     leak=leak))
    return deploy_mod.Deployment(
        model_cfg=cfg,
        params={"p2m": take(fp["p2m"]), "backbone": take(fp["backbone"])},
        bn_state=take(fp["state"]), record=rec, protocol="unfrozen")


def _drifted(dep: deploy_mod.Deployment) -> deploy_mod.Deployment:
    leak = replace(dep.model_cfg.p2m.leak, **DRIFT)
    return replace(dep, model_cfg=replace(
        dep.model_cfg, p2m=replace(dep.model_cfg.p2m, leak=leak)))


def _acc(results, half: str | None = None) -> float:
    rs = list(results)
    if half == "second":
        rs = rs[len(rs) // 2:]
    ok = [r for r in rs if r.label is not None and r.label >= 0]
    return (sum(r.prediction == r.label for r in ok) / len(ok)
            if ok else 0.0)


def run(fast: bool = False, hw: int = 16) -> dict:
    source = SyntheticSource(replace(events_mod.dvs_gesture_like(hw),
                                     n_classes=N_CLASSES,
                                     duration_ms=DURATION_MS))
    n_streams = 32 if fast else 64
    capacity = 4
    dep = _train_deployment(fast, hw)
    drifted = _drifted(dep)
    out: dict = {"drift": dict(DRIFT),
                 "trained_accuracy": dep.record.get("accuracy")}
    entries = []

    # 1) deployed model on the leak it trained for (the ceiling)
    rep = StreamEngine(dep, capacity=capacity).serve(source, n_streams,
                                                     seed=0)
    acc_clean = _acc(rep.results)
    p50_clean = rep.to_artifact()["latency_ms"]["readout_p50"]
    out["clean_frozen"] = rep.to_artifact()
    emit("stream_adapt/clean_frozen", p50_clean * 1e3,
         f"accuracy={acc_clean:.3f}")
    entries.append(bench_entry("clean_frozen", xla_us=p50_clean * 1e3,
                               meta={"accuracy": acc_clean}))

    # 2) same weights, drifted leak — the frozen floor
    repf = StreamEngine(drifted, capacity=capacity).serve(source, n_streams,
                                                          seed=0)
    acc_frozen = _acc(repf.results)
    acc_frozen_2nd = _acc(repf.results, "second")
    out["drift_frozen"] = repf.to_artifact()
    emit("stream_adapt/drift_frozen", None,
         f"accuracy={acc_frozen:.3f};second_half={acc_frozen_2nd:.3f}")
    entries.append(bench_entry(
        "drift_frozen", xla_us=None,
        meta={"accuracy": acc_frozen, "accuracy_2nd_half": acc_frozen_2nd,
              **{f"drift_{k}": v for k, v in DRIFT.items()}}))

    # 3) drifted leak + per-lane adaptation on the SAME streams
    eng = StreamEngine(drifted, capacity=capacity,
                       adapt=AdaptConfig(rule="surrogate", lr_w=LR_W))
    repa = eng.serve(source, n_streams, seed=0)
    arta = repa.to_artifact()
    ad = arta["adaptation"]
    gap = ad["accuracy_post"] - acc_frozen_2nd
    out["drift_adapt"] = arta
    emit("stream_adapt/drift_adapt", arta["latency_ms"]["readout_p50"] * 1e3,
         f"pre={ad['accuracy_pre']:.3f};post={ad['accuracy_post']:.3f};"
         f"gap={gap:+.3f};n_updates={ad['n_updates']}")
    entries.append(bench_entry(
        "drift_adapt", xla_us=arta["latency_ms"]["readout_p50"] * 1e3,
        meta={"rule": ad["rule"], "lr_w": ad["lr_w"],
              "n_updates": ad["n_updates"],
              "accuracy_pre": ad["accuracy_pre"],
              "accuracy_post": ad["accuracy_post"],
              "frozen_2nd_half": acc_frozen_2nd, "gap": gap}))
    assert gap > 0, (
        f"adaptation did not beat the frozen drifted serve "
        f"(post={ad['accuracy_post']:.3f} vs frozen "
        f"2nd-half={acc_frozen_2nd:.3f})")

    # 4) close the loop: harvest the busiest lane → validated delta
    # checkpoint → new deployment → registry entry → frozen re-serve
    best = max(ad["lanes"], key=lambda r: r["n_updates"])["lane"]
    h = eng.harvest(best)
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        deploy_mod.save_adapt_delta(tmp, h["base"], dw=h["dw"],
                                    dtheta=h["dtheta"],
                                    base_name=h["base_name"],
                                    base_uid=h["base_uid"], lane=h["lane"],
                                    n_updates=h["n_updates"],
                                    rule="surrogate")
        delta = deploy_mod.load_adapt_delta(tmp, h["base"])
    adapted = deploy_mod.apply_adapt_delta(h["base"], delta)
    reg = Registry()
    reg.register("base", drifted)
    entry = reg.register("base+adapt", adapted)
    repr_ = StreamEngine(reg, capacity=capacity,
                         default_entry="base+adapt").serve(
        source, n_streams // 2, seed=1)
    acc_re = _acc(repr_.results)
    out["drift_readapted"] = repr_.to_artifact()
    emit("stream_adapt/drift_readapted", None,
         f"accuracy={acc_re:.3f};entry_uid={entry.uid};"
         f"delta_n_updates={delta['n_updates']}")
    entries.append(bench_entry(
        "drift_readapted", xla_us=None,
        meta={"accuracy": acc_re, "entry_uid": entry.uid,
              "harvested_lane": delta["lane"],
              "delta_n_updates": delta["n_updates"]}))

    save_json("stream_adapt", out)
    bench_record("stream_adapt", entries,
                 extra={"fast": fast, "hw": hw, "n_streams": n_streams,
                        "n_classes": N_CLASSES, "lr_w": LR_W,
                        "drift": dict(DRIFT)})
    return out


if __name__ == "__main__":
    run()
