"""Beyond-paper demo: the P²M analog constraint applied to a VLM's vision
frontend.

The assigned llama-3.2-vision arch stubs its frontend (precomputed patch
embeddings per spec). Conceptually though, a DVS-equipped VLM could compute
its *first patch-embedding conv in-pixel* exactly like the paper's spiking
CNN. This example applies the P²M transfer curve + leakage to the patch
embeddings before cross-attention and measures how much the LM output
degrades per circuit config — the paper's co-design question asked of a
modern architecture.

    PYTHONPATH=src python examples/p2m_vlm_frontend.py
"""
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core import analog, leakage
from repro.core.analog import AnalogConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.models import lm


def p2m_constrain_embeddings(img_embed: jax.Array, circuit: CircuitConfig,
                             t_intg_ms: float = 10.0) -> jax.Array:
    """Push patch embeddings through the P²M analog model: quantized to
    transistor levels, compressed by the transfer curve, decayed by the
    circuit's leakage over the integration window."""
    acfg = AnalogConfig()
    lcfg = LeakageConfig(circuit=circuit)
    # embeddings as accumulated voltages: scale into the capacitor swing
    scale = float(jnp.std(img_embed)) * 3.0
    v = img_embed / scale * acfg.v_precharge
    v = analog.transfer_curve(v, acfg)
    # kernel-leak params from a proxy kernel (per-channel sign mix)
    w_proxy = jnp.sign(jnp.sin(jnp.arange(v.shape[-1], dtype=jnp.float32)))
    lk = leakage.kernel_leak_params(w_proxy[None, :, None].repeat(2, 0),
                                    lcfg)
    v = leakage.leak_step(v, leakage.LeakParams(
        v_inf=jnp.full((1,), float(jnp.mean(lk.v_inf))),
        tau_ms=jnp.full((1,), float(jnp.mean(lk.tau_ms)))), t_intg_ms)
    return (v / acfg.v_precharge * scale).astype(img_embed.dtype)


def main():
    cfg = smoke_variant(get_config("llama-3.2-vision-90b"))
    cfg = replace(cfg, compute_dtype="float32")
    B, S = 2, 16
    k = jax.random.PRNGKey(0)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    img = jax.random.normal(jax.random.fold_in(k, 1),
                            (B, cfg.n_image_tokens, cfg.vision_dim))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    ref_logits, _ = lm.forward(params, tokens, cfg, img_embed=img)
    print(f"{'circuit':>9} {'T_INTG':>8} {'logit drift':>12} "
          f"{'top1 agreement':>15}")
    for circuit in (CircuitConfig.IDEAL, CircuitConfig.NULLIFIED,
                    CircuitConfig.SWITCH, CircuitConfig.BASIC):
        for t in (1.0, 10.0, 100.0):
            img_c = p2m_constrain_embeddings(img, circuit, t)
            logits, _ = lm.forward(params, tokens, cfg, img_embed=img_c)
            drift = float(jnp.mean(jnp.abs(logits - ref_logits)))
            agree = float(jnp.mean(
                (jnp.argmax(logits, -1) == jnp.argmax(ref_logits, -1))
                .astype(jnp.float32)))
            print(f"{circuit.value:>9} {t:7.0f}ms {drift:12.4f} {agree:15.3f}")
    print("\nsame co-design story as the paper, one abstraction up: "
          "config (c)\npreserves the VLM's output at 10ms; (a)/(b) degrade "
          "it as T grows.")


if __name__ == "__main__":
    main()
