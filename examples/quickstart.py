"""Quickstart: the P²M in-pixel layer in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's model (P²M analog first layer + digital spiking
backbone), runs a forward pass on synthetic DVS events, and shows the
hardware-algorithm trade-off: the same network evaluated under the three
leakage circuit configs of Fig 3.
"""
import jax
import jax.numpy as jnp

from repro.core import codesign
from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod


def main():
    # 1. a reduced-scale P²M model (paper geometry: DVS in → analog conv →
    #    4-block spiking CNN → 11 gesture classes)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=4, t_intg_ms=10.0, mode="scan"),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16), input_hw=(24, 24),
                                  fc_hidden=64, n_classes=11,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)
    data = ev_mod.dvs_gesture_like(24)

    key = jax.random.PRNGKey(0)
    params, state = codesign.model_init(key, model)
    ev, labels = ev_mod.sample_batch(key, data, 2, model.p2m.t_intg_ms,
                                     n_sub=model.p2m.n_sub)
    print(f"events: {ev.shape}  (B, T_fine, n_sub, H, W, polarity)")

    # 2. forward under each circuit config — watch the pre-activation error
    #    and the classifier output drift as leakage gets worse
    from dataclasses import replace

    from repro.core import p2m_layer
    v_ref = None
    for circuit in (CircuitConfig.IDEAL, CircuitConfig.NULLIFIED,
                    CircuitConfig.SWITCH, CircuitConfig.BASIC):
        p2m_cfg = replace(model.p2m, leak=LeakageConfig(circuit=circuit))
        spikes, v_pre = p2m_layer.p2m_apply(params["p2m"], ev, p2m_cfg)
        if v_ref is None:
            v_ref = v_pre
        err_mv = float(jnp.mean(jnp.abs(v_pre - v_ref))) * 1e3
        print(f"config {circuit.value:>5}: layer-1 spikes={float(spikes.sum()):9.0f}  "
              f"pre-activation error vs ideal={err_mv:7.2f} mV")

    print("\nconfig (c) — the paper's nullified-leak circuit — tracks the "
          "ideal closely at T_INTG=10ms;\nconfig (a) saturates, exactly the "
          "Fig-4 story. Next: examples/train_p2m_gesture.py")


if __name__ == "__main__":
    main()
