"""Reproduce the paper's co-design study (Table 1 + Fig 2 + Fig 4) with the
batched sweep engine: one in-process run covers every circuit config at
every T_INTG and prints the trade-off table per config.

    PYTHONPATH=src python examples/codesign_sweep.py [--fast] [--circuit c] \\
        [--protocol frozen|unfrozen|both] [--axes sigma v-threshold] \\
        [--devices N] [--dataset dvs128 --data-root /data/DvsGesture]

``--circuit all`` (default) sweeps configs (a), (b) and (c) in one batched
compile per T_INTG — the engine stacks the variant axis through the leak
model, the P²M layer, and the batched finetune. ``--axes`` widens the grid
with any registered variant axis (core/variant_grid.py) at its default
value grid; ``--devices N`` shards the stacked axis over a device mesh
(on CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N).
``--protocol both`` runs the paper's frozen phase 2 AND the unfrozen
variant (each config learns its own layer-1 weights) off one shared
pretrain, so the tables compare the co-design optimum across protocols.
"""
import argparse
from dataclasses import replace

from repro.core import sweep as engine
from repro.core import variant_grid
from repro.core.leakage import CircuitConfig
from repro.core.sweep_exec import make_executor
from repro.data import sources


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--circuit", type=str, default="all",
                    choices=["a", "b", "c", "all"])
    ap.add_argument("--protocol", type=str, default="frozen",
                    choices=["frozen", "unfrozen", "both"])
    ap.add_argument("--axes", type=str, nargs="+", default=None,
                    choices=[a.cli for a in variant_grid.AXES],
                    help="widen the grid with registry axes at their "
                         "default value grids")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the stacked variant axis over N devices")
    ap.add_argument("--dataset", type=str, default="synthetic-gesture",
                    choices=["synthetic-gesture", "synthetic-nmnist",
                             "dvs128", "nmnist"],
                    help="event source; dvs128/nmnist need --data-root "
                         "(docs/datasets.md)")
    ap.add_argument("--data-root", type=str, default=None,
                    help="dataset directory for file-backed datasets")
    ap.add_argument("--hw", type=int, default=16)
    args = ap.parse_args()

    data, model, sweep_cfg, grid = engine.paper_setup(
        fast=args.fast, hw=args.hw, dataset=args.dataset,
        data_root=args.data_root)
    # file-backed datasets eval on their held-out split when it exists
    eval_data, _ = sources.resolve_eval_dataset(
        args.dataset, hw=args.hw, data_root=args.data_root)
    if args.circuit != "all":
        grid = replace(grid, circuits=(CircuitConfig(args.circuit),))
    for name in args.axes or []:
        ax = variant_grid.axis(name)
        grid = replace(grid, **{ax.name: ax.cli_defaults})
    results = engine.run_protocols(
        data, model, sweep_cfg, grid,
        protocols=engine.resolve_protocols(args.protocol),
        executor=make_executor(args.devices), eval_data=eval_data)
    for proto, result in results.items():
        # one table per (label, n_sub) series — the normalization unit
        series = sorted({(r["label"], r["n_sub"]) for r in result.records})
        multi_nsub = len({ns for _, ns in series}) > 1
        for lab, ns in series:
            recs = [r for r in result.records
                    if r["label"] == lab and r["n_sub"] == ns]
            tag = f", n_sub={ns}" if multi_nsub else ""
            print(f"\n=== co-design sweep, circuit config ({lab}){tag}, "
                  f"{proto} phase 2 ===")
            print(f"{'T_INTG':>8} {'accuracy':>9} {'train_time':>11} "
                  f"{'bandwidth':>10} {'energy_impr':>12} {'retention':>10}")
            for r in recs:
                print(f"{r['t_intg_ms']:7.0f}ms {r['accuracy']:9.3f} "
                      f"{r['train_time_norm']:10.1f}x "
                      f"{r['bandwidth_norm']:9.2f}x "
                      f"{r['energy_improvement']:11.2f}x "
                      f"{r['retention_err_v'] * 1e3:7.2f}mV")
    print("\npaper's conclusion: T=10ms balances hardware leakage (config "
          "(c) holds 10ms)\nagainst accuracy/bandwidth/training-time — the "
          "rows above show the same trade-off directionally.")
    if len(results) > 1:
        print("unfrozen rows let each circuit learn its own layer-1 "
              "weights; compare per-cell accuracy to see what co-designed "
              "training recovers at short T_INTG.")


if __name__ == "__main__":
    main()
