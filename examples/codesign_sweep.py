"""Reproduce the paper's co-design study (Table 1 + Fig 2): sweep T_INTG
over the paper's grid and print the trade-off table.

    PYTHONPATH=src python examples/codesign_sweep.py [--fast]
"""
import argparse
from dataclasses import replace

from repro.core import codesign
from repro.core.codesign import P2MModelConfig, SweepConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--circuit", type=str, default="c", choices=["a", "b", "c"])
    args = ap.parse_args()

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16), input_hw=(24, 24),
                                  fc_hidden=64, n_classes=11,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)
    data = replace(ev_mod.dvs_gesture_like(24), duration_ms=2000.0)
    sweep = SweepConfig(
        t_intg_grid_ms=(10.0, 1000.0) if args.fast else
        (1.0, 10.0, 100.0, 1000.0),
        batch_size=4,
        pretrain_steps=6 if args.fast else 40,
        finetune_steps=3 if args.fast else 12,
        eval_batches=2 if args.fast else 8)

    recs = codesign.run_sweep(data, model, sweep,
                              circuit=CircuitConfig(args.circuit))
    print(f"\n=== co-design sweep, circuit config ({args.circuit}) ===")
    print(f"{'T_INTG':>8} {'accuracy':>9} {'train_time':>11} "
          f"{'bandwidth':>10} {'energy_impr':>12}")
    for r in recs:
        print(f"{r['t_intg_ms']:7.0f}ms {r['accuracy']:9.3f} "
              f"{r['train_time_norm']:10.1f}x {r['bandwidth_norm']:9.2f}x "
              f"{r['energy_improvement']:11.2f}x")
    print("\npaper's conclusion: T=10ms balances hardware leakage (config "
          "(c) holds 10ms)\nagainst accuracy/bandwidth/training-time — the "
          "rows above show the same trade-off directionally.")


if __name__ == "__main__":
    main()
