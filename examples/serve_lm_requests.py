"""Serve a small LM with batched requests through the slot server
(continuous batching): admits requests, prefills into free KV slots,
decodes the whole batch per step.

    PYTHONPATH=src python examples/serve_lm_requests.py --requests 8
"""
import subprocess
import sys


def main():
    # launch/serve.py is the real driver; this example pins a reproducible
    # smoke configuration of it.
    args = [sys.executable, "-m", "repro.launch.serve",
            "--arch", "internlm2-1.8b", "--smoke",
            "--requests", "8", "--batch", "4",
            "--prompt-len", "32", "--gen", "16"] + sys.argv[1:]
    raise SystemExit(subprocess.call(args))


if __name__ == "__main__":
    main()
