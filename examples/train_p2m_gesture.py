"""End-to-end driver: train the P²M-constrained spiking CNN on the synthetic
DVS-gesture stream with the paper's two-phase protocol, with checkpointing.

    PYTHONPATH=src python examples/train_p2m_gesture.py [--steps 300]

Phase 1: pretrain everything at long T_INTG (no circuit constraints).
Phase 2: impose P²M constraints (config (c), T_INTG=10ms), freeze layer 1,
         finetune the backbone. Eval accuracy is printed along the way.
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import codesign, snn
from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--hw", type=int, default=24)
    ap.add_argument("--t-intg-ms", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", type=str, default="artifacts/ckpt_p2m")
    args = ap.parse_args()

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=args.t_intg_ms,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16),
                                  input_hw=(args.hw, args.hw),
                                  fc_hidden=64, n_classes=11,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)
    data = replace(ev_mod.dvs_gesture_like(args.hw), duration_ms=2000.0)

    key = jax.random.PRNGKey(0)
    n_pre = args.steps // 3
    n_fine = args.steps - n_pre

    # ---------------- phase 1: pretrain at long T, no constraints ---------
    pre_cfg = replace(model, p2m=replace(
        model.p2m, t_intg_ms=model.coarse_window_ms,
        leak=LeakageConfig(circuit=CircuitConfig.IDEAL)))
    params, state = codesign.model_init(key, pre_cfg)
    opt = adamw(2e-3)
    opt_state = opt.init(params)
    step = codesign.make_train_step(pre_cfg, opt, freeze_p2m=False)
    print(f"[phase1] pretrain {n_pre} steps at T={model.coarse_window_ms}ms")
    t0 = time.perf_counter()
    for i in range(n_pre):
        key, kb = jax.random.split(key)
        ev, lab = ev_mod.sample_batch(kb, data, args.batch,
                                      pre_cfg.p2m.t_intg_ms,
                                      n_sub=pre_cfg.p2m.n_sub)
        params, opt_state, state, m, _ = step(params, opt_state, state, ev, lab)
        if i % 20 == 0:
            print(f"[phase1] step {i:4d} loss={float(m['loss']):.3f} "
                  f"acc={float(m['acc']):.3f}")
    print(f"[phase1] done in {time.perf_counter() - t0:.1f}s")

    # ---------------- phase 2: P²M constraints on, layer 1 frozen ---------
    ckpt = CheckpointManager(args.ckpt_dir, every_steps=100, keep=2)
    opt_state = opt.init(params)
    step = codesign.make_train_step(model, opt, freeze_p2m=True)
    eval_fn = codesign.make_eval_fn(model)
    print(f"[phase2] finetune {n_fine} steps at T={model.p2m.t_intg_ms}ms "
          f"(circuit (c), layer 1 frozen)")
    for i in range(n_fine):
        key, kb = jax.random.split(key)
        ev, lab = ev_mod.sample_batch(kb, data, args.batch,
                                      model.p2m.t_intg_ms,
                                      n_sub=model.p2m.n_sub)
        params, opt_state, state, m, _ = step(params, opt_state, state, ev, lab)
        if i % 20 == 0:
            key, ke = jax.random.split(key)
            ev_e, lab_e = ev_mod.sample_batch(ke, data, args.batch,
                                              model.p2m.t_intg_ms,
                                              n_sub=model.p2m.n_sub)
            em, aux = eval_fn(params, state, ev_e, lab_e)
            bw = float(aux["spikes/p2m"]) / max(float(aux["events/in"]), 1.0)
            print(f"[phase2] step {i:4d} loss={float(m['loss']):.3f} "
                  f"eval_acc={float(em['acc']):.3f} bandwidth={bw:.3f}")
        if ckpt.should_save(i + 1):
            ckpt.save(i + 1, {"params": params, "opt": opt_state},
                      extra={"step": i + 1}, blocking=False)
    ckpt.wait()
    print(f"[done] final eval_acc={float(em['acc']):.3f}; checkpoints in "
          f"{args.ckpt_dir}")


if __name__ == "__main__":
    main()
