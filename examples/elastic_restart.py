"""Fault-tolerance demo: train → simulate chip failures → plan the elastic
re-mesh → restore the checkpoint onto the smaller mesh → continue training.

    PYTHONPATH=src python examples/elastic_restart.py

On this CPU host the "meshes" are 1-device, but the code path exercised —
checkpoint save on mesh A, plan_remesh, restore with mesh-B shardings — is
exactly what a pod runs; the mesh shapes printed are the production ones.
"""
import tempfile

import jax

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.ft import plan_remesh
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, run


def main():
    cfg = smoke_variant(get_config("internlm2-1.8b"))
    shape = ShapeConfig("t", "train", 64, 4)
    mesh = make_host_mesh()

    with tempfile.TemporaryDirectory() as d:
        print("=== phase 1: train 6 steps on the 'healthy' mesh ===")
        lp = LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=d,
                        log_every=2, ckpt_async=False)
        r1 = run(cfg, shape, mesh, lp)
        print(f"trained to step {r1.final_step}; "
              f"checkpoints committed at 3 and 6")

        print("\n=== phase 2: 37 of 512 chips fail → plan the re-mesh ===")
        plan = plan_remesh(512 - 37, tp=16, global_batch=256)
        print(f"surviving 475 chips → mesh {plan.mesh_shape} "
              f"(grad_accum x{plan.grad_accum}, {plan.dropped_chips} idle)")
        print(f"note: {plan.note}")

        print("\n=== phase 3: restore onto the new mesh and continue ===")
        # checkpoints are mesh-shape-agnostic: the restore path re-shards
        # every leaf to whatever the new step function expects
        lp2 = LoopConfig(total_steps=9, ckpt_every=3, ckpt_dir=d,
                         log_every=2, ckpt_async=False)
        r2 = run(cfg, shape, mesh, lp2)
        assert r2.restored_from == 6
        print(f"resumed from {r2.restored_from}, reached {r2.final_step}; "
              f"losses continue the same trajectory: "
              f"{[round(x, 4) for x in r2.losses]}")


if __name__ == "__main__":
    main()
