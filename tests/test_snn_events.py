"""Spiking-CNN substrate + synthetic event-stream data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import snn
from repro.core.snn import LIFConfig, SpikingCNNConfig
from repro.data import events as ev_mod


class TestLIF:
    def test_integration_and_reset(self):
        cfg = LIFConfig(tau=2.0, v_threshold=1.0, soft_reset=True)
        # constant drive of 1.5: v crosses threshold → spikes, soft reset
        x = jnp.full((20, 1), 1.5)
        s = snn.lif_over_time(x, cfg)
        assert float(jnp.sum(s)) > 0
        # no drive → no spikes
        s0 = snn.lif_over_time(jnp.zeros((20, 1)), cfg)
        assert float(jnp.sum(s0)) == 0.0

    def test_surrogate_gradient_nonzero(self):
        g = jax.grad(lambda x: jnp.sum(snn.spike_fn(x)))(jnp.array([-0.1, 0.1]))
        assert float(jnp.max(jnp.abs(g))) > 0.0
        # forward is still hard heaviside
        np.testing.assert_array_equal(
            np.asarray(snn.spike_fn(jnp.array([-0.1, 0.1]))), [0.0, 1.0])

    def test_hard_vs_soft_reset(self):
        x = jnp.full((10, 1), 1.8)
        s_soft = snn.lif_over_time(x, LIFConfig(soft_reset=True))
        s_hard = snn.lif_over_time(x, LIFConfig(soft_reset=False))
        assert float(jnp.sum(s_soft)) >= float(jnp.sum(s_hard))


class TestBackbone:
    def _cfg(self, external=False):
        return SpikingCNNConfig(channels=(4, 8, 8, 8), input_hw=(16, 16),
                                fc_hidden=16, n_classes=5,
                                first_layer_external=external)

    def test_forward_shapes_and_state(self):
        cfg = self._cfg()
        params, state = snn.spiking_cnn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.poisson(jax.random.PRNGKey(1), 0.3,
                               (2, 3, 16, 16, 2)).astype(jnp.float32)
        logits, new_state, aux = snn.spiking_cnn_apply(params, state, x, cfg,
                                                       train=True)
        assert logits.shape == (2, 5)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # bn running stats updated
        assert not np.allclose(np.asarray(new_state["bn0"]["mean"]),
                               np.asarray(state["bn0"]["mean"]))
        assert "spikes/conv0" in aux and "synops/fc1" in aux

    def test_external_first_layer(self):
        cfg = self._cfg(external=True)
        params, state = snn.spiking_cnn_init(jax.random.PRNGKey(0), cfg)
        assert "conv0" not in params
        # input: P²M output counts at half resolution, channels[0] channels
        x = jnp.ones((2, 3, 8, 8, 4))
        logits, _, _ = snn.spiking_cnn_apply(params, state, x, cfg, train=False)
        assert logits.shape == (2, 5)

    def test_training_reduces_loss(self):
        """A few SGD steps on one batch reduce CE — grads are sane."""
        cfg = self._cfg()
        params, state = snn.spiking_cnn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.poisson(jax.random.PRNGKey(1), 0.4,
                               (4, 3, 16, 16, 2)).astype(jnp.float32)
        y = jnp.array([0, 1, 2, 3])

        def loss_fn(p, s):
            logits, ns, _ = snn.spiking_cnn_apply(p, s, x, cfg, train=True)
            return snn.cross_entropy(logits, y), ns

        l0 = None
        for _ in range(8):
            (l, state), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state)
            params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
            l0 = l0 if l0 is not None else float(l)
        assert float(l) < l0


class TestEventStreams:
    def test_batch_shapes_and_polarity(self):
        cfg = ev_mod.dvs_gesture_like(16)
        ev, labels = ev_mod.sample_batch(jax.random.PRNGKey(0), cfg, 3, 100.0)
        # [B, T_out, n_sub, H, W, 2]
        assert ev.ndim == 6 and ev.shape[0] == 3 and ev.shape[-1] == 2
        assert ev.shape[3:5] == (16, 16)
        assert labels.shape == (3,)
        assert float(jnp.min(ev)) >= 0.0          # counts
        assert float(jnp.sum(ev)) > 0.0           # events exist

    def test_event_count_invariant_to_t_intg(self):
        """Total events ≈ independent of integration slicing (same scene)."""
        cfg = ev_mod.dvs_gesture_like(16)
        k = jax.random.PRNGKey(5)
        ev_a, _ = ev_mod.sample_batch(k, cfg, 2, 100.0)
        ev_b, _ = ev_mod.sample_batch(k, cfg, 2, 500.0)
        ta, tb = float(jnp.sum(ev_a)), float(jnp.sum(ev_b))
        assert abs(ta - tb) / max(ta, tb) < 0.15

    def test_labels_deterministic_in_key(self):
        cfg = ev_mod.nmnist_like(12)
        k = jax.random.PRNGKey(3)
        ev1, l1 = ev_mod.sample_batch(k, cfg, 4, 200.0)
        ev2, l2 = ev_mod.sample_batch(k, cfg, 4, 200.0)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(ev1), np.asarray(ev2))

    def test_classes_distinguishable(self):
        """Different labels produce different spatio-temporal statistics —
        the stream is learnable, not noise."""
        cfg = ev_mod.dvs_gesture_like(16)
        k = jax.random.PRNGKey(0)
        ev, labels = ev_mod.sample_batch_with_labels(
            k, cfg, jnp.array([0, 5]), 250.0) if hasattr(
                ev_mod, "sample_batch_with_labels") else (None, None)
        if ev is None:
            pytest.skip("no fixed-label sampler exposed")
        m0 = np.asarray(jnp.mean(ev[0], axis=(0, 1)))
        m1 = np.asarray(jnp.mean(ev[1], axis=(0, 1)))
        assert np.abs(m0 - m1).max() > 1e-4
