"""Runtime substrate: checkpointing, fault-tolerance monitors, elastic
planning, gradient compression, token pipeline, training loop restart."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def _tree(self, scale=1.0):
        return {"a": {"w": jnp.full((4, 4), scale), "b": jnp.arange(3.0)},
                "step_arr": jnp.ones((2,)) * scale}

    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import load_checkpoint, save_checkpoint
        t = self._tree(2.0)
        save_checkpoint(tmp_path, 7, t, extra={"step": 7})
        got, extra = load_checkpoint(tmp_path)
        assert extra["step"] == 7
        np.testing.assert_array_equal(np.asarray(got["a"]["w"]),
                                      np.asarray(t["a"]["w"]))

    def test_atomic_commit_ignores_uncommitted(self, tmp_path):
        from repro.checkpoint import latest_step, save_checkpoint
        save_checkpoint(tmp_path, 5, self._tree())
        # simulate a crashed save: directory without COMMIT
        bad = tmp_path / "step_000000009"
        bad.mkdir()
        (bad / "index.json").write_text("{}")
        assert latest_step(tmp_path) == 5

    def test_retention_gc(self, tmp_path):
        from repro.checkpoint import CheckpointManager, latest_step
        mgr = CheckpointManager(tmp_path, every_steps=1, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        kept = sorted(p.name for p in tmp_path.iterdir())
        assert kept == ["step_000000003", "step_000000004"]
        assert latest_step(tmp_path) == 4

    def test_async_save(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(tmp_path, every_steps=1, keep=5)
        mgr.save(1, self._tree(1.0), blocking=False)
        mgr.wait()
        got, _ = mgr.restore()
        np.testing.assert_array_equal(np.asarray(got["step_arr"]), [1.0, 1.0])

    def test_restore_with_shardings(self, tmp_path):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self._tree(3.0))
        sh = NamedSharding(mesh, P())
        shardings = jax.tree.map(lambda _: sh, self._tree())
        got, _ = mgr.restore(shardings=shardings)
        assert got["a"]["w"].sharding == sh

    def test_missing_returns_none(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        assert CheckpointManager(tmp_path / "nope").restore() is None


# ---------------------------------------------------------------------------
# fault-tolerance monitors
# ---------------------------------------------------------------------------


class TestStragglerMonitor:
    def test_flags_outlier_after_warmup(self):
        from repro.ft import StragglerMonitor
        m = StragglerMonitor(warmup_steps=4, k_sigma=4.0)
        flagged = []
        for i in range(30):
            dt = 1.0 + 0.01 * ((i * 2654435761) % 7 - 3) / 3.0
            flagged.append(m.observe(i, dt))
        assert not any(flagged)
        assert m.observe(30, 3.0)         # 3x the mean → straggler
        # baseline not poisoned by the outlier
        assert abs(m.mean_s - 1.0) < 0.05

    def test_consecutive_flags(self):
        from repro.ft import StragglerMonitor
        m = StragglerMonitor(warmup_steps=2, k_sigma=3.0)
        for i in range(10):
            m.observe(i, 1.0)
        for i in range(10, 13):
            m.observe(i, 5.0)
        assert m.consecutive_flags(3)


class TestHeartbeat:
    def test_dead_detection_simulated_clock(self):
        from repro.ft import HeartbeatTracker
        now = [0.0]
        hb = HeartbeatTracker(n_workers=4, timeout_s=10.0, clock=lambda: now[0])
        now[0] = 5.0
        hb.beat(0); hb.beat(1); hb.beat(2)
        now[0] = 12.0
        assert hb.dead() == [3]
        assert hb.alive() == [0, 1, 2]


class TestPreemptionGuard:
    def test_trigger_and_poll(self):
        from repro.ft import PreemptionGuard
        with PreemptionGuard() as g:
            assert not g.preempted
            g.trigger()
            assert g.preempted


class TestElasticPlan:
    def test_preserves_model_axis(self):
        from repro.ft import plan_remesh
        plan = plan_remesh(480, tp=16, global_batch=256)
        assert plan.mesh_shape == (30, 16)
        # 256 % 30 != 0 → grad accumulation restores the global batch
        assert plan.grad_accum > 1

    def test_no_accum_when_batch_divides(self):
        from repro.ft import plan_remesh
        plan = plan_remesh(256, tp=16, global_batch=256)
        assert plan.mesh_shape == (16, 16)
        assert plan.grad_accum == 1

    def test_degrades_model_axis_when_needed(self):
        from repro.ft import plan_remesh
        plan = plan_remesh(8, tp=16, global_batch=64)
        assert plan.mesh_shape[1] <= 8
        assert plan.chips <= 8

    def test_full_pod(self):
        from repro.ft import plan_remesh
        plan = plan_remesh(512, tp=16, global_batch=256)
        assert plan.mesh_shape == (32, 16)
        assert plan.grad_accum == 1
        assert plan.dropped_chips == 0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


class TestCompression:
    def test_int8_roundtrip_error_bound(self):
        from repro.distributed import compress_int8, decompress_int8
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
        q, scale, pad = compress_int8(g, block=128)
        back = decompress_int8(q, scale, pad, g.shape)
        # max error ≤ scale/2 per block
        err = jnp.abs(back - g)
        bound = jnp.repeat(scale / 2, 128)[:1000] + 1e-9
        assert bool(jnp.all(err <= bound))

    def test_compression_ratio(self):
        from repro.distributed import compress_int8
        g = jnp.ones((4096,))
        q, scale, pad = compress_int8(g, block=256)
        raw = 4096 * 4
        comp = q.size * 1 + scale.size * 4
        assert raw / comp > 3.5

    def test_error_feedback_unbiased_over_steps(self):
        """With EF, the *cumulative* applied update converges to the
        cumulative true gradient (residual stays bounded)."""
        from repro.distributed import compress_int8, decompress_int8
        key = jax.random.PRNGKey(1)
        ef = jnp.zeros((512,))
        total_true = jnp.zeros((512,))
        total_applied = jnp.zeros((512,))
        for i in range(20):
            g = jax.random.normal(jax.random.fold_in(key, i), (512,))
            total_true += g
            gq, scale, pad = compress_int8(g + ef, block=128)
            applied = decompress_int8(gq, scale, pad, g.shape)
            ef = (g + ef) - applied
            total_applied += applied
        # residual is one quantization step, not 20 accumulated ones
        drift = float(jnp.max(jnp.abs(total_true - total_applied)))
        assert drift < 0.05

    def test_tree_allreduce_single_device(self):
        """pmean over a 1-member axis is identity → compressed allreduce
        reduces to quantize/dequantize + EF bookkeeping."""
        from repro.distributed import (CompressionState,
                                       init_error_feedback)
        from repro.distributed.compression import tree_compressed_allreduce
        import jax.experimental.shard_map as shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
        state = init_error_feedback(grads)

        def f(g, res):
            out, new_state = tree_compressed_allreduce(
                g, CompressionState(residual=res), "data")
            return out, new_state.residual

        fm = shard_map.shard_map(
            f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False)   # all_gather-based reduce defeats rep inference
        out, res = fm(grads, state.residual)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(grads["w"]), atol=0.05)
        # residual + applied == original
        np.testing.assert_allclose(
            np.asarray(out["w"] + res["w"]), np.asarray(grads["w"]),
            rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# token pipeline
# ---------------------------------------------------------------------------


class TestTokens:
    def _cfg(self):
        from repro.data.tokens import TokenStreamConfig
        return TokenStreamConfig(vocab_size=128, seq_len=32, global_batch=4)

    def test_deterministic_in_step(self):
        from repro.data.tokens import sample_batch
        cfg = self._cfg()
        b1 = sample_batch(cfg, jnp.asarray(5))
        b2 = sample_batch(cfg, jnp.asarray(5))
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = sample_batch(cfg, jnp.asarray(6))
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        from repro.data.tokens import sample_batch
        b = sample_batch(self._cfg(), jnp.asarray(0))
        np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                      np.asarray(b["tokens"][:, 1:]))

    def test_seek_skip_ahead(self):
        from repro.data.tokens import TokenLoader
        cfg = self._cfg()
        l1 = TokenLoader(cfg)
        for _ in range(3):
            next(l1)
        s1, b1 = next(l1)
        l2 = TokenLoader(cfg)
        l2.seek(3)
        s2, b2 = next(l2)
        assert s1 == s2 == 3
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_structure_learnable(self):
        """Markov stream has bigram structure: H(next|prev) < H(next) —
        a next-token predictor can beat the unigram baseline."""
        from repro.data.tokens import TokenStreamConfig, sample_batch
        cfg = TokenStreamConfig(vocab_size=16, seq_len=512, global_batch=8,
                                markov_temp=0.4, n_states=8)
        b = sample_batch(cfg, jnp.asarray(0))
        toks = np.asarray(b["tokens"])
        uni = np.bincount(toks.reshape(-1), minlength=16).astype(float) + 1e-9
        p_uni = uni / uni.sum()
        h_uni = -(p_uni * np.log2(p_uni)).sum()
        big = np.zeros((16, 16)) + 1e-9
        for row in toks:
            np.add.at(big, (row[:-1], row[1:]), 1.0)
        p_j = big / big.sum()
        p_prev = p_j.sum(1, keepdims=True)
        h_cond = -(p_j * np.log2(p_j / p_prev)).sum()
        assert h_cond < h_uni - 0.05   # ≥0.05 bits of usable structure

    def test_host_slice(self):
        from repro.data.tokens import host_slice, sample_batch
        b = sample_batch(self._cfg(), jnp.asarray(0))
        s0 = host_slice(b, 0, 2)
        s1 = host_slice(b, 1, 2)
        assert s0["tokens"].shape[0] == 2
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s0["tokens"]), np.asarray(s1["tokens"])]),
            np.asarray(b["tokens"]))


# ---------------------------------------------------------------------------
# training loop restart (integration)
# ---------------------------------------------------------------------------


class TestLoopRestart:
    def test_restart_replays_identically(self, tmp_path):
        from repro.configs import get_config, smoke_variant
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_host_mesh
        from repro.train.loop import LoopConfig, run

        cfg = smoke_variant(get_config("internlm2-1.8b"))
        shape = ShapeConfig("t", "train", 32, 2)
        mesh = make_host_mesh()
        lp = LoopConfig(total_steps=5, ckpt_every=3, log_every=100,
                        ckpt_dir=str(tmp_path), ckpt_async=False)
        logs = []
        r1 = run(cfg, shape, mesh, lp, log=logs.append)
        assert r1.final_step == 5
        # a "crashed" rerun resumes at 3 and reproduces steps 3..4 exactly
        r2 = run(cfg, shape, mesh, lp, log=logs.append)
        assert r2.restored_from == 3
        np.testing.assert_allclose(r2.losses, r1.losses[3:], rtol=1e-5)
