"""Grouped-dispatch MoE invariants (the §Perf iteration-2 change)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_variant
from repro.nn import moe as moe_mod


def _cfg(dropless=True, **kw):
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    if dropless:
        kw.setdefault("capacity_factor", cfg.n_experts / max(cfg.top_k, 1))
    return dataclasses.replace(cfg, compute_dtype="float32", **kw)


class TestGroupingInvariance:
    def test_dropless_output_independent_of_groups(self):
        """With ample capacity, splitting the dispatch into G groups must
        not change the output at all — grouping only affects *which* tokens
        drop under pressure, never the kept-token math."""
        cfg = _cfg(dropless=True)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        y1, _ = moe_mod.moe_apply(p, x, cfg, groups=1)
        y2, _ = moe_mod.moe_apply(p, x, cfg, groups=4)
        y3, _ = moe_mod.moe_apply(p, x, cfg, groups=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y3),
                                   rtol=1e-5, atol=1e-5)

    def test_capacity_is_per_group(self):
        """Under tight capacity, per-group enforcement drops tokens in the
        overloaded group even when another group has slack."""
        cfg = _cfg(dropless=False, capacity_factor=0.5)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model))
        _, aux1 = moe_mod.moe_apply(p, x, cfg, groups=1)
        _, aux4 = moe_mod.moe_apply(p, x, cfg, groups=4)
        assert float(aux1["drop_frac"]) > 0.0
        assert float(aux4["drop_frac"]) > 0.0

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_gate_weights_preserved_property(self, seed):
        """Dropless output equals the explicit dense mixture Σ g_e E_e(x)."""
        cfg = _cfg(dropless=True)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, cfg.d_model))
        y, _ = moe_mod.moe_apply(p, x, cfg, groups=1)

        # dense reference: route every token through every chosen expert
        xf = x.reshape(-1, cfg.d_model)
        logits = xf @ p["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, cfg.top_k)
        gate = gate / gate.sum(-1, keepdims=True)

        def expert(e, v):
            h = jax.nn.silu(v @ p["wg"][e]) * (v @ p["wu"][e])
            return h @ p["wd"][e]

        want = jnp.zeros_like(xf)
        for t in range(xf.shape[0]):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.top_k):
                acc += gate[t, j] * expert(idx[t, j], xf[t])
            want = want.at[t].set(acc)
        np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                                   np.asarray(want), rtol=2e-4, atol=2e-4)
