"""The paper-level integration test: a miniature T_INTG co-design sweep must
reproduce the directional claims of Table 1 / Fig 2 (bandwidth ↑, training
slower, energy improvement ≥, at shorter T_INTG; P²M ≥ ~2× energy win)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codesign
from repro.core.codesign import P2MModelConfig, SweepConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod


def _mini():
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=120.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 8, 8, 8), input_hw=(16, 16),
                                  fc_hidden=16, n_classes=5,
                                  first_layer_external=True),
        coarse_window_ms=120.0)
    data = ev_mod.EventStreamConfig(name="gesture", height=16, width=16,
                                    n_classes=5, duration_ms=240.0)
    sweep = SweepConfig(t_intg_grid_ms=(5.0, 30.0, 120.0), batch_size=2,
                        pretrain_steps=4, finetune_steps=2, eval_batches=2,
                        seed=0)
    return model, data, sweep


@pytest.fixture(scope="module")
def sweep_records():
    model, data, sweep = _mini()
    return codesign.run_sweep(data, model, sweep, log=lambda *_: None)


class TestCoDesignSweep:
    def test_record_completeness(self, sweep_records):
        recs = sweep_records
        assert len(recs) == 3
        for r in recs:
            for k in ("accuracy", "train_time_s", "bandwidth_norm",
                      "backend_energy_p2m_j", "backend_energy_conventional_j",
                      "energy_improvement", "train_time_norm"):
                assert k in r, k
            assert 0.0 <= r["accuracy"] <= 1.0

    def test_bandwidth_increases_at_short_t(self, sweep_records):
        """Fig 2 left: normalized bandwidth > 1 at short T_INTG."""
        recs = sweep_records
        assert recs[0]["bandwidth_norm"] > recs[-1]["bandwidth_norm"]
        assert abs(recs[-1]["bandwidth_norm"] - 1.0) < 1e-6

    def test_training_slower_at_short_t(self, sweep_records):
        """Table 1: more timesteps at short T_INTG → slower steps."""
        recs = sweep_records
        assert recs[0]["train_time_norm"] > 1.5 * recs[-1]["train_time_norm"]

    def test_p2m_energy_wins(self, sweep_records):
        """Fig 2 right: ≥~2× backend-energy improvement at every T."""
        for r in sweep_records:
            assert r["energy_improvement"] > 1.5, r["t_intg_ms"]

    def test_energy_improvement_grows_with_t(self, sweep_records):
        recs = sweep_records
        assert recs[-1]["energy_improvement"] >= recs[0]["energy_improvement"]


class TestTrainingProtocol:
    def test_freeze_p2m_keeps_layer1_static(self):
        """Phase-2 finetune must not move P²M weights (paper §3)."""
        from repro.optim import adamw
        model, data, _ = _mini()
        key = jax.random.PRNGKey(0)
        params, state = codesign.model_init(key, model)
        opt = adamw(1e-2)
        opt_state = opt.init(params)
        step = codesign.make_train_step(model, opt, freeze_p2m=True)
        ev, labels = ev_mod.sample_batch(key, data, 2, model.p2m.t_intg_ms,
                                         n_sub=model.p2m.n_sub)
        w0 = np.asarray(params["p2m"]["w"]).copy()
        b0 = np.asarray(params["backbone"]["fc1"]["w"]).copy()
        params, opt_state, state, m, aux = step(params, opt_state, state,
                                                ev, labels)
        np.testing.assert_array_equal(np.asarray(params["p2m"]["w"]), w0)
        assert not np.array_equal(np.asarray(params["backbone"]["fc1"]["w"]), b0)

    def test_protocol_pair_frozen_static_unfrozen_moves(self):
        """The batched engine's protocol pair on the SAME batch: the frozen
        step returns layer 1 bitwise untouched; the unfrozen step
        measurably moves every circuit config's own layer-1 copy, and the
        copies diverge from each other (each config learns under its own
        leak model)."""
        from repro.core import p2m_layer
        from repro.core import sweep as engine
        from repro.optim import adamw
        model, data, _ = _mini()
        mcfg = replace(model, p2m=replace(model.p2m, mode="curvefit"))
        leak_cfgs = engine.expand_leak_configs(engine.SweepGrid(),
                                               mcfg.p2m.leak)
        G = len(leak_cfgs)
        key = jax.random.PRNGKey(0)
        params, state = codesign.model_init(key, mcfg)
        bb_s = engine._stack_tree(params["backbone"], G)
        state_s = engine._stack_tree(state, G)
        ev, labels = ev_mod.sample_batch(key, data, 2, mcfg.p2m.t_intg_ms,
                                         n_sub=mcfg.p2m.n_sub)
        opt = adamw(1e-2)

        step_f = engine.make_batched_finetune_step(mcfg, leak_cfgs, opt,
                                                   protocol="frozen")
        p2m_out, bb_out, *_ = step_f(params["p2m"], bb_s,
                                     jax.vmap(opt.init)(bb_s), state_s,
                                     ev, labels)
        np.testing.assert_array_equal(np.asarray(p2m_out["w"]),
                                      np.asarray(params["p2m"]["w"]))
        assert not np.array_equal(np.asarray(bb_out["fc1"]["w"]),
                                  np.asarray(bb_s["fc1"]["w"]))

        p2m_s = p2m_layer.stack_p2m_params(params["p2m"], G)
        step_u = engine.make_batched_finetune_step(mcfg, leak_cfgs, opt,
                                                   protocol="unfrozen")
        opt_u = jax.vmap(opt.init)({"p2m": p2m_s, "backbone": bb_s})
        p2m_s_out, *_ = step_u(p2m_s, bb_s, opt_u, state_s, ev, labels)
        w_new = np.asarray(p2m_s_out["w"])
        w_old = np.asarray(p2m_s["w"])
        for g in range(G):
            assert np.max(np.abs(w_new[g] - w_old[g])) > 1e-6, \
                f"unfrozen step left config {leak_cfgs[g].circuit.value} " \
                f"layer-1 static"
        for g in range(1, G):
            assert np.max(np.abs(w_new[g] - w_new[0])) > 1e-7, \
                "configs did not diverge"

    def test_full_model_gradients_finite(self):
        model, data, _ = _mini()
        key = jax.random.PRNGKey(1)
        params, state = codesign.model_init(key, model)
        ev, labels = ev_mod.sample_batch(key, data, 2, model.p2m.t_intg_ms,
                                         n_sub=model.p2m.n_sub)

        def loss(p):
            logits, _, _ = codesign.model_apply(p, state, ev, model, train=True)
            return jnp.mean(
                jax.nn.log_softmax(logits)[jnp.arange(2), labels]) * -1.0

        g = jax.grad(loss)(params)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.all(jnp.isfinite(leaf)))
