"""tools/ab_compare.py — A/B accuracy verdicts on handcrafted artifacts.

The helper has two jobs with different failure modes: the PAIRED path
(two artifacts, same replayed streams) must refuse to pair streams that
are not actually the same (disjoint ids, label mismatch, pre-v4 schema),
and its exact sign test must match hand-computed binomial tails; the
UNPAIRED path (entry-vs-entry inside one artifact) must filter rows by
registry entry and keep its permutation p-value sane on degenerate
inputs. Every case here is a handcrafted artifact — no engine runs.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import ab_compare  # noqa: E402


def _art(rows, version=5):
    return {"schema": f"p2m-stream-serving/v{version}", "streams": rows}


def _row(sid, label, correct, entry=None):
    row = {"stream_id": sid, "label": label, "correct": correct,
           "prediction": label if correct else (label + 1) % 3}
    if entry is not None:
        row["entry"] = entry
    return row


class TestSchemaGate:
    def test_v4_and_v5_accepted(self):
        assert ab_compare.schema_version(_art([], 4)) == 4
        assert ab_compare.schema_version(_art([], 5)) == 5

    def test_pre_v4_rejected(self):
        with pytest.raises(ValueError, match="predates"):
            ab_compare.schema_version(_art([], 3))

    def test_non_serving_artifact_rejected(self):
        with pytest.raises(ValueError, match="not a serving-stats"):
            ab_compare.schema_version({"schema": "p2m-bench/v1"})


class TestStreamRows:
    def test_unlabeled_streams_dropped(self):
        rows = [_row(0, 1, True), _row(1, -1, True),
                {"stream_id": 2, "label": None, "correct": True}]
        assert set(ab_compare.stream_rows(_art(rows))) == {0}

    def test_entry_filter(self):
        rows = [_row(0, 1, True, "a"), _row(1, 1, False, "b")]
        assert set(ab_compare.stream_rows(_art(rows), "a")) == {0}
        assert set(ab_compare.stream_rows(_art(rows), "b")) == {1}

    def test_unknown_entry_names_present_entries(self):
        rows = [_row(0, 1, True, "a")]
        with pytest.raises(ValueError, match="entries present"):
            ab_compare.stream_rows(_art(rows), "nope")


class TestSignTest:
    def test_no_discordant_pairs_is_p1(self):
        assert ab_compare.sign_test(0, 0) == 1.0

    def test_one_sided_discordance_exact_tail(self):
        # 8 discordant pairs, all favoring B: p = 2 * C(8,0)/2^8
        assert ab_compare.sign_test(0, 8) == pytest.approx(2 / 256)

    def test_balanced_discordance_not_significant(self):
        assert ab_compare.sign_test(4, 4) == pytest.approx(1.0)


class TestPaired:
    def test_identical_artifacts_null_verdict(self):
        rows = {i: _row(i, i % 3, i % 2 == 0) for i in range(20)}
        res = ab_compare.paired_compare(rows, rows)
        assert res["delta"] == 0.0
        assert res["p"] == 1.0
        assert res["ci"][0] <= 0.0 <= res["ci"][1]

    def test_clear_improvement_significant(self):
        a = {i: _row(i, i % 3, False) for i in range(24)}
        b = {i: _row(i, i % 3, i < 16) for i in range(24)}
        res = ab_compare.paired_compare(a, b)
        assert res["delta"] == pytest.approx(16 / 24)
        assert res["n01"] == 0 and res["n10"] == 16
        assert res["p"] < 0.001
        assert res["ci"][0] > 0.0

    def test_disjoint_stream_ids_rejected(self):
        a = {i: _row(i, 0, True) for i in range(4)}
        b = {i: _row(i, 0, True) for i in range(10, 14)}
        with pytest.raises(ValueError, match="no overlapping"):
            ab_compare.paired_compare(a, b)

    def test_label_mismatch_rejected(self):
        a = {0: _row(0, 1, True)}
        b = {0: _row(0, 2, True)}
        with pytest.raises(ValueError, match="different labels"):
            ab_compare.paired_compare(a, b)

    def test_bootstrap_is_seeded(self):
        a = {i: _row(i, 0, i % 2 == 0) for i in range(16)}
        b = {i: _row(i, 0, i % 3 == 0) for i in range(16)}
        r1 = ab_compare.paired_compare(a, b, seed=7)
        r2 = ab_compare.paired_compare(a, b, seed=7)
        assert r1["ci"] == r2["ci"]


class TestUnpaired:
    def test_degenerate_gap_significant(self):
        a = {i: _row(i, 0, False, "a") for i in range(20)}
        b = {i: _row(100 + i, 0, True, "b") for i in range(20)}
        res = ab_compare.unpaired_compare(a, b)
        assert res["delta"] == 1.0
        assert res["p"] < 0.01

    def test_identical_rates_not_significant(self):
        a = {i: _row(i, 0, i % 2 == 0, "a") for i in range(20)}
        b = {100 + i: _row(100 + i, 0, i % 2 == 0, "b") for i in range(20)}
        res = ab_compare.unpaired_compare(a, b)
        assert res["delta"] == 0.0
        assert res["p"] > 0.5

    def test_empty_side_rejected(self):
        a = {0: _row(0, 0, True)}
        with pytest.raises(ValueError, match="no labeled"):
            ab_compare.unpaired_compare(a, {})


class TestCLI:
    def _run(self, *args):
        return subprocess.run(
            [sys.executable, str(REPO / "tools" / "ab_compare.py"),
             *args], capture_output=True, text=True)

    def test_paired_verdict_line(self, tmp_path):
        a = _art([_row(i, i % 3, False) for i in range(24)])
        b = _art([_row(i, i % 3, i < 16) for i in range(24)])
        pa, pb = tmp_path / "a.json", tmp_path / "b.json"
        pa.write_text(json.dumps(a))
        pb.write_text(json.dumps(b))
        out = self._run(str(pa), str(pb))
        assert out.returncode == 0
        assert "verdict:" in out.stdout
        assert "SIGNIFICANT" in out.stdout.splitlines()[-1]

    def test_entries_mode(self, tmp_path):
        art = _art([_row(i, 0, False, "x") for i in range(10)]
                   + [_row(100 + i, 0, True, "y") for i in range(10)])
        p = tmp_path / "m.json"
        p.write_text(json.dumps(art))
        out = self._run(str(p), "--entries", "x", "y")
        assert out.returncode == 0
        assert "entry:y vs entry:x" in out.stdout

    def test_usage_error(self, tmp_path):
        p = tmp_path / "a.json"
        p.write_text(json.dumps(_art([])))
        assert self._run(str(p)).returncode == 2

    def test_old_schema_exit_2(self, tmp_path):
        p = tmp_path / "a.json"
        p.write_text(json.dumps(_art([], version=3)))
        out = self._run(str(p), str(p))
        assert out.returncode == 2
        assert "predates" in out.stderr
