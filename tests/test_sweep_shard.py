"""Mesh-sharded sweep executor: sharded-vs-single-device record parity
(via a subprocess with 8 forced host devices, like test_pipeline.py) plus
in-process unit coverage of the padding/mesh policy in
``repro.core.sweep_exec``.

The parity bar is EXACT equality: the shard_map body is the same traced
function as the single-device path, only partitioned, so every non-timing
record field must match bit-for-bit — including an ``n_cfg`` that does not
divide the device count (padding lanes compute real-but-discarded work).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sweep_exec import SweepExecutor, make_executor

REPO = Path(__file__).resolve().parents[1]


class TestExecutorPolicy:
    def test_default_is_single_device(self):
        ex = make_executor(None)
        assert ex.devices == 1 and not ex.is_sharded

    def test_make_executor_validates_devices_eagerly(self):
        """A bad --devices must fail at executor construction, before any
        compute (not after a paper-scale pretrain)."""
        if jax.device_count() >= 4:
            assert make_executor(4).devices == 4
        else:
            with pytest.raises(ValueError, match="force_host_platform"):
                make_executor(4)

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            SweepExecutor(devices=0)

    @pytest.mark.parametrize("n_cfg,devices,padded", [
        (3, 1, 3), (3, 8, 8), (4, 8, 8), (9, 8, 16), (8, 8, 8)])
    def test_padded_size(self, n_cfg, devices, padded):
        assert SweepExecutor(devices=devices).padded_size(n_cfg) == padded

    def test_pad_stacked_repeats_last_variant(self):
        ex = SweepExecutor(devices=4)
        tree = {"a": jnp.arange(3.0), "b": jnp.ones((3, 2))}
        padded = ex.pad_stacked(tree, 3)
        assert padded["a"].shape == (4,) and padded["b"].shape == (4, 2)
        np.testing.assert_array_equal(np.asarray(padded["a"]),
                                      [0.0, 1.0, 2.0, 2.0])

    def test_pad_noop_when_divisible(self):
        ex = SweepExecutor(devices=2)
        x = jnp.arange(4.0)
        assert ex.pad_stacked({"x": x}, 4)["x"] is x

    def test_single_device_shard_is_identity(self):
        ex = SweepExecutor(devices=1)
        fn = lambda x: x + 1  # noqa: E731
        assert ex.shard(fn, in_specs=(None,), out_specs=None) is fn

    def test_mesh_requires_enough_devices(self):
        want = jax.device_count() + 1
        with pytest.raises(ValueError, match="force_host_platform"):
            _ = SweepExecutor(devices=want).mesh


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device (run under XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
class TestShardedExecution:
    """In-process shard_map coverage — active under the CI multi-device
    step; the full-engine record parity lives in the subprocess test."""

    def test_sharded_matches_unsharded_stacked_fn(self):
        from repro.core.sweep_exec import P_CFG, P_REP
        n_dev = jax.device_count()
        ex = SweepExecutor(devices=n_dev)

        def fn(stacked, shared):
            return jax.vmap(lambda s: {"y": s["a"] * 2.0 + shared.sum(),
                                       "n": (s["a"] > 0).sum()})(stacked)

        n_cfg = n_dev + 1                       # force a padded lane
        stacked = {"a": jnp.arange(float(n_cfg * 3)).reshape(n_cfg, 3) - 2.0}
        shared = jnp.ones((4,))
        want = fn(stacked, shared)
        padded = ex.pad_stacked(stacked, n_cfg)
        got = jax.jit(ex.shard(fn, in_specs=(P_CFG, P_REP),
                               out_specs=P_CFG))(padded, shared)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k][:n_cfg]),
                                          np.asarray(want[k]))


_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.core import sweep as engine
    from repro.core.codesign import P2MModelConfig, SweepConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig
    from repro.core.sweep_exec import make_executor
    from repro.data import events as ev_mod

    assert jax.device_count() == 8, jax.device_count()
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=120.0),
        backbone=SpikingCNNConfig(channels=(8, 8, 8, 8), input_hw=(16, 16),
                                  fc_hidden=16, n_classes=5,
                                  first_layer_external=True),
        coarse_window_ms=120.0)
    data = ev_mod.EventStreamConfig(name="gesture", height=16, width=16,
                                    n_classes=5, duration_ms=240.0)
    sweep_cfg = SweepConfig(batch_size=2, pretrain_steps=2, finetune_steps=2,
                            eval_batches=1, lr_p2m=5e-4)
    # 3 circuits, mismatch expands only (c): n_cfg = 4. devices=8 pads the
    # stacked axis 4 -> 8; devices=3 pads 4 -> 6 (non-divisible n_cfg).
    grid = engine.SweepGrid(t_intg_grid_ms=(30.0, 120.0),
                            null_mismatch=(0.02, 0.06))
    TIMING = {"train_time_s", "train_time_per_step_s", "train_time_norm"}
    for proto in ("frozen", "unfrozen"):
        base = engine.run_grid(data, model, sweep_cfg, grid,
                               log=lambda *_: None, protocol=proto)
        assert [r["label"] for r in base.records[:4]] == [
            "a", "b", "c@m=0.02", "c@m=0.06"]
        for dev in (3, 8):
            sh = engine.run_grid(data, model, sweep_cfg, grid,
                                 log=lambda *_: None, protocol=proto,
                                 executor=make_executor(dev))
            assert len(sh.records) == len(base.records)
            for a, b in zip(base.records, sh.records):
                assert set(a) == set(b), (set(a) ^ set(b))
                for k in a:
                    if k in TIMING:
                        assert b[k] > 0.0
                        continue
                    assert a[k] == b[k], (proto, dev, k, a["label"],
                                          a["t_intg_ms"], a[k], b[k])
        print(proto, "parity ok")
    print("PARITY_PASS")
""")


@pytest.mark.slow
def test_sharded_records_match_single_device():
    """Forced 8-host-device run: frozen AND unfrozen grids, devices in
    {3, 8} (n_cfg = 4 → both the divisible and the padded case), every
    non-timing record field exactly equal to the unsharded run, in the
    same order."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)   # the script must own the device count
    proc = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PARITY_PASS" in proc.stdout
