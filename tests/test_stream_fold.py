"""Fused streaming-fold kernel (repro.kernels.stream_fold) tests.

The load-bearing contract: the deposit-mode kernel is **bit-exact** with
the XLA ``lax.scan`` fold the streaming accumulator runs — not allclose,
equal — on every shape, including lane/tile padding edges, empty
(gap-decay) chunks, and inactive capacity-padding lanes. Because the
scan fold telescopes to the offline curve-fit forward
(docs/streaming.md), bit-exactness here is what lets
``StreamEngine(use_kernel=True)`` inherit the streaming≡offline parity
contract unchanged; tests/test_streaming.py re-runs its parity grid
through the kernel on top of this suite."""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from repro.core.leakage import CircuitConfig, LeakageConfig  # noqa: E402
from repro.core.p2m_layer import _conv  # noqa: E402
from repro.kernels.stream_fold import ops, ref  # noqa: E402
from repro.kernels.stream_fold.stream_fold import (  # noqa: E402
    stream_fold_mac_pallas, stream_fold_pallas,
)
from repro.stream import accumulator, deploy as deploy_mod  # noqa: E402

HW = 16


def _fold_inputs(key, S, N, F):
    ks = jax.random.split(key, 3)
    x0 = jax.random.normal(ks[0], (N, F)) * 0.05
    dep = jax.random.normal(ks[1], (S, N, F)) * 0.01
    a = jnp.exp(-jax.random.uniform(ks[2], (F,)))
    return x0, dep, a


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

class TestFoldKernel:
    @pytest.mark.parametrize("S,N,F,block_n", [
        (1, 8, 3, 256),      # single sub-slot, tiny shapes
        (3, 37, 5, 16),      # N not a multiple of block_n → grid padding
        (6, 64, 8, 64),      # exact tiling
        (4, 5, 1, 2),        # single filter lane
    ])
    def test_bit_exact_vs_scan(self, S, N, F, block_n):
        x0, dep, a = _fold_inputs(jax.random.PRNGKey(S * 1000 + N), S, N, F)
        out = stream_fold_pallas(x0, dep, a, block_n=block_n)
        want = ref.stream_fold_ref(x0, dep, a)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_bit_exact_under_jit(self):
        x0, dep, a = _fold_inputs(jax.random.PRNGKey(0), 4, 50, 8)
        out = jax.jit(lambda *t: stream_fold_pallas(*t, block_n=32))(
            x0, dep, a)
        want = ref.stream_fold_ref(x0, dep, a)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_zero_deposits_pure_gap_decay(self):
        """An all-empty chunk is S multiplies by the decay: exactly the
        scan's answer, and (to float tolerance) x0·a^S."""
        S, N, F = 5, 20, 6
        x0, _, a = _fold_inputs(jax.random.PRNGKey(1), S, N, F)
        dep = jnp.zeros((S, N, F))
        out = stream_fold_pallas(x0, dep, a, block_n=8)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.stream_fold_ref(x0, dep, a)))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(x0 * a ** S), rtol=1e-6)

    def test_mac_variant_close(self):
        S, N, K, F = 3, 40, 18, 8
        key = jax.random.PRNGKey(2)
        ks = jax.random.split(key, 4)
        x0 = jax.random.normal(ks[0], (N, F)) * 0.05
        patches = jax.random.poisson(ks[1], 0.4, (S, N, K)).astype(
            jnp.float32)
        w = jax.random.normal(ks[2], (K, F)) * 0.1
        a = jnp.exp(-jax.random.uniform(ks[3], (F,)))
        out = stream_fold_mac_pallas(x0, patches, w, a, dv_unit=0.01,
                                     block_n=16)
        want = ref.stream_fold_mac_ref(x0, patches, w, a, dv_unit=0.01)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# serving-shaped wrapper (ops.fold_chunk)
# ---------------------------------------------------------------------------

def _chunk_inputs(key, B, S, hw, F, k=3):
    ks = jax.random.split(key, 4)
    frames = jax.random.poisson(ks[0], 0.3, (B, S, hw, hw, 2)).astype(
        jnp.float32)
    w_q = jax.random.normal(ks[1], (k, k, 2, F)) * 0.1
    a = jnp.exp(-jax.random.uniform(ks[2], (F,)))
    return frames, w_q, a, ks[3]


def _scan_fold(x, frames, w_q, a, stride, dv_unit):
    def sub(x, ev):
        return x * a + _conv(ev, w_q, stride) * dv_unit, None
    x, _ = lax.scan(sub, x, jnp.moveaxis(frames, 1, 0))
    return x


class TestFoldChunk:
    @pytest.mark.parametrize("stride", [1, 2])
    def test_deposit_bit_exact_vs_scan(self, stride):
        B, S, F = 3, 4, 8
        frames, w_q, a, kx = _chunk_inputs(jax.random.PRNGKey(3), B, S,
                                           HW, F)
        ho = HW // stride
        x0 = jax.random.normal(kx, (B, ho, ho, F)) * 0.05
        out = ops.fold_chunk(x0, frames, w_q, a, stride=stride,
                             dv_unit=0.01)
        want = _scan_fold(x0, frames, w_q, a, stride, 0.01)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_empty_chunk_gap_decay(self):
        B, S, F = 2, 6, 8
        _, w_q, a, kx = _chunk_inputs(jax.random.PRNGKey(4), B, S, HW, F)
        frames = jnp.zeros((B, S, HW, HW, 2))
        x0 = jax.random.normal(kx, (B, HW, HW, F)) * 0.05
        out = ops.fold_chunk(x0, frames, w_q, a, stride=1, dv_unit=0.01)
        want = _scan_fold(x0, frames, w_q, a, 1, 0.01)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_mac_close_to_deposit(self):
        B, S, F = 2, 3, 8
        frames, w_q, a, kx = _chunk_inputs(jax.random.PRNGKey(5), B, S,
                                           HW, F)
        x0 = jax.random.normal(kx, (B, HW, HW, F)) * 0.05
        dep = ops.fold_chunk(x0, frames, w_q, a, stride=1, dv_unit=0.01)
        mac = ops.fold_chunk(x0, frames, w_q, a, stride=1, dv_unit=0.01,
                             mode="mac")
        np.testing.assert_allclose(np.asarray(mac), np.asarray(dep),
                                   rtol=1e-5, atol=1e-7)

    def test_unknown_mode_raises(self):
        frames, w_q, a, kx = _chunk_inputs(jax.random.PRNGKey(6), 1, 2,
                                           HW, 8)
        x0 = jnp.zeros((1, HW, HW, 8))
        with pytest.raises(ValueError, match="unknown stream_fold mode"):
            ops.fold_chunk(x0, frames, w_q, a, stride=1, dv_unit=0.01,
                           mode="conv")


# ---------------------------------------------------------------------------
# accumulator wiring (use_kernel switch) + offline telescope
# ---------------------------------------------------------------------------

def _deployment(circuit, t_intg_ms):
    from repro.core.codesign import P2MModelConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=t_intg_ms,
                      leak=LeakageConfig(circuit=circuit)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(HW, HW),
                                  fc_hidden=32, n_classes=5,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)
    return deploy_mod.fresh_deployment(model, seed=0)


class TestAccumulatorWiring:
    def test_fold_bit_exact_and_inactive_lanes_kept(self):
        """make_stream_fns(use_kernel=True).fold ≡ the scan fold bitwise,
        and inactive (capacity-padding) lanes keep their old state on
        both paths."""
        dep = _deployment(CircuitConfig.NULLIFIED, 250.0)
        n_sub = dep.model_cfg.p2m.n_sub
        capacity = 3
        fns_scan = accumulator.make_stream_fns(dep, capacity=capacity,
                                               chunk_slots=n_sub)
        fns_kern = accumulator.make_stream_fns(dep, capacity=capacity,
                                               chunk_slots=n_sub,
                                               use_kernel=True)
        key = jax.random.PRNGKey(7)
        frames = jax.random.poisson(key, 0.3,
                                    (capacity, n_sub, HW, HW, 2)).astype(
                                        jnp.float32)
        state = fns_scan.init_state()
        state["x"] = jax.random.normal(jax.random.fold_in(key, 1),
                                       state["x"].shape) * 0.05
        active = jnp.asarray([True, False, True])
        s_scan = fns_scan.fold(dict(state), frames, active)
        s_kern = fns_kern.fold(dict(state), frames, active)
        np.testing.assert_array_equal(np.asarray(s_kern["x"]),
                                      np.asarray(s_scan["x"]))
        # the masked (inactive) lane is untouched on both paths
        np.testing.assert_array_equal(np.asarray(s_kern["x"][1]),
                                      np.asarray(state["x"][1]))

    @pytest.mark.parametrize("circuit", [CircuitConfig.BASIC,
                                         CircuitConfig.NULLIFIED])
    @pytest.mark.parametrize("t_intg_ms", [100.0, 250.0])
    def test_telescope_matches_offline_curvefit(self, circuit, t_intg_ms):
        """Driving one coarse window through the KERNEL fold + readout
        reproduces the offline curve-fit forward: spike maps bit-equal,
        logits to 1e-5 — the telescoping identity survives the fusion,
        across 2 T_INTG × 2 circuits."""
        dep = _deployment(circuit, t_intg_ms)
        n_sub = dep.model_cfg.p2m.n_sub
        group = dep.model_cfg.coarsen_group()
        n_slots = group                       # exactly one coarse window
        frames = jax.random.poisson(
            jax.random.PRNGKey(int(t_intg_ms)), 0.3,
            (n_slots, n_sub, HW, HW, 2)).astype(jnp.float32)
        off = deploy_mod.offline_forward(dep, frames[None])

        fns = accumulator.make_stream_fns(dep, capacity=2,
                                          chunk_slots=n_sub,
                                          use_kernel=True)
        state = fns.init_state()
        active = jnp.asarray([True, False])
        spikes = []
        for t in range(n_slots):
            fr = jnp.concatenate(
                [frames[t][None], jnp.zeros((1, n_sub, HW, HW, 2))])
            state = fns.fold(state, fr, active)
            cm = jnp.asarray([(t + 1) % group == 0, False])
            state, out = fns.readout(state, active, cm)
            spikes.append(np.asarray(out["spikes"][0]))
        np.testing.assert_array_equal(np.stack(spikes),
                                      np.asarray(off["spikes"][0]))
        logits = np.asarray(state["logits"][0]) / int(state["n_coarse"][0])
        np.testing.assert_allclose(logits, np.asarray(off["logits"][0]),
                                   rtol=1e-5, atol=1e-6)
