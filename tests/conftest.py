"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


def assert_finite(tree, msg=""):
    import numpy as np
    for path, leaf in _paths(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.isfinite(arr).all(), f"non-finite at {path} {msg}"


def _paths(tree):
    from repro.utils import tree_paths
    return tree_paths(tree)
