"""Mesh-sharded lane-parallel serving (repro.stream.shard +
repro.serve.slots.ShardedSlots): sharded-vs-single-device serving parity
(via a subprocess with 8 forced host devices, like test_sweep_shard.py)
plus in-process unit coverage of the lane executor and the per-shard slot
bookkeeping.

The parity bar is EXACT equality — every lane's serving forward is
independent of its neighbours (no cross-lane reduction), so shard_map
partitioning must not change a single bit of any prediction, logit
vector, admission ledger entry, or spike count, for any device count,
padded or not, paced or unpaced, prefetching or inline.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.sweep_exec import MeshExecutor  # noqa: E402
from repro.serve.slots import ShardedSlots  # noqa: E402
from repro.stream.shard import (LANE_AXIS, LaneExecutor,  # noqa: E402
                                make_lane_executor)

REPO = Path(__file__).resolve().parents[1]


class TestLaneExecutor:
    def test_default_is_single_device(self):
        ex = make_lane_executor(None)
        assert ex.devices == 1 and not ex.is_sharded
        assert ex.axis == LANE_AXIS

    def test_is_a_mesh_executor(self):
        """One executor family: the lane executor reuses the sweep
        engine's mesh/padding/spec machinery wholesale."""
        assert issubclass(LaneExecutor, MeshExecutor)
        assert LaneExecutor(devices=1).padded_size(3) == 3
        assert LaneExecutor(devices=4).padded_size(3) == 4
        assert LaneExecutor(devices=4).padded_size(8) == 8

    def test_validates_devices_eagerly(self):
        """A bad --devices must fail at construction, before any stream
        is opened."""
        if jax.device_count() >= 4:
            assert make_lane_executor(4).devices == 4
        else:
            with pytest.raises(ValueError, match="force_host_platform"):
                make_lane_executor(4)

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            LaneExecutor(devices=0)

    def test_single_device_shard_is_identity(self):
        ex = LaneExecutor(devices=1)
        fn = lambda x: x + 1  # noqa: E731
        assert ex.shard(fn, in_specs=(None,), out_specs=None) is fn


class TestShardedSlots:
    def test_degenerates_to_slot_manager(self):
        s = ShardedSlots(4)
        assert (s.devices, s.padded_capacity, s.lanes_per_shard) == (1, 4, 4)
        assert s.admit("a") == 0 and s.admit("b") == 1
        assert s.active_mask() == [True, True, False, False]
        assert s.release(0) == "a"
        assert s.admit("c") == 0          # lowest free lane again

    def test_admission_order_matches_single_manager(self):
        """Shard-major scan → lowest free GLOBAL lane: placement is
        identical to a devices=1 SlotManager, which is what makes sharded
        serving replay-identical."""
        s = ShardedSlots(4, devices=2)
        assert [s.admit(i) for i in "abcd"] == [0, 1, 2, 3]
        assert s.admit("e") is None       # full
        s.release(1)
        s.release(2)
        assert s.admit("e") == 1          # lowest freed, shard 0
        assert s.admit("f") == 2          # then shard 1

    def test_padding_lanes_never_admitted(self):
        s = ShardedSlots(3, devices=2)    # pads 3 -> 4
        assert s.padded_capacity == 4 and s.lanes_per_shard == 2
        assert [s.admit(i) for i in "abc"] == [0, 1, 2]
        assert s.admit("d") is None       # lane 3 is padding
        assert s.active_mask() == [True, True, True, False]
        with pytest.raises(ValueError, match="padding"):
            s.release(3)

    def test_pure_padding_shard(self):
        s = ShardedSlots(2, devices=4)    # shards 2,3 hold no real lane
        assert s.padded_capacity == 4 and s.lanes_per_shard == 1
        assert [s.admit(i) for i in "ab"] == [0, 1]
        assert s.admit("c") is None
        assert s.per_shard_occupied() == [1, 1, 0, 0]
        with pytest.raises(ValueError, match="padding"):
            s.release(2)

    def test_shard_of_and_occupied_order(self):
        s = ShardedSlots(6, devices=3)
        assert [s.shard_of(i) for i in range(6)] == [0, 0, 1, 1, 2, 2]
        for item in "abcdef":
            s.admit(item)
        s.release(1)
        assert [lane for lane, _ in s.occupied()] == [0, 2, 3, 4, 5]
        assert s.n_occupied == 5 and s.n_free == 1
        with pytest.raises(ValueError, match="outside"):
            s.shard_of(6)

    def test_counters_and_flags(self):
        s = ShardedSlots(2, devices=2)
        assert s.is_empty() and not s.is_full()
        s.admit("a")
        s.admit("b")
        assert s.is_full() and not s.is_empty()
        assert s.capacity == 2

    def test_rejects_bad_args(self):
        with pytest.raises(ValueError, match="capacity"):
            ShardedSlots(0)
        with pytest.raises(ValueError, match="devices"):
            ShardedSlots(2, devices=0)


def _tiny_serve(devices, capacity=4, n_streams=6, paced=False,
                prefetch=True, bin_workers=None):
    from repro.core.codesign import P2MModelConfig
    from repro.core.leakage import CircuitConfig, LeakageConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig
    from repro.data import sources
    from repro.stream import deploy as deploy_mod
    from repro.stream.engine import StreamEngine

    hw = 16
    src = sources.resolve_dataset("synthetic-gesture", hw=hw,
                                  duration_ms=400.0)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=100.0,
                      leak=LeakageConfig(circuit=CircuitConfig.BASIC)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(hw, hw),
                                  fc_hidden=32, n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=200.0)
    dep = deploy_mod.fresh_deployment(model, seed=0)
    engine = StreamEngine(dep, capacity=capacity, prefetch=prefetch,
                          executor=make_lane_executor(devices),
                          bin_workers=bin_workers)
    return engine.serve(src, n_streams, seed=0, paced=paced)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device (run under XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
class TestShardedServing:
    """In-process sharded serving — active under the CI multi-device
    step; the full padded/paced/prefetch matrix lives in the subprocess
    test below."""

    def test_sharded_serving_bit_identical(self):
        n_dev = min(2, jax.device_count())
        base = _tiny_serve(devices=None)
        got = _tiny_serve(devices=n_dev)
        key = lambda r: r.stream_id  # noqa: E731
        for a, b in zip(sorted(base.results, key=key),
                        sorted(got.results, key=key)):
            assert a.prediction == b.prediction
            assert a.n_events == b.n_events
            assert a.admitted_window == b.admitted_window
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))
        assert got.total_layer1_spikes == base.total_layer1_spikes
        art = got.to_artifact()
        assert art["sharding"]["devices"] == n_dev
        assert sum(art["sharding"]["per_shard_admitted"]) == got.n_admitted
        assert art["throughput"]["events_per_s_per_device"] * n_dev == \
            pytest.approx(art["throughput"]["events_per_s"])


_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core.codesign import P2MModelConfig
    from repro.core.leakage import CircuitConfig, LeakageConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig
    from repro.data import sources
    from repro.stream import deploy as deploy_mod
    from repro.stream.engine import StreamEngine
    from repro.stream.shard import make_lane_executor

    assert jax.device_count() == 8, jax.device_count()
    hw = 16
    src = sources.resolve_dataset("synthetic-gesture", hw=hw,
                                  duration_ms=400.0)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=100.0,
                      leak=LeakageConfig(circuit=CircuitConfig.BASIC)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(hw, hw),
                                  fc_hidden=32, n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=200.0)
    dep = deploy_mod.fresh_deployment(model, seed=0)

    def serve(capacity, devices):
        eng = StreamEngine(dep, capacity=capacity,
                           executor=make_lane_executor(devices))
        return eng.serve(src, 6, seed=0)

    def assert_same(a_rep, b_rep, tag):
        key = lambda r: r.stream_id
        assert len(a_rep.results) == len(b_rep.results), tag
        for a, b in zip(sorted(a_rep.results, key=key),
                        sorted(b_rep.results, key=key)):
            assert a.label == b.label, tag
            assert a.prediction == b.prediction, (tag, a.stream_id)
            assert a.n_events == b.n_events, tag
            assert a.n_readouts == b.n_readouts, tag
            assert a.offered_window == b.offered_window, tag
            assert a.admitted_window == b.admitted_window, tag
            assert a.finished_window == b.finished_window, tag
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))
        for k in ("n_offered", "n_admitted", "n_shed", "n_deferred",
                  "total_events", "total_readouts", "total_layer1_spikes"):
            assert getattr(a_rep, k) == getattr(b_rep, k), (tag, k)
        print(tag, "bitexact")

    # capacity 4: divisible (2, 4) and padded (8 -> padded_capacity 8
    # with 4 padding lanes); capacity 3 over 2 devices pads 3 -> 4
    base4 = serve(4, None)
    for dev in (2, 4, 8):
        assert_same(base4, serve(4, dev), f"c4_d{dev}")
    assert_same(serve(3, None), serve(3, 2), "c3_d2_padded")

    # paced, prefetch off, and multi-worker binning on the sharded path
    eng_w = StreamEngine(dep, capacity=4, executor=make_lane_executor(2))
    eng_w.serve(src, 4, seed=0)                       # warm the jits
    base_paced = StreamEngine(dep, capacity=4).serve(src, 6, seed=0,
                                                     paced=True)
    assert_same(base_paced, eng_w.serve(src, 6, seed=0, paced=True),
                "c4_d2_paced")
    assert_same(base4, StreamEngine(
        dep, capacity=4, executor=make_lane_executor(2),
        prefetch=False).serve(src, 6, seed=0), "c4_d2_noprefetch")
    assert_same(base4, StreamEngine(
        dep, capacity=4, executor=make_lane_executor(2),
        bin_workers=4).serve(src, 6, seed=0), "c4_d2_w4")
    art = eng_w.serve(src, 6, seed=0).to_artifact()
    assert art["sharding"] == {"devices": 2, "bin_workers": 2,
                               "padded_capacity": 4, "lanes_per_shard": 2,
                               "per_shard_admitted": [4, 2]}
    print("PARITY_PASS")
""")


@pytest.mark.slow
def test_sharded_serving_matches_single_device():
    """Forced 8-host-device run: devices in {2, 4, 8} plus a
    non-divisible capacity (3 lanes over 2 devices), paced, inline
    (prefetch=False), and multi-worker binning — every prediction, logit
    vector, ledger counter, and spike count exactly equal to the
    unsharded serve."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)   # the script must own the device count
    proc = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PARITY_PASS" in proc.stdout
