"""The version-aware serving-stats gate (tools/check_stream_stats.py)
on handcrafted artifacts: v2/v3/v4/v5 records pass, and every class of
corruption the gate exists to catch — ledger imbalance, per-entry sums
that leak streams, streams bound to absent entries, duplicate rows,
inconsistent adaptation blocks, missing per-version keys, unrecognized
schemas — fails with a pointed error. Engine-emitted artifacts are gated in test_streaming.py /
test_registry.py; this file pins the CHECKER itself, so a gate
regression can't silently wave broken artifacts through CI.
"""
import copy
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _gate():
    tools = str(REPO / "tools")
    if tools not in sys.path:
        sys.path.insert(0, tools)
    import check_stream_stats
    return check_stream_stats


def _v4(paced=False):
    """A minimal internally consistent v4 artifact: 3 streams over two
    registry entries, one rejected offer."""
    streams = []
    for sid, (entry, uid, miss) in enumerate(
            [("a", 0, 0), ("b", 1, 1 if paced else 0), ("a", 0, 0)]):
        streams.append({
            "stream_id": sid, "label": sid % 2, "prediction": sid % 2,
            "correct": True, "n_events": 100 + sid, "n_readouts": 4,
            "n_coarse_frames": 2, "offered_window": sid,
            "admitted_window": sid, "finished_window": sid + 4,
            "n_misses": miss, "logits": [0.1, 0.9],
            "entry": entry, "entry_uid": uid})
    n_miss = sum(s["n_misses"] for s in streams)
    return {
        "schema": "p2m-stream-serving/v4",
        "deployed": {"label": "x", "protocol": "frozen"},
        "n_streams": 3, "capacity": 2, "chunks_per_window": 4,
        "t_intg_ms": 100.0, "accuracy": 1.0, "paced": paced,
        "admission": {"offered_rate": None, "max_pending": 4,
                      "n_offered": 4, "n_admitted": 3, "n_shed": 0,
                      "n_rejected": 1, "n_deferred": 1,
                      "max_open_streams": 2},
        "deadlines": {"n_deadlines": 12 if paced else 0,
                      "n_misses": n_miss,
                      "miss_rate": n_miss / 12 if paced else 0.0,
                      "margin_ms": {"p50": 50.0, "p90": 20.0, "p99": 5.0,
                                    "max": 80.0},
                      "histogram": {"<=0ms": n_miss}},
        "streams": streams,
        "latency_ms": {"readout_p50": 1.0, "readout_p99": 2.0,
                       "readout_mean": 1.2, "fold_p50": 0.5,
                       "fold_p99": 0.9},
        "throughput": {"wall_s": 1.5, "events_per_s": 200.0,
                       "events_per_s_per_device": 100.0,
                       "readouts_per_s": 8.0, "streams_per_s": 2.0},
        "sharding": {"devices": 2, "bin_workers": 2, "padded_capacity": 2,
                     "lanes_per_shard": 1, "per_shard_admitted": [2, 1]},
        "registry": {
            "compat": "deadbeef0123", "max_entries": 3,
            "entries": [
                {"name": "a", "uid": 0, "n_admitted": 2, "n_finished": 2,
                 "n_correct": 2, "n_misses": 0, "n_events": 203,
                 "n_readouts": 8, "accuracy": 1.0, "events_per_s": 135.0},
                {"name": "b", "uid": 1, "n_admitted": 1, "n_finished": 1,
                 "n_correct": 1, "n_misses": n_miss, "n_events": 101,
                 "n_readouts": 4, "accuracy": 1.0, "events_per_s": 67.0},
            ]},
    }


def _v3():
    art = _v4()
    art["schema"] = "p2m-stream-serving/v3"
    del art["registry"]
    del art["admission"]["n_rejected"]
    art["admission"]["n_offered"] = 3          # no rejected leg in v3
    for s in art["streams"]:
        del s["entry"], s["entry_uid"]
    return art


def _v2():
    art = _v3()
    art["schema"] = "p2m-stream-serving/v2"
    del art["sharding"]
    del art["throughput"]["events_per_s_per_device"]
    return art


def _v5(enabled=True, paced=False):
    art = _v4(paced=paced)
    art["schema"] = "p2m-stream-serving/v5"
    if enabled:
        art["adaptation"] = {
            "enabled": True, "rule": "surrogate", "lr_w": 0.005,
            "lr_theta": 0.0, "n_updates": 7,
            "accuracy_pre": 0.5, "accuracy_post": 1.0,
            "lanes": [
                {"lane": 0, "n_updates": 4, "dw_norm": 0.12,
                 "dtheta": 0.0},
                {"lane": 1, "n_updates": 3, "dw_norm": 0.07,
                 "dtheta": -0.002},
            ]}
    else:
        art["adaptation"] = {"enabled": False, "rule": None, "lr_w": 0.0,
                             "lr_theta": 0.0, "n_updates": 0,
                             "accuracy_pre": None, "accuracy_post": None,
                             "lanes": []}
    return art


@pytest.fixture()
def gate():
    return _gate()


class TestVersions:
    def test_v4_passes(self, gate):
        assert gate.check(_v4()) == []
        assert gate.check(_v4(paced=True), paced=True,
                          max_miss_rate=50.0) == []
        assert gate.schema_version(_v4()) == 4

    def test_v3_passes(self, gate):
        assert gate.check(_v3()) == []
        assert gate.schema_version(_v3()) == 3

    def test_v2_passes(self, gate):
        assert gate.check(_v2()) == []
        assert gate.schema_version(_v2()) == 2

    @pytest.mark.parametrize("schema", ["p2m-stream-serving/v1",
                                        "p2m-stream-serving/v99",
                                        "p2m-stream-serving/vx",
                                        "something-else", None, 4])
    def test_unrecognized_schema_rejected(self, gate, schema):
        art = _v4()
        art["schema"] = schema
        errs = gate.check(art)
        assert len(errs) == 1 and "unrecognized schema" in errs[0]
        assert gate.schema_version(art) is None

    def test_older_versions_do_not_require_newer_keys(self, gate):
        """A v2 artifact must NOT be failed for lacking sharding or
        registry blocks — the gate is version-aware, not
        latest-version-only."""
        art = _v2()
        assert "sharding" not in art and "registry" not in art
        assert gate.check(art) == []

    def test_v4_requires_new_blocks(self, gate):
        for key in ("registry", "sharding"):
            art = _v4()
            del art[key]
            assert any(key in e for e in gate.check(art)), key
        art = _v4()
        del art["admission"]["n_rejected"]
        assert any("n_rejected" in e for e in gate.check(art))
        art = _v4()
        del art["streams"][1]["entry"]
        assert any("entry" in e for e in gate.check(art))


class TestAdaptationBlock:
    def test_v5_passes(self, gate):
        assert gate.check(_v5()) == []
        assert gate.check(_v5(enabled=False)) == []
        assert gate.schema_version(_v5()) == 5

    def test_v5_requires_adaptation_block(self, gate):
        art = _v5()
        del art["adaptation"]
        assert any("adaptation" in e for e in gate.check(art))
        art = _v5()
        del art["adaptation"]["rule"]
        assert any("adaptation missing" in e for e in gate.check(art))

    def test_v4_does_not_require_adaptation(self, gate):
        """Old artifacts predate the block — the gate stays
        version-aware, not latest-version-only."""
        art = _v4()
        assert "adaptation" not in art
        assert gate.check(art) == []

    def test_disabled_block_must_be_empty(self, gate):
        art = _v5(enabled=False)
        art["adaptation"]["n_updates"] = 3
        assert any("disabled adaptation block carries updates" in e
                   for e in gate.check(art))
        art = _v5(enabled=False)
        art["adaptation"]["lanes"] = [
            {"lane": 0, "n_updates": 1, "dw_norm": 0.1, "dtheta": 0.0}]
        assert any("disabled adaptation block" in e
                   for e in gate.check(art))

    def test_unknown_rule_rejected(self, gate):
        art = _v5()
        art["adaptation"]["rule"] = "hebbian"
        assert any("adaptation.rule" in e for e in gate.check(art))

    def test_lane_updates_must_sum_to_total(self, gate):
        art = _v5()
        art["adaptation"]["n_updates"] = 99
        assert any("per-lane update counts sum" in e
                   for e in gate.check(art))

    def test_lane_row_consistency(self, gate):
        art = _v5()
        art["adaptation"]["lanes"][0]["dw_norm"] = -0.1
        assert any("dw_norm" in e for e in gate.check(art))
        art = _v5()
        art["adaptation"]["lanes"].append(
            dict(art["adaptation"]["lanes"][0]))
        assert any("duplicate lane" in e for e in gate.check(art))
        art = _v5()
        art["adaptation"]["lanes"][0]["n_updates"] = 0
        art["adaptation"]["n_updates"] = 3
        assert any("only lanes that updated" in e for e in gate.check(art))
        art = _v5()
        del art["adaptation"]["lanes"][1]["dw_norm"]
        assert any("lanes[1] missing" in e for e in gate.check(art))

    def test_accuracy_split_ranges(self, gate):
        art = _v5()
        art["adaptation"]["accuracy_post"] = 1.5
        assert any("accuracy_post out of range" in e
                   for e in gate.check(art))
        # None is legal (too few streams for a split)
        art = _v5()
        art["adaptation"]["accuracy_pre"] = None
        assert gate.check(art) == []


class TestLedgers:
    def test_admission_ledger_imbalance(self, gate):
        art = _v4()
        art["admission"]["n_offered"] = 99
        assert any("ledger does not balance" in e for e in gate.check(art))

    def test_rejected_counts_in_v4_ledger(self, gate):
        """offered = admitted + shed + REJECTED: dropping the rejected
        leg from the sum must unbalance the ledger."""
        art = _v4()
        art["admission"]["n_rejected"] = 0
        errs = gate.check(art)
        assert any("ledger does not balance" in e for e in errs)

    def test_stream_count_mismatch(self, gate):
        assert any("expected 7 streams" in e
                   for e in gate.check(_v4(), n_streams=7))
        art = _v4()
        art["n_streams"] = 2
        assert any("n_streams" in e for e in gate.check(art))

    def test_per_entry_sums_must_match_fleet(self, gate):
        for field in ("n_admitted", "n_finished", "n_misses"):
            art = _v4()
            art["registry"]["entries"][0][field] += 1
            errs = gate.check(art)
            assert any(f"per-entry {field}" in e for e in errs), (field,
                                                                  errs)

    def test_stream_bound_to_absent_entry(self, gate):
        art = _v4()
        art["streams"][2]["entry"] = "ghost"
        assert any("absent from registry" in e for e in gate.check(art))
        # same name but a different uid (stale hot-swap binding) is
        # ALSO absent — uid is part of the binding
        art = _v4()
        art["streams"][2]["entry_uid"] = 9
        assert any("absent from registry" in e for e in gate.check(art))

    def test_entry_finished_vs_bound_streams(self, gate):
        art = _v4()
        # shuffle one stream from a to b without touching the rows
        art["streams"][2]["entry"] = "b"
        art["streams"][2]["entry_uid"] = 1
        errs = gate.check(art)
        assert any("streams bound to it" in e for e in errs)

    def test_duplicate_entry_rows(self, gate):
        art = _v4()
        art["registry"]["entries"].append(
            copy.deepcopy(art["registry"]["entries"][0]))
        assert any("duplicate row" in e for e in gate.check(art))

    def test_entry_row_ranges(self, gate):
        art = _v4()
        art["registry"]["entries"][0]["accuracy"] = 1.5
        assert any("accuracy out of range" in e for e in gate.check(art))
        art = _v4()
        art["registry"]["entries"][0]["n_correct"] = 99
        assert any("n_correct" in e for e in gate.check(art))

    def test_registry_scalars(self, gate):
        art = _v4()
        art["registry"]["compat"] = ""
        assert any("compat" in e for e in gate.check(art))
        art = _v4()
        art["registry"]["max_entries"] = 0
        assert any("max_entries" in e for e in gate.check(art))
        art = _v4()
        del art["registry"]["entries"][0]["uid"]
        assert any("entries[0] missing" in e for e in gate.check(art))


class TestSharedChecks:
    def test_sharding_checks_still_apply(self, gate):
        art = _v4()
        art["sharding"]["per_shard_admitted"] = [1, 1]
        assert any("per-shard admits" in e for e in gate.check(art))
        art = _v4()
        art["sharding"]["lanes_per_shard"] = 5
        assert any("geometry" in e for e in gate.check(art))

    def test_paced_flags(self, gate):
        assert any("not a paced run" in e
                   for e in gate.check(_v4(), paced=True))
        art = _v4(paced=True)
        errs = gate.check(art, max_miss_rate=1.0)
        assert any("miss rate" in e for e in errs)

    def test_unpaced_must_not_carry_deadlines(self, gate):
        art = _v4()
        art["deadlines"]["n_deadlines"] = 5
        assert any("unpaced run carries" in e for e in gate.check(art))

    def test_stream_counters(self, gate):
        art = _v4()
        art["streams"][0]["n_events"] = 0
        assert any("empty serving counters" in e for e in gate.check(art))
        art = _v4()
        art["streams"][0]["n_misses"] = 99
        assert any("miss counter out of range" in e
                   for e in gate.check(art))

    def test_malformed_inputs_error_not_crash(self, gate):
        """Structurally broken artifacts must come back as error lists,
        never exceptions."""
        assert gate.check({}) != []
        assert gate.check({"schema": "p2m-stream-serving/v4"}) != []
        art = _v4()
        art["streams"] = [{}]
        assert any("stream[0] missing" in e for e in gate.check(art))
        art = _v4()
        art["registry"] = {}
        assert any("registry missing" in e for e in gate.check(art))
        art = _v4()
        art["deadlines"] = {}
        assert any("deadlines missing" in e for e in gate.check(art))


class TestCli:
    def _run(self, tmp_path, art, *flags):
        p = tmp_path / "art.json"
        p.write_text(json.dumps(art))
        return subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_stream_stats.py"),
             str(p), *flags], capture_output=True, text=True, timeout=120)

    def test_cli_ok_lines(self, tmp_path):
        for art, note in ((_v5(), "adapting (surrogate): 7 updates"),
                          (_v4(), "registry entries"), (_v3(), "v3"),
                          (_v2(), "v2")):
            proc = self._run(tmp_path, art, "--streams", "3")
            assert proc.returncode == 0, proc.stderr
            assert "OK" in proc.stdout and note in proc.stdout

    def test_cli_fails_on_corruption(self, tmp_path):
        art = _v4()
        art["registry"]["entries"][0]["n_admitted"] = 9
        proc = self._run(tmp_path, art)
        assert proc.returncode == 1
        assert "per-entry n_admitted" in proc.stderr
