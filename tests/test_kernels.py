"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# p2m_conv
# ---------------------------------------------------------------------------


class TestP2MConvKernel:
    @pytest.mark.parametrize("hw,cin,cout,t,nsub", [
        (8, 2, 4, 2, 3),
        (12, 2, 8, 1, 4),
        (16, 3, 5, 2, 2),
    ])
    def test_kernel_matches_scan_oracle(self, hw, cin, cout, t, nsub):
        from repro.core.p2m_layer import P2MConfig, p2m_init, p2m_forward_scan
        from repro.kernels.p2m_conv import ops

        cfg = P2MConfig(in_channels=cin, out_channels=cout, t_intg_ms=10.0,
                        n_sub=nsub)
        key = jax.random.PRNGKey(42)
        params = p2m_init(key, cfg)
        ev = jax.random.poisson(jax.random.fold_in(key, 1), 0.3,
                                (2, t, nsub, hw, hw, cin)).astype(jnp.float32)
        s_ref, v_ref = p2m_forward_scan(params, ev, cfg)
        s_k, v_k = ops.p2m_conv(params, ev, cfg)
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))

    def test_kernel_matches_jnp_ref(self):
        """ops(use_ref=True) — the pure-jnp ref path — agrees with Pallas."""
        from repro.core.p2m_layer import P2MConfig, p2m_init
        from repro.kernels.p2m_conv import ops

        cfg = P2MConfig(out_channels=6, n_sub=3)
        key = jax.random.PRNGKey(0)
        params = p2m_init(key, cfg)
        ev = jax.random.poisson(key, 0.5, (1, 2, 3, 10, 10, 2)).astype(jnp.float32)
        s_k, v_k = ops.p2m_conv(params, ev, cfg)
        s_r, v_r = ops.p2m_conv(params, ev, cfg, use_ref=True)
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r),
                                   rtol=1e-5, atol=1e-6)

    def test_nonsquare_tile_padding(self):
        """P not a multiple of block_p exercises the pad/crop path."""
        from repro.core.p2m_layer import P2MConfig, p2m_init, p2m_forward_scan
        from repro.kernels.p2m_conv.p2m_conv import p2m_conv_pallas
        from repro.kernels.p2m_conv.ops import _prepare

        cfg = P2MConfig(out_channels=4, n_sub=2)
        params = p2m_init(jax.random.PRNGKey(1), cfg)
        ev = jnp.ones((1, 1, 2, 7, 9, 2))
        patches, w2, v_inf, decay, theta, params2, consts, dims = _prepare(
            params, ev, cfg)
        s, v = p2m_conv_pallas(patches, w2, v_inf, decay, theta,
                               params2["pv_gain"], params2["pv_offset"],
                               block_p=16, **consts)
        s_ref, v_ref = p2m_forward_scan(params, ev, cfg)
        B, T, Ho, Wo = dims
        v_cropped = v[:, :B * Ho * Wo].reshape(T, B, Ho, Wo, 4)
        np.testing.assert_allclose(np.asarray(jnp.moveaxis(v_cropped, 0, 1)),
                                   np.asarray(v_ref), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# lif
# ---------------------------------------------------------------------------


class TestLIFKernel:
    @pytest.mark.parametrize("t,n", [(4, 32), (16, 100), (7, 513)])
    @pytest.mark.parametrize("soft", [True, False])
    def test_matches_ref(self, t, n, soft):
        from repro.kernels.lif.lif import lif_pallas
        from repro.kernels.lif.ref import lif_ref

        x = jax.random.normal(jax.random.PRNGKey(0), (t, n)) * 2.0
        out_k = lif_pallas(x, soft_reset=soft, block_n=64)
        out_r = lif_ref(x, soft_reset=soft)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-6)

    def test_matches_snn_lif(self):
        """Kernel agrees with the training-path LIF in core/snn.py."""
        from repro.core.snn import LIFConfig, lif_over_time
        from repro.kernels.lif.lif import lif_pallas

        x = jax.random.normal(jax.random.PRNGKey(3), (6, 4, 10)) * 1.5
        ref = lif_over_time(x, LIFConfig())
        k = lif_pallas(x.reshape(6, 40)).reshape(6, 4, 10)
        np.testing.assert_allclose(np.asarray(k), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv,d,causal", [
        (64, 64, 16, True),
        (32, 128, 32, False),
        (100, 100, 16, True),     # non-multiple of block → pad path
        (1, 96, 16, False),       # decode-like
    ])
    def test_matches_ref(self, sq, skv, d, causal):
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas)
        from repro.kernels.flash_attention.ref import attention_ref

        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (2, sq, d))
        kk = jax.random.normal(jax.random.fold_in(k, 1), (2, skv, d))
        v = jax.random.normal(jax.random.fold_in(k, 2), (2, skv, d))
        if causal and sq != skv:
            pytest.skip("causal requires sq == skv in this ref")
        o_k = flash_attention_pallas(q, kk, v, causal=causal, block_q=32,
                                     block_k=32)
        o_r = attention_ref(q, kk, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=2e-3, atol=2e-3)

    def test_kv_len_masking(self):
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas)
        from repro.kernels.flash_attention.ref import attention_ref

        k = jax.random.PRNGKey(1)
        q = jax.random.normal(k, (1, 1, 16))
        kk = jax.random.normal(jax.random.fold_in(k, 1), (1, 64, 16))
        v = jax.random.normal(jax.random.fold_in(k, 2), (1, 64, 16))
        o_k = flash_attention_pallas(q, kk, v, causal=False, kv_len=40,
                                     block_k=32)
        o_r = attention_ref(q, kk[:, :40], v[:, :40], causal=False)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------


def _ssd_inputs(key, b, s, h, p, g, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    return x, dt, A, B, C


class TestSSDKernel:
    @pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
        (1, 64, 2, 8, 1, 8, 16),
        (2, 96, 4, 16, 2, 8, 32),
        (1, 50, 2, 8, 2, 4, 16),    # pad path
        (2, 32, 8, 8, 8, 8, 32),    # g == h (no grouping)
    ])
    def test_matches_sequential_ref(self, b, s, h, p, g, n, chunk):
        from repro.kernels.ssd.ref import ssd_ref
        from repro.kernels.ssd.ssd import ssd_pallas

        x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(7), b, s, h, p, g, n)
        y_k, st_k = ssd_pallas(x, dt, A, B, C, chunk=chunk)
        y_r, st_r = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                                   rtol=1e-3, atol=1e-4)

    def test_chunked_jnp_matches_ref(self):
        """nn/ssm.ssd_chunked (the training path) vs the sequential oracle."""
        from repro.kernels.ssd.ref import ssd_ref
        from repro.nn.ssm import ssd_chunked

        x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(9), 2, 64, 4, 8, 2, 8)
        y_c, st_c = ssd_chunked(x, dt, A, B, C, chunk=16)
        y_r, st_r = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                                   rtol=1e-3, atol=1e-4)

    def test_trainable_grad_path(self):
        from repro.kernels.ssd.ops import ssd_trainable

        x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(11), 1, 32, 2, 8, 1, 4)
        def loss(x):
            return jnp.sum(ssd_trainable(x, dt, A, B, C) ** 2)
        g = jax.grad(loss)(x)
        assert g.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(g)))
