"""build_train_step: single-shot vs grad-accum equivalence, donation,
sharded lowering on the host mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.train.steps import build_train_step


def _setup(grad_accum=1, batch=4, seq=32):
    cfg = smoke_variant(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    shape = ShapeConfig("t", "train", seq, batch)
    mesh = make_host_mesh()
    with mesh:
        step, sds, opt = build_train_step(cfg, shape, mesh, lr=1e-3,
                                          grad_accum=grad_accum,
                                          donate=False)
        from repro.models import lm
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        k = jax.random.PRNGKey(1)
        batch_data = {
            "tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.fold_in(k, 1),
                                         (batch, seq), 0, cfg.vocab_size),
        }
        return mesh, step, params, opt_state, batch_data


def test_grad_accum_matches_single_shot():
    mesh, step1, params, opt_state, batch = _setup(grad_accum=1)
    with mesh:
        p1, o1, m1 = step1(params, opt_state, batch)
    mesh, step2, params, opt_state, batch = _setup(grad_accum=2)
    with mesh:
        p2, o2, m2 = step2(params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # parameters after one update agree to fp32 tolerance
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_loss_decreases_over_steps():
    mesh, step, params, opt_state, batch = _setup()
    with mesh:
        losses = []
        for _ in range(5):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
