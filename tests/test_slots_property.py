"""Property-based invariants for the serving slot managers
(repro.serve.slots.SlotManager / ShardedSlots).

One model-based driver runs random admit / release / refill / swap
sequences against a ShardedSlots and a devices=1 SlotManager side by
side, checking after EVERY operation:

  * no lane is ever double-assigned (an admit only ever returns a lane
    that was free, and every occupied lane holds exactly one item);
  * a padding lane is never admitted, released, swapped, or reported
    active — real lanes are exactly the globals [0, capacity);
  * shard-major placement is identical to devices=1 — the lane index
    returned for every admit, the refill placements, and the full
    active mask match the plain SlotManager lane-for-lane (this is the
    invariant that makes sharded serving replay-identical);
  * counters (n_occupied, n_free, per-shard sums) agree with the model.

The suite runs under hypothesis when installed; a seeded random-walk
fallback drives the same checker otherwise, so the invariants are
always exercised.
"""
import random
from collections import deque

import pytest

from repro.serve.slots import ShardedSlots, SlotManager

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

MAX_EXAMPLES = 25

# op codes for the random walk: (kind, argument)
ADMIT, RELEASE, REFILL, SWAP = range(4)


def _check_invariants(sh, ref, model):
    """``model`` is the oracle dict {lane: item} of what must be live."""
    assert sh.n_occupied == ref.n_occupied == len(model)
    assert sh.n_free == ref.n_free == sh.capacity - len(model)
    assert sh.is_full() == ref.is_full()
    assert sh.is_empty() == ref.is_empty()
    mask = sh.active_mask()
    assert len(mask) == sh.padded_capacity
    assert mask[:sh.capacity] == ref.active_mask()
    # padding lanes are NEVER active
    assert not any(mask[sh.capacity:])
    # each occupied lane holds exactly the modeled item, in lane order
    occ = list(sh.occupied())
    assert occ == sorted(model.items())
    assert list(ref.occupied()) == occ
    assert sum(sh.per_shard_occupied()) == len(model)


def _apply_ops(capacity, devices, ops):
    """Drive both managers through ``ops`` and check invariants after
    every step. ``ops`` is a list of (op_code, int_arg) pairs; arguments
    are reduced modulo whatever the op needs, so any integer sequence is
    a valid walk."""
    ref = SlotManager(capacity)
    sh = ShardedSlots(capacity, devices=devices)
    model = {}
    next_item = 0
    for code, arg in ops:
        if code == ADMIT:
            item = f"s{next_item}"
            next_item += 1
            lane_sh = sh.admit(item)
            lane_ref = ref.admit(item)
            assert lane_sh == lane_ref          # shard-major == devices=1
            if lane_sh is None:
                assert len(model) == capacity   # only full rejects
            else:
                assert lane_sh not in model     # never double-assign
                assert 0 <= lane_sh < capacity  # never a padding lane
                # admit fills the LOWEST free lane
                assert all(lane in model for lane in range(lane_sh))
                model[lane_sh] = item
        elif code == RELEASE:
            if model:
                lane = sorted(model)[arg % len(model)]
                got_sh = sh.release(lane)
                got_ref = ref.release(lane)
                assert got_sh == got_ref == model.pop(lane)
            else:
                with pytest.raises(ValueError):
                    sh.release(arg % capacity)
                with pytest.raises(ValueError):
                    ref.release(arg % capacity)
        elif code == REFILL:
            n = arg % (capacity + 2)
            items = [f"s{next_item + i}" for i in range(n)]
            next_item += n
            placed = ref.refill(deque(items))
            # ShardedSlots has no refill (the engine admits one stream
            # at a time); the equivalence claim is that repeated admits
            # place the SAME items on the SAME lanes.
            for lane, item in placed:
                assert sh.admit(item) == lane
                assert lane not in model and 0 <= lane < capacity
                model[lane] = item
            assert len(placed) == min(n, capacity - (len(model) - len(placed)))
        elif code == SWAP:
            if model:
                lane = sorted(model)[arg % len(model)]
                item = f"s{next_item}"
                next_item += 1
                old_sh = sh.swap(lane, item)
                assert old_sh == model[lane]
                assert ref.swap(lane, item) == old_sh
                model[lane] = item
                # swap never frees the lane
                assert sh.active_mask()[lane]
            else:
                with pytest.raises(ValueError, match="free"):
                    sh.swap(arg % capacity, "x")
                with pytest.raises(ValueError, match="free"):
                    ref.swap(arg % capacity, "x")
        _check_invariants(sh, ref, model)
    return model


def _random_walk(rng, n_ops):
    return [(rng.randrange(4), rng.randrange(1 << 16)) for _ in range(n_ops)]


# ---------------------------------------------------------------------------
# seeded fallback walks — always run, hypothesis or not
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("capacity,devices", [(1, 1), (4, 1), (4, 2),
                                              (3, 2), (5, 4), (2, 4),
                                              (7, 3)])
def test_random_walks_hold_invariants(capacity, devices, seed):
    rng = random.Random(seed * 1000 + capacity * 10 + devices)
    _apply_ops(capacity, devices, _random_walk(rng, 60))


def test_admit_heavy_walk_fills_then_rejects():
    """An admit-only walk fills lanes 0..capacity-1 in order, then every
    further admit returns None on both managers."""
    model = _apply_ops(5, 2, [(ADMIT, 0)] * 8)
    assert sorted(model) == list(range(5))


def test_padding_lane_operations_rejected():
    sh = ShardedSlots(3, devices=2)       # lane 3 is padding
    sh.admit("a")
    for lane in range(3, sh.padded_capacity):
        with pytest.raises(ValueError, match="padding"):
            sh.release(lane)
        with pytest.raises(ValueError, match="padding"):
            sh.swap(lane, "x")
    with pytest.raises(ValueError, match="outside"):
        sh.shard_of(sh.padded_capacity)


def test_swap_is_invisible_to_placement():
    """Swapping a resident lane must not change where the NEXT admit
    lands — the lane never transits through the free state."""
    sh = ShardedSlots(4, devices=2)
    ref = SlotManager(4)
    for item in ("a", "b", "c"):
        assert sh.admit(item) == ref.admit(item)
    assert sh.swap(1, "b2") == ref.swap(1, "b2") == "b"
    assert sh.admit("d") == ref.admit("d") == 3
    assert sh.admit("e") is ref.admit("e") is None


# ---------------------------------------------------------------------------
# hypothesis-driven walks — arbitrary op sequences, minimized on failure
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(capacity=st.integers(1, 9),
           devices=st.integers(1, 5),
           ops=st.lists(st.tuples(st.integers(0, 3),
                                  st.integers(0, 1 << 16)),
                        max_size=80))
    def test_hypothesis_walks_hold_invariants(capacity, devices, ops):
        _apply_ops(capacity, devices, ops)

    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(capacity=st.integers(1, 9), devices=st.integers(1, 5),
           n=st.integers(0, 12))
    def test_hypothesis_refill_matches_admit_loop(capacity, devices, n):
        """refill(queue) on the reference manager and an admit loop on
        the sharded manager place identical items on identical lanes and
        leave identical leftovers."""
        ref = SlotManager(capacity)
        sh = ShardedSlots(capacity, devices=devices)
        q = deque(f"s{i}" for i in range(n))
        placed = ref.refill(q)
        assert len(placed) == min(n, capacity)
        assert len(q) == n - len(placed)
        for lane, item in placed:
            assert sh.admit(item) == lane
        assert sh.active_mask()[:capacity] == ref.active_mask()
