"""Gradient-correctness suite for the unfrozen phase-2 protocol.

Three layers of checks on the differentiable seam the unfrozen protocol
trains through (all on the CPU/interpret-friendly curvefit path):

  * finite-difference validation of ``jax.grad`` through
    ``p2m_forward_curvefit_stacked`` w.r.t. the layer-1 weights, per
    circuit config — including config (a), whose leak linearization
    (v_inf, tau) is itself a function of the kernel;
  * the frozen protocol's layer-1 gradients are EXACTLY zero (the
    ``stop_gradient`` contract the paper's §3 protocol relies on);
  * the grouped (per-config-params) forward matches the shared-params
    stacked forward when every config holds the same weights, and its
    gradients are per-config independent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import codesign, snn
from repro.core import sweep as engine
from repro.core import leakage, p2m_layer
from repro.core.analog import AnalogConfig
from repro.core.codesign import P2MModelConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig, p2m_init
from repro.core.snn import SpikingCNNConfig
from repro.data import events as ev_mod

CIRCUITS = (CircuitConfig.BASIC, CircuitConfig.SWITCH,
            CircuitConfig.NULLIFIED)


def _setup(analog: AnalogConfig | None = None, key: int = 0):
    kw = dict(out_channels=4, t_intg_ms=10.0, n_sub=3)
    if analog is not None:
        kw["analog"] = analog
    cfg = P2MConfig(**kw)
    params = p2m_init(jax.random.PRNGKey(key), cfg)
    ev = jax.random.poisson(jax.random.PRNGKey(key + 1), 0.4,
                            (1, 2, cfg.n_sub, 8, 8, 2)).astype(
                                params["w"].dtype)
    return cfg, params, ev


class TestFiniteDifference:
    """``jax.grad`` through the stacked curvefit forward must match a
    central finite difference of a v_pre readout (rtol ≤ 1e-3).

    Two deliberate choices make FD meaningful: the readout is the
    pre-comparator voltage (the spike comparator is a step function — its
    surrogate gradient is exactly what FD must NOT see), and the weight
    quantizer runs at a very fine step (the straight-through estimator's
    analytic gradient is quantizer-independent, but FD of a coarse
    staircase measures the steps, not the slope). float64 keeps the FD
    truncation/roundoff error far below the tolerance.
    """

    @pytest.mark.parametrize("circuit", CIRCUITS, ids=lambda c: c.value)
    def test_grad_matches_fd_per_circuit(self, circuit):
        with enable_x64():
            cfg, params, ev = _setup(AnalogConfig(weight_levels=1 << 22))
            leak_cfgs = (LeakageConfig(circuit=circuit),)
            kc, kd = jax.random.split(jax.random.PRNGKey(42))

            _, v0 = p2m_layer.p2m_forward_curvefit_stacked(params, ev, cfg,
                                                           leak_cfgs)
            cot = jax.random.normal(kc, v0.shape)

            def scalar(w):
                p = {**params, "w": w}
                _, v = p2m_layer.p2m_forward_curvefit_stacked(p, ev, cfg,
                                                              leak_cfgs)
                return jnp.vdot(v, cot)

            w0 = params["w"]
            g = jax.grad(scalar)(w0)
            assert np.isfinite(np.asarray(g)).all()

            d = jax.random.normal(kd, w0.shape)
            d = d / jnp.linalg.norm(d)
            eps = 1e-3
            fd = (scalar(w0 + eps * d) - scalar(w0 - eps * d)) / (2 * eps)
            analytic = jnp.vdot(g, d)
            assert float(jnp.abs(fd)) > 1e-6, "degenerate FD probe"
            np.testing.assert_allclose(float(analytic), float(fd), rtol=1e-3)

    def test_basic_grad_flows_through_leak_linearization(self):
        """Config (a)'s v_inf/tau depend on the kernel: the gradient must
        differ from one with the leak params detached — i.e. the unfrozen
        protocol really trains through the re-linearized leak."""
        cfg, params, ev = _setup()
        leak_cfgs = (LeakageConfig(circuit=CircuitConfig.BASIC),)
        co = leakage.leak_coeffs(leak_cfgs[0])

        def v_sum(w, detach_leak):
            p = {**params, "w": w}
            w_q = p2m_layer.effective_weights(p, cfg)
            lk = leakage.leak_params_from_coeffs(w_q, co)
            if detach_leak:
                lk = jax.tree.map(jax.lax.stop_gradient, lk)
            return jnp.sum(p2m_layer._curvefit_from_lk(p, ev, cfg, w_q, lk))

        g_full = jax.grad(lambda w: v_sum(w, False))(params["w"])
        g_detached = jax.grad(lambda w: v_sum(w, True))(params["w"])
        assert float(jnp.max(jnp.abs(g_full - g_detached))) > 1e-7


def _mini_model():
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=60.0),
        backbone=SpikingCNNConfig(channels=(8, 8, 8, 8), input_hw=(16, 16),
                                  fc_hidden=16, n_classes=5,
                                  first_layer_external=True),
        coarse_window_ms=120.0)
    data = ev_mod.EventStreamConfig(name="gesture", height=16, width=16,
                                    n_classes=5, duration_ms=240.0)
    return model, data


class TestFrozenProtocolGrads:
    def test_frozen_loss_layer1_grads_exactly_zero(self):
        """The frozen phase-2 loss (stacked layer-1 forward outside the
        gradient, stop_gradient on the coarse spikes) must give EXACTLY
        zero layer-1 gradients — not merely small ones."""
        model, data = _mini_model()
        leak_cfgs = engine.expand_leak_configs(engine.SweepGrid(),
                                               model.p2m.leak)
        G = len(leak_cfgs)
        key = jax.random.PRNGKey(0)
        params, state = codesign.model_init(key, model)
        bb_s = engine._stack_tree(params["backbone"], G)
        state_s = engine._stack_tree(state, G)
        ev, labels = ev_mod.sample_batch(key, data, 2, model.p2m.t_intg_ms,
                                         n_sub=model.p2m.n_sub)

        def frozen_loss(p2m_params):
            coarse_s, _ = engine._layer1_coarse(p2m_params, ev, model,
                                                leak_cfgs)
            coarse_s = jax.lax.stop_gradient(coarse_s)

            def per_cfg(bb_p, st, coarse):
                logits, _, _ = snn.spiking_cnn_apply(
                    bb_p, st, coarse, model.backbone, train=True)
                return snn.cross_entropy(logits, labels)

            return jnp.sum(jax.vmap(per_cfg)(bb_s, state_s, coarse_s))

        g = jax.grad(frozen_loss)(params["p2m"])
        for leaf in jax.tree.leaves(g):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)

    def test_unfrozen_loss_layer1_grads_nonzero_and_finite(self):
        """The unfrozen counterpart (no stop_gradient, per-config leak
        re-linearization) must produce finite, nonzero layer-1 grads for
        every circuit config."""
        model, data = _mini_model()
        leak_cfgs = engine.expand_leak_configs(engine.SweepGrid(),
                                               model.p2m.leak)
        coeffs_s = leakage.stacked_leak_coeffs(leak_cfgs)
        G = len(leak_cfgs)
        key = jax.random.PRNGKey(0)
        params, state = codesign.model_init(key, model)
        bb_s = engine._stack_tree(params["backbone"], G)
        state_s = engine._stack_tree(state, G)
        p2m_s = p2m_layer.stack_p2m_params(params["p2m"], G)
        ev, labels = ev_mod.sample_batch(key, data, 2, model.p2m.t_intg_ms,
                                         n_sub=model.p2m.n_sub)

        def unfrozen_loss(p2m_params_s):
            def per_cfg(p2m_p, bb_p, st, co):
                coarse, _ = engine._layer1_coarse_one(p2m_p, ev, model, co)
                logits, _, _ = snn.spiking_cnn_apply(
                    bb_p, st, coarse, model.backbone, train=True)
                return snn.cross_entropy(logits, labels)

            return jnp.sum(jax.vmap(per_cfg)(p2m_params_s, bb_s, state_s,
                                             coeffs_s))

        g = jax.grad(unfrozen_loss)(p2m_s)
        assert np.isfinite(np.asarray(g["w"])).all()
        for i in range(G):
            assert float(jnp.max(jnp.abs(g["w"][i]))) > 0.0, \
                f"zero layer-1 grad for config {leak_cfgs[i].circuit.value}"


class TestGroupedForward:
    def test_grouped_matches_stacked_with_shared_params(self):
        cfg, params, ev = _setup()
        leak_cfgs = tuple(LeakageConfig(circuit=c) for c in CIRCUITS)
        s0, v0 = p2m_layer.p2m_forward_curvefit_stacked(params, ev, cfg,
                                                        leak_cfgs)
        p_s = p2m_layer.stack_p2m_params(params, len(leak_cfgs))
        s1, v1 = p2m_layer.p2m_forward_curvefit_grouped(p_s, ev, cfg,
                                                        leak_cfgs)
        np.testing.assert_allclose(np.asarray(v0), np.asarray(v1),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))

    def test_grouped_grads_per_config_independent(self):
        """Config g's output depends only on params slice g: the gradient
        of a single config's readout must vanish on every other slice."""
        cfg, params, ev = _setup()
        leak_cfgs = tuple(LeakageConfig(circuit=c) for c in CIRCUITS)
        p_s = p2m_layer.stack_p2m_params(params, len(leak_cfgs))

        def one_cfg_readout(p_s):
            _, v = p2m_layer.p2m_forward_curvefit_grouped(p_s, ev, cfg,
                                                          leak_cfgs)
            return jnp.sum(v[0] ** 2)

        g = jax.grad(one_cfg_readout)(p_s)
        assert float(jnp.max(jnp.abs(g["w"][0]))) > 0.0
        np.testing.assert_array_equal(np.asarray(g["w"][1:]), 0.0)

    def test_grouped_leak_params_match_per_config(self):
        w_s = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 3, 2, 6))
        cfgs = leakage.paper_circuits()
        lk = leakage.grouped_leak_params(w_s, cfgs)
        for i, c in enumerate(cfgs):
            ref = leakage.kernel_leak_params(w_s[i], c)
            np.testing.assert_array_equal(np.asarray(lk.v_inf[i]),
                                          np.asarray(ref.v_inf))
            np.testing.assert_array_equal(np.asarray(lk.tau_ms[i]),
                                          np.asarray(ref.tau_ms))


class TestJointOptimizerLRSplit:
    """The unfrozen joint update's per-group optimizer: lr_p2m=None must be
    a pure refactor of the single-optimizer update, and a split LR must
    move ONLY the layer-1 leaf group differently."""

    def _joint(self):
        from repro.optim import adamw
        key = jax.random.PRNGKey(7)
        joint = {"p2m": {"w": jax.random.normal(key, (3, 3, 2, 4))},
                 "backbone": {"w": jax.random.normal(
                     jax.random.fold_in(key, 1), (8, 8))}}
        grads = jax.tree.map(jnp.ones_like, joint)
        return adamw, joint, grads

    def test_equal_lrs_match_single_optimizer(self):
        adamw, joint, grads = self._joint()
        single = adamw(2e-3)
        split = engine.joint_optimizer(adamw(2e-3), adamw(2e-3))
        up_1, _ = single.update(grads, single.init(joint), joint)
        up_2, _ = split.update(grads, split.init(joint), joint)
        for grp in ("p2m", "backbone"):
            np.testing.assert_array_equal(np.asarray(up_1[grp]["w"]),
                                          np.asarray(up_2[grp]["w"]))

    def test_split_lr_moves_only_layer1(self):
        adamw, joint, grads = self._joint()
        ref = engine.joint_optimizer(adamw(2e-3), adamw(2e-3))
        split = engine.joint_optimizer(adamw(2e-3), adamw(1e-4))
        up_r, _ = ref.update(grads, ref.init(joint), joint)
        up_s, _ = split.update(grads, split.init(joint), joint)
        np.testing.assert_array_equal(np.asarray(up_r["backbone"]["w"]),
                                      np.asarray(up_s["backbone"]["w"]))
        assert float(jnp.max(jnp.abs(up_r["p2m"]["w"] - up_s["p2m"]["w"]))) \
            > 0.0

    def test_run_grid_lr_p2m_changes_learned_layer1(self):
        """End-to-end: the same unfrozen fast cell with a different layer-1
        LR must produce different learned-kernel retention for the
        kernel-dependent circuit (a) — the lr_p2m knob actually reaches
        the in-pixel weights."""
        from dataclasses import replace as dc_replace

        from repro.core.codesign import SweepConfig
        from repro.data import events as events_mod

        model = P2MModelConfig(
            p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=120.0),
            backbone=SpikingCNNConfig(channels=(8, 8, 8, 8),
                                      input_hw=(16, 16), fc_hidden=16,
                                      n_classes=5,
                                      first_layer_external=True),
            coarse_window_ms=120.0)
        data = ev_mod.EventStreamConfig(name="gesture", height=16, width=16,
                                        n_classes=5, duration_ms=240.0)
        grid = engine.SweepGrid(circuits=(CircuitConfig.BASIC,),
                                t_intg_grid_ms=(120.0,))
        sweep_cfg = SweepConfig(batch_size=2, pretrain_steps=2,
                                finetune_steps=3, eval_batches=1)
        rec = {}
        for lr_p2m in (None, 0.2):
            sw = dc_replace(sweep_cfg, lr_p2m=lr_p2m)
            res = engine.run_grid(data, model, sw, grid,
                                  log=lambda *_: None, protocol="unfrozen")
            rec[lr_p2m] = res.records[0]["retention_err_v"]
        assert rec[None] != rec[0.2]
