"""Per-lane online adaptation (repro.stream.adapt) — the four contracts
that make it safe to ship:

1. **Adaptation-off parity** — an adapting engine that cannot learn
   (zero learning rates, or no labeled streams) serves bit-identically
   to the frozen PR 9 engine: same predictions, same logits to the last
   ulp, same ledger. The relinearized per-lane numerics seam must not
   perturb the frozen forward. Checked on one device here and on a
   forced 8-device lane mesh in the subprocess test.
2. **Lane isolation** — a lane's updates never touch another lane:
   unlabeled lanes keep exactly-zero deltas AND their streams' logits
   stay bit-equal to the frozen serve even while neighbouring lanes
   learn.
3. **Delta round trip** — harvest → save_adapt_delta → load_adapt_delta
   → apply_adapt_delta reproduces exactly what the lane served, and the
   load refuses tampered stamps, wrong bases, and stale base uids.
4. **Kernel guard** — the fused stream_fold kernel has no VJP, so
   adaptation with use_kernel=True must fail loudly at construction.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import p2m_layer  # noqa: E402
from repro.core.codesign import P2MModelConfig  # noqa: E402
from repro.core.leakage import CircuitConfig, LeakageConfig  # noqa: E402
from repro.core.p2m_layer import P2MConfig  # noqa: E402
from repro.core.snn import SpikingCNNConfig  # noqa: E402
from repro.data import sources  # noqa: E402
from repro.stream import deploy as deploy_mod  # noqa: E402
from repro.stream.adapt import AdaptConfig, make_adapt_fns  # noqa: E402
from repro.stream.engine import StreamEngine  # noqa: E402
from repro.stream.registry import Registry, compat_key  # noqa: E402

REPO = Path(__file__).resolve().parents[1]
HW = 16


def _dep(seed=0, coarse_ms=200.0):
    src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                  duration_ms=400.0)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=100.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(HW, HW),
                                  fc_hidden=32, n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=coarse_ms)
    return src, deploy_mod.fresh_deployment(model, seed=seed)


class _MaskLabels:
    """Source wrapper hiding the label (-> -1) of every stream whose
    open order is NOT in ``labeled`` — so only chosen lanes can learn."""

    def __init__(self, src, labeled):
        self._src = src
        self._labeled = set(labeled)
        self._i = 0
        for attr in ("name", "height", "width", "n_classes", "duration_ms",
                     "sensor_hw"):
            setattr(self, attr, getattr(src, attr))

    def n_slots(self, t_intg_ms):
        return self._src.n_slots(t_intg_ms)

    def iter_event_chunks(self, key, *, chunk_us, slot_us=None):
        label, chunks = self._src.iter_event_chunks(key, chunk_us=chunk_us,
                                                    slot_us=slot_us)
        keep = self._i in self._labeled
        self._i += 1
        return (label if keep else -1), chunks


def _assert_bitexact(ref, got, *, check_labels=True):
    key = lambda r: r.stream_id  # noqa: E731
    assert len(ref.results) == len(got.results)
    for a, b in zip(sorted(ref.results, key=key),
                    sorted(got.results, key=key)):
        if check_labels:
            assert a.label == b.label
        assert a.prediction == b.prediction, a.stream_id
        assert a.n_events == b.n_events
        assert a.n_readouts == b.n_readouts
        np.testing.assert_array_equal(np.asarray(a.logits),
                                      np.asarray(b.logits))
    for k in ("n_offered", "n_admitted", "total_events", "total_readouts",
              "total_layer1_spikes"):
        assert getattr(ref, k) == getattr(got, k), k


# ---------------------------------------------------------------------------
# config + kernel guard
# ---------------------------------------------------------------------------

class TestAdaptConfig:
    def test_rejects_unknown_rule(self):
        with pytest.raises(ValueError, match="rule"):
            AdaptConfig(rule="hebbian-ish")

    def test_rejects_negative_lr(self):
        with pytest.raises(ValueError, match="learning rates"):
            AdaptConfig(lr_w=-1e-3)

    def test_rejects_nonpositive_clip(self):
        with pytest.raises(ValueError, match="clips"):
            AdaptConfig(clip_w=0.0)


class TestKernelGuard:
    """kernels/stream_fold carries no VJP: adaptation needs gradients
    through the fold, so use_kernel=True must fail at construction, not
    silently serve without learning (or crash mid-stream)."""

    def test_make_adapt_fns_raises(self):
        _, dep = _dep()
        with pytest.raises(ValueError, match="use_kernel"):
            make_adapt_fns(dep, capacity=2, chunk_slots=1,
                           adapt=AdaptConfig(), use_kernel=True)

    def test_engine_raises(self):
        _, dep = _dep()
        with pytest.raises(ValueError, match="use_kernel"):
            StreamEngine(dep, capacity=2, use_kernel=True,
                         adapt=AdaptConfig())

    def test_kernel_without_adapt_still_allowed(self):
        _, dep = _dep()
        eng = StreamEngine(dep, capacity=2, use_kernel=True)
        assert eng.adapt is None


# ---------------------------------------------------------------------------
# adaptation-off bit-identity (single device; the mesh variant is the
# subprocess test at the bottom)
# ---------------------------------------------------------------------------

class TestAdaptOffParity:
    def test_zero_lr_bit_identical_to_frozen(self):
        """lr_w = lr_theta = 0: updates fire but deltas stay exactly 0,
        so the relinearized per-lane forward must reproduce the frozen
        engine's logits bit-for-bit."""
        src, dep = _dep()
        frozen = StreamEngine(dep, capacity=4).serve(src, 6, seed=0)
        eng = StreamEngine(dep, capacity=4,
                           adapt=AdaptConfig(lr_w=0.0, lr_theta=0.0))
        adapted = eng.serve(src, 6, seed=0)
        _assert_bitexact(frozen, adapted)
        ast = jax.device_get(eng.adapt_state)
        np.testing.assert_array_equal(np.asarray(ast["dw"]), 0.0)
        np.testing.assert_array_equal(np.asarray(ast["dtheta"]), 0.0)

    def test_unlabeled_streams_bit_identical_to_frozen(self):
        """No labels -> no update is ever applied, whatever the lr."""
        src, dep = _dep()
        frozen = StreamEngine(dep, capacity=4).serve(
            _MaskLabels(src, labeled=()), 4, seed=0)
        eng = StreamEngine(dep, capacity=4,
                           adapt=AdaptConfig(lr_w=0.5, lr_theta=1e-3))
        adapted = eng.serve(_MaskLabels(src, labeled=()), 4, seed=0)
        _assert_bitexact(frozen, adapted)
        ast = jax.device_get(eng.adapt_state)
        assert int(np.asarray(ast["n_updates"]).sum()) == 0
        np.testing.assert_array_equal(np.asarray(ast["dw"]), 0.0)

    def test_artifact_reports_adaptation_off(self):
        src, dep = _dep()
        art = StreamEngine(dep, capacity=2).serve(src, 2,
                                                  seed=0).to_artifact()
        ad = art["adaptation"]
        assert ad["enabled"] is False
        assert ad["lanes"] == [] and ad["n_updates"] == 0


# ---------------------------------------------------------------------------
# lane isolation property
# ---------------------------------------------------------------------------

class TestLaneIsolation:
    def test_updates_never_perturb_other_lanes(self):
        """4 streams on 4 lanes, labels only on streams 0 and 2: lanes
        1/3 keep exactly-zero deltas and their streams' logits stay
        bit-equal to the frozen serve, even though lanes 0/2 are
        learning next to them in the same batched fold/readout."""
        src, dep = _dep()
        frozen = StreamEngine(dep, capacity=4).serve(
            _MaskLabels(src, labeled=(0, 2)), 4, seed=0)
        eng = StreamEngine(dep, capacity=4,
                           adapt=AdaptConfig(rule="surrogate", lr_w=0.5))
        rep = eng.serve(_MaskLabels(src, labeled=(0, 2)), 4, seed=0)

        ast = jax.device_get(eng.adapt_state)
        n_upd = np.asarray(ast["n_updates"])
        dw = np.asarray(ast["dw"])
        assert n_upd[0] > 0 and n_upd[2] > 0
        assert np.linalg.norm(dw[0]) > 0 and np.linalg.norm(dw[2]) > 0
        # unpaced admission is sid-order, so stream i rode lane i
        for lane in (1, 3):
            assert n_upd[lane] == 0
            np.testing.assert_array_equal(dw[lane], 0.0)

        by_id = {r.stream_id: r for r in rep.results}
        for r in frozen.results:
            if r.stream_id in (1, 3):
                np.testing.assert_array_equal(
                    np.asarray(by_id[r.stream_id].logits),
                    np.asarray(r.logits))

    def test_reward_rule_also_isolated(self):
        src, dep = _dep()
        eng = StreamEngine(dep, capacity=4,
                           adapt=AdaptConfig(rule="reward", lr_w=0.1))
        eng.serve(_MaskLabels(src, labeled=(1,)), 4, seed=0)
        ast = jax.device_get(eng.adapt_state)
        n_upd = np.asarray(ast["n_updates"])
        assert n_upd[1] > 0
        for lane in (0, 2, 3):
            assert n_upd[lane] == 0
            np.testing.assert_array_equal(np.asarray(ast["dw"])[lane], 0.0)


# ---------------------------------------------------------------------------
# delta checkpoint round trip + negative paths
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def adapted_engine():
    """One adapting serve whose lane 0 actually learned something."""
    src, dep = _dep()
    eng = StreamEngine(dep, capacity=2,
                       adapt=AdaptConfig(rule="surrogate", lr_w=0.5))
    eng.serve(src, 4, seed=0)
    return src, dep, eng


class TestDeltaRoundTrip:
    def test_harvest_save_load_apply_reserve(self, adapted_engine,
                                             tmp_path):
        """The full loop: the re-registered deployment's effective
        weights are EXACTLY what the adapted lane served —
        quantize(w_q_base + dw) at theta_base + dtheta — and it serves
        as a registry entry beside its base."""
        src, dep, eng = adapted_engine
        h = eng.harvest(0)
        assert h["n_updates"] > 0 and np.linalg.norm(h["dw"]) > 0
        deploy_mod.save_adapt_delta(tmp_path, h["base"], dw=h["dw"],
                                    dtheta=h["dtheta"], lane=h["lane"],
                                    n_updates=h["n_updates"],
                                    meta={"why": "test"})
        delta = deploy_mod.load_adapt_delta(tmp_path, h["base"])
        assert delta["n_updates"] == h["n_updates"]
        assert delta["meta"] == {"why": "test"}
        np.testing.assert_array_equal(delta["dw"], h["dw"])

        adapted = deploy_mod.apply_adapt_delta(h["base"], delta)
        w_base = p2m_layer.effective_weights(h["base"].params["p2m"],
                                             h["base"].model_cfg.p2m)
        w_served = p2m_layer.effective_weights(
            {**h["base"].params["p2m"],
             "w": np.asarray(w_base) + h["dw"]}, h["base"].model_cfg.p2m)
        w_adapted = p2m_layer.effective_weights(adapted.params["p2m"],
                                                adapted.model_cfg.p2m)
        np.testing.assert_array_equal(np.asarray(w_adapted),
                                      np.asarray(w_served))
        assert adapted.coeffs.v_threshold == pytest.approx(
            float(h["base"].coeffs.v_threshold) + h["dtheta"])
        assert adapted.record["adapted"]["n_updates"] == h["n_updates"]

        # same compat key -> registers and serves beside its base
        assert compat_key(adapted) == compat_key(dep)
        reg = Registry()
        reg.register("base", dep)
        entry = reg.register("base+adapt", adapted)
        rep = StreamEngine(reg, capacity=2,
                           default_entry="base+adapt").serve(src, 2, seed=1)
        assert len(rep.results) == 2
        assert all(r.entry == "base+adapt" and r.entry_uid == entry.uid
                   for r in rep.results)

    def test_zero_delta_is_identity(self, tmp_path):
        _, dep = _dep()
        w = p2m_layer.effective_weights(dep.params["p2m"],
                                        dep.model_cfg.p2m)
        deploy_mod.save_adapt_delta(tmp_path, dep,
                                    dw=np.zeros_like(np.asarray(w)),
                                    dtheta=0.0)
        same = deploy_mod.apply_adapt_delta(
            dep, deploy_mod.load_adapt_delta(tmp_path, dep))
        np.testing.assert_array_equal(
            np.asarray(p2m_layer.effective_weights(same.params["p2m"],
                                                   same.model_cfg.p2m)),
            np.asarray(w))
        assert same.coeffs.v_threshold == dep.coeffs.v_threshold

    def test_wrong_base_digest_rejected(self, tmp_path):
        _, dep = _dep(seed=0)
        _, other = _dep(seed=1)
        w = p2m_layer.effective_weights(dep.params["p2m"],
                                        dep.model_cfg.p2m)
        deploy_mod.save_adapt_delta(tmp_path, dep,
                                    dw=np.zeros_like(np.asarray(w)),
                                    dtheta=0.0)
        with pytest.raises(ValueError, match="digests to"):
            deploy_mod.load_adapt_delta(tmp_path, other)

    def test_stale_base_uid_rejected(self, tmp_path):
        _, dep = _dep()
        w = p2m_layer.effective_weights(dep.params["p2m"],
                                        dep.model_cfg.p2m)
        deploy_mod.save_adapt_delta(tmp_path, dep,
                                    dw=np.zeros_like(np.asarray(w)),
                                    dtheta=0.0, base_uid=3)
        assert deploy_mod.load_adapt_delta(
            tmp_path, dep, expect_uid=3)["base_uid"] == 3
        with pytest.raises(ValueError, match="hot-swapped"):
            deploy_mod.load_adapt_delta(tmp_path, dep, expect_uid=7)

    def test_tampered_stamp_rejected(self, tmp_path):
        _, dep = _dep()
        w = p2m_layer.effective_weights(dep.params["p2m"],
                                        dep.model_cfg.p2m)
        ckpt = deploy_mod.save_adapt_delta(
            tmp_path, dep, dw=np.zeros_like(np.asarray(w)), dtheta=0.0)
        index = ckpt / "index.json"
        good = json.loads(index.read_text())

        bad = json.loads(json.dumps(good))
        del bad["extra"]["base"]["digest"]
        index.write_text(json.dumps(bad))
        with pytest.raises(ValueError, match="corrupt"):
            deploy_mod.load_adapt_delta(tmp_path, dep)

        bad = json.loads(json.dumps(good))
        bad["extra"]["delta_schema"] = "p2m-deploy/v1"
        index.write_text(json.dumps(bad))
        with pytest.raises(ValueError, match="not an adaptation delta"):
            deploy_mod.load_adapt_delta(tmp_path, dep)

    def test_not_a_delta_checkpoint_rejected(self, tmp_path):
        """A plain training checkpoint (no delta stamp) is refused."""
        from repro.checkpoint import store
        _, dep = _dep()
        store.save_checkpoint(tmp_path, 0, {"dw": np.zeros(3)},
                              {"schema": "something-else"})
        with pytest.raises(ValueError, match="not an adaptation delta"):
            deploy_mod.load_adapt_delta(tmp_path, dep)

    def test_dw_shape_mismatch_rejected(self, tmp_path):
        _, dep = _dep()
        with pytest.raises(ValueError, match="shape"):
            deploy_mod.save_adapt_delta(tmp_path, dep,
                                        dw=np.zeros((2, 2)), dtheta=0.0)


class TestHarvestValidation:
    def test_frozen_engine_has_nothing_to_harvest(self):
        src, dep = _dep()
        eng = StreamEngine(dep, capacity=2)
        eng.serve(src, 2, seed=0)
        with pytest.raises(ValueError, match="without adapt"):
            eng.harvest(0)

    def test_never_served_lane_rejected(self, adapted_engine):
        _, _, eng = adapted_engine
        with pytest.raises(ValueError, match="out of range"):
            eng.harvest(99)
        fresh = StreamEngine(_dep()[1], capacity=2, adapt=AdaptConfig())
        with pytest.raises(ValueError, match="never served"):
            fresh.harvest(0)


# ---------------------------------------------------------------------------
# the v5 stats gate on a LIVE adapting artifact
# ---------------------------------------------------------------------------

class TestLiveArtifactGate:
    def test_adapting_artifact_passes_v5_gate(self, adapted_engine,
                                              tmp_path):
        src, _, eng = adapted_engine
        art = eng.serve(src, 2, seed=3).to_artifact()
        assert art["schema"] == "p2m-stream-serving/v5"
        ad = art["adaptation"]
        assert ad["enabled"] and ad["rule"] == "surrogate"
        assert ad["n_updates"] == sum(r["n_updates"] for r in ad["lanes"])
        path = tmp_path / "serving_stats.json"
        path.write_text(json.dumps(art))
        proc = subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_stream_stats.py"),
             str(path), "--streams", "2"],
            capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "adapting (surrogate)" in proc.stdout


# ---------------------------------------------------------------------------
# lane-mesh composition (forced 8 host devices, like test_stream_shard)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core.codesign import P2MModelConfig
    from repro.core.leakage import CircuitConfig, LeakageConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig
    from repro.data import sources
    from repro.stream import deploy as deploy_mod
    from repro.stream.adapt import AdaptConfig
    from repro.stream.engine import StreamEngine
    from repro.stream.shard import make_lane_executor

    assert jax.device_count() == 8, jax.device_count()
    hw = 16
    src = sources.resolve_dataset("synthetic-gesture", hw=hw,
                                  duration_ms=400.0)
    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=100.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(hw, hw),
                                  fc_hidden=32, n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=200.0)
    dep = deploy_mod.fresh_deployment(model, seed=0)

    def assert_same(a_rep, b_rep, tag):
        key = lambda r: r.stream_id
        for a, b in zip(sorted(a_rep.results, key=key),
                        sorted(b_rep.results, key=key)):
            assert a.prediction == b.prediction, (tag, a.stream_id)
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))
        assert a_rep.total_layer1_spikes == b_rep.total_layer1_spikes, tag
        print(tag, "bitexact")

    # 1) adaptation-off (zero-lr) on the mesh == frozen single-device
    frozen = StreamEngine(dep, capacity=4).serve(src, 6, seed=0)
    off = AdaptConfig(lr_w=0.0, lr_theta=0.0)
    for dev in (2, 4):
        eng = StreamEngine(dep, capacity=4, adapt=off,
                           executor=make_lane_executor(dev))
        assert_same(frozen, eng.serve(src, 6, seed=0), f"off_d{dev}")
        ast = jax.device_get(eng.adapt_state)
        assert float(np.abs(np.asarray(ast["dw"])).max()) == 0.0

    # 2) LEARNING on the mesh == learning on one device: per-lane
    # updates have no cross-lane reduction, so shard_map over lanes
    # must not change a bit of the logits OR the learned deltas
    cfg = AdaptConfig(rule="surrogate", lr_w=0.5)
    e1 = StreamEngine(dep, capacity=4, adapt=cfg)
    r1 = e1.serve(src, 6, seed=0)
    e2 = StreamEngine(dep, capacity=4, adapt=cfg,
                      executor=make_lane_executor(2))
    r2 = e2.serve(src, 6, seed=0)
    assert_same(r1, r2, "learn_d2")
    a1 = jax.device_get(e1.adapt_state)
    a2 = jax.device_get(e2.adapt_state)
    assert int(np.asarray(a1["n_updates"]).sum()) > 0
    np.testing.assert_array_equal(np.asarray(a1["n_updates"]),
                                  np.asarray(a2["n_updates"]))
    np.testing.assert_array_equal(np.asarray(a1["dw"]),
                                  np.asarray(a2["dw"]))
    np.testing.assert_array_equal(np.asarray(a1["dtheta"]),
                                  np.asarray(a2["dtheta"]))
    h1, h2 = e1.harvest(0), e2.harvest(0)
    np.testing.assert_array_equal(h1["dw"], h2["dw"])
    assert h1["n_updates"] == h2["n_updates"]
    print("MESH_ADAPT_PASS")
""")


@pytest.mark.slow
def test_adaptation_composes_with_lane_mesh():
    """Forced 8-host-device subprocess: adaptation-off serves on 2/4
    device meshes are bit-identical to the frozen single-device serve,
    and a LEARNING serve produces bit-identical logits and deltas on
    the mesh vs one device."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MESH_ADAPT_PASS" in proc.stdout
