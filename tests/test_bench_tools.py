"""BENCH_*.json perf-trajectory plumbing: the bench_record writer
(benchmarks/common.py) emits schema-valid records, and the
tools/check_bench.py gate validates schema and flags regressions against
a prior record."""
from __future__ import annotations

import json
import sys
from pathlib import Path

import pytest

jax = pytest.importorskip("jax")

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))
sys.path.insert(0, str(REPO))

import check_bench  # noqa: E402

from benchmarks.common import BENCH_SCHEMA, bench_entry, bench_record  # noqa: E402


def _write(tmp_path, name="t"):
    return bench_record(name, [
        bench_entry("k1", xla_us=100.0, kernel_us=50.0, max_err=0.0),
        bench_entry("k2", xla_us=10.0, kernel_us=None, max_err=1e-6,
                    meta={"note": "serving"}),
    ], extra={"fast": True}, root=tmp_path)


class TestBenchRecord:
    def test_writes_valid_schema(self, tmp_path):
        p = _write(tmp_path)
        assert p.name == "BENCH_t.json"
        rec = json.loads(p.read_text())
        assert rec["schema"] == BENCH_SCHEMA
        assert rec["backend"] == jax.default_backend()
        assert isinstance(rec["interpret"], bool)
        assert rec["context"] == {"fast": True}
        assert check_bench.validate(rec, "t") == []

    def test_entry_shape(self):
        e = bench_entry("x", xla_us=1.0)
        assert e == {"name": "x", "xla_us": 1.0, "kernel_us": None,
                     "max_err": None, "meta": {}}


class TestValidate:
    def _rec(self, tmp_path):
        return json.loads(_write(tmp_path).read_text())

    def test_wrong_schema_rejected(self, tmp_path):
        rec = self._rec(tmp_path)
        rec["schema"] = "p2m-bench/v0"
        assert any("schema" in e for e in check_bench.validate(rec, "t"))

    def test_missing_key_rejected(self, tmp_path):
        rec = self._rec(tmp_path)
        del rec["commit"]
        assert any("commit" in e for e in check_bench.validate(rec, "t"))

    def test_empty_entries_rejected(self, tmp_path):
        rec = self._rec(tmp_path)
        rec["entries"] = []
        assert any("non-empty" in e for e in check_bench.validate(rec, "t"))

    def test_bad_timing_rejected(self, tmp_path):
        rec = self._rec(tmp_path)
        rec["entries"][0]["kernel_us"] = "fast"
        rec["entries"][1]["xla_us"] = -1.0
        errs = check_bench.validate(rec, "t")
        assert any("kernel_us" in e for e in errs)
        assert any(">= 0" in e for e in errs)

    def test_duplicate_and_unknown_keys_rejected(self, tmp_path):
        rec = self._rec(tmp_path)
        rec["entries"][1]["name"] = "k1"
        rec["entries"][0]["speedup"] = 2.0
        errs = check_bench.validate(rec, "t")
        assert any("duplicate" in e for e in errs)
        assert any("unknown keys" in e for e in errs)


class TestTrajectory:
    def test_slowdowns_flagged(self, tmp_path):
        prev = json.loads(_write(tmp_path).read_text())
        fresh = json.loads(json.dumps(prev))
        fresh["entries"][0]["kernel_us"] = 200.0      # 4x slower
        regs = check_bench.diff_trajectory(fresh, prev)
        assert [(r[0], r[3]) for r in regs] == [("k1.kernel_us", 4.0)]

    def test_new_entries_ignored(self, tmp_path):
        prev = json.loads(_write(tmp_path).read_text())
        fresh = json.loads(json.dumps(prev))
        fresh["entries"].append(bench_entry("k3", kernel_us=1.0))
        assert check_bench.diff_trajectory(fresh, prev) == []

    def test_throughput_meta_drop_flagged(self, tmp_path):
        """meta.events_per_s(_per_device) are rates — LOWER is the
        regression, and the reported ratio is old/new so >1 always means
        'worse'."""
        prev = json.loads(_write(tmp_path).read_text())
        prev["entries"][1]["meta"] = {"events_per_s": 1000.0,
                                      "events_per_s_per_device": 500.0}
        fresh = json.loads(json.dumps(prev))
        fresh["entries"][1]["meta"]["events_per_s"] = 250.0       # 4x drop
        fresh["entries"][1]["meta"]["events_per_s_per_device"] = 500.0
        regs = check_bench.diff_trajectory(fresh, prev)
        assert [(r[0], r[3]) for r in regs] == [("k2.meta.events_per_s",
                                                 4.0)]

    def test_throughput_meta_gain_not_flagged(self, tmp_path):
        prev = json.loads(_write(tmp_path).read_text())
        prev["entries"][1]["meta"] = {"events_per_s_per_device": 100.0}
        fresh = json.loads(json.dumps(prev))
        fresh["entries"][1]["meta"]["events_per_s_per_device"] = 400.0
        assert check_bench.diff_trajectory(fresh, prev) == []

    def test_non_rate_meta_ignored(self, tmp_path):
        """Arbitrary meta fields (miss_rate, counts, notes) never enter
        the trajectory diff — only the declared rate keys do."""
        prev = json.loads(_write(tmp_path).read_text())
        prev["entries"][1]["meta"] = {"miss_rate": 0.0, "n_shed": 0}
        fresh = json.loads(json.dumps(prev))
        fresh["entries"][1]["meta"] = {"miss_rate": 0.5, "n_shed": 7}
        assert check_bench.diff_trajectory(fresh, prev) == []


class TestMain:
    def test_valid_record_passes(self, tmp_path):
        p = _write(tmp_path)
        assert check_bench.main([str(p)]) == 0

    def test_invalid_record_fails(self, tmp_path):
        p = _write(tmp_path)
        rec = json.loads(p.read_text())
        rec["entries"] = []
        p.write_text(json.dumps(rec))
        assert check_bench.main([str(p)]) == 1

    def test_committed_records_valid(self):
        """The BENCH_*.json records committed at the repo root always
        satisfy their own schema."""
        records = sorted(REPO.glob("BENCH_*.json"))
        assert records, "no BENCH_*.json committed at repo root"
        assert check_bench.main([str(p) for p in records]) == 0
