"""Dry-run smoke: one real (arch × shape × mesh) cell through the actual
launch path, in a subprocess (dryrun.py must own XLA_FLAGS before jax
init — never import it in-process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internlm2-1.8b", "--shape", "decode_32k", "--pods", "1",
         "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "internlm2-1.8b__decode_32k__1pod.json").read_text())
    assert rec["status"] == "ok", rec.get("error")
    assert rec["chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["cost"]["flops"] > 0


@pytest.mark.slow
def test_dryrun_full_mesh_cell(tmp_path):
    """The real 512-device two-pod cell for the paper-relevant SSM arch."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-780m", "--shape", "long_500k", "--pods", "2",
         "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "mamba2-780m__long_500k__2pod.json").read_text())
    assert rec["status"] == "ok", rec.get("error")
    assert rec["chips"] == 512
    assert rec["mesh"] == {"pod": 2, "data": 16, "model": 16}
