"""Serving-layer tests: per-row (continuous-batching) decode correctness
and the slot server lifecycle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import lm


def _cfg():
    cfg = smoke_variant(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, compute_dtype="float32")


class TestPerRowDecode:
    def test_vector_pos_matches_scalar_pos_rows(self):
        """Decoding rows at DIFFERENT positions in one batch must equal
        decoding each row separately at its own scalar position."""
        cfg = _cfg()
        B, max_len = 3, 24
        k = jax.random.PRNGKey(0)
        params = lm.init_params(k, cfg)
        lens = [5, 9, 14]
        prompts = [jax.random.randint(jax.random.fold_in(k, i), (1, n), 0,
                                      cfg.vocab_size)
                   for i, n in enumerate(lens)]

        # per-row batched: prefill each into its slot of a shared cache
        cache = lm.init_cache(cfg, B, max_len)
        next_tok = []
        for i, p in enumerate(prompts):
            logits_i, c1 = lm.prefill(params, p, cfg, max_len=max_len)
            cache = jax.tree.map(lambda big, small, i=i:
                                 big.at[:, i:i + 1].set(small), cache, c1)
            next_tok.append(int(jnp.argmax(logits_i[0])))
        toks = jnp.array(next_tok, jnp.int32)[:, None]
        pos_vec = jnp.array(lens, jnp.int32)
        logits_batch, _ = lm.decode_step(params, toks, pos_vec, cache, cfg)

        # reference: each row alone with a scalar position
        for i, p in enumerate(prompts):
            _, ci = lm.prefill(params, p, cfg, max_len=max_len)
            li, _ = lm.decode_step(params, toks[i:i + 1],
                                   jnp.asarray(lens[i], jnp.int32), ci, cfg)
            np.testing.assert_allclose(
                np.asarray(logits_batch[i, 0]), np.asarray(li[0, 0]),
                rtol=3e-4, atol=3e-4, err_msg=f"row {i}")


class TestSlotServer:
    def test_lifecycle(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.serve import Request, SlotServer
        cfg = smoke_variant(get_config("internlm2-1.8b"))
        mesh = make_host_mesh()
        with mesh:
            server = SlotServer(cfg, mesh, batch=2, max_len=32)
            params = lm.init_params(jax.random.PRNGKey(0), server.cfg)
            server.load(params)
            k = jax.random.PRNGKey(1)
            reqs = [Request(i, jax.random.randint(jax.random.fold_in(k, i),
                                                  (6 + 2 * i,), 0,
                                                  server.cfg.vocab_size),
                            max_new=4) for i in range(4)]
            queue = list(reqs)
            done = []
            steps = 0
            while len(done) < len(reqs):
                while queue and server.admit(queue[0]):
                    queue.pop(0)
                done.extend(server.step())
                steps += 1
                assert steps < 64
            assert all(len(r.generated) >= r.max_new for r in done)
            # slots recycled: more requests than batch completed
            assert len(done) == 4 > server.batch
