"""P²M core: analog MAC model, leakage configs, the in-pixel layer, and
the paper's qualitative claims at module level."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analog, leakage
from repro.core.analog import AnalogConfig
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import (
    P2MConfig, coarsen_spikes, p2m_apply, p2m_forward_curvefit,
    p2m_forward_scan, p2m_init,
)


# ---------------------------------------------------------------------------
# analog model
# ---------------------------------------------------------------------------


class TestAnalog:
    def test_quantizer_levels(self):
        cfg = AnalogConfig(weight_levels=16)
        w = jnp.linspace(-1.2, 1.2, 101)
        q = analog.quantize_weights(w, cfg)
        scale = cfg.w_clip / (cfg.weight_levels // 2)
        lv = np.asarray(q / scale)
        np.testing.assert_allclose(lv, np.round(lv), atol=1e-5)
        assert float(jnp.max(jnp.abs(q))) <= cfg.w_clip + 1e-6

    def test_quantizer_straight_through(self):
        cfg = AnalogConfig()
        g = jax.grad(lambda w: jnp.sum(analog.quantize_weights(w, cfg)))(
            jnp.array([0.3, -0.7]))
        np.testing.assert_allclose(np.asarray(g), [1.0, 1.0])

    def test_transfer_curve_compresses(self):
        """Cubic fit compresses large swings (c3 < 0) and clamps to rails."""
        cfg = AnalogConfig()
        x = jnp.array([0.05, 0.2, 0.39])
        y = analog.transfer_curve(x, cfg)
        assert float(y[0]) < 0.05 and float(y[0]) > 0.04
        # compression grows with amplitude
        ratios = np.asarray(y / x)
        assert ratios[0] > ratios[1] > ratios[2]
        big = analog.transfer_curve(jnp.array([10.0]), cfg)
        assert float(big[0]) <= cfg.vdd - cfg.v_precharge + 1e-6

    def test_process_variation_stats(self):
        cfg = AnalogConfig(pv_gain_sigma=0.02)
        pv = analog.sample_process_variation(jax.random.PRNGKey(0), 4096, cfg)
        assert abs(float(jnp.std(pv["gain"])) - 0.02) < 0.005
        assert abs(float(jnp.mean(pv["gain"])) - 1.0) < 0.01

    def test_step_nonlinearity_shrinks_near_rail(self):
        cfg = AnalogConfig()
        g0 = analog.step_nonlinearity(jnp.array(0.0), cfg)
        gr = analog.step_nonlinearity(jnp.array(0.35), cfg)
        assert float(g0) == 1.0
        assert float(gr) < 0.4


# ---------------------------------------------------------------------------
# leakage configs (paper Fig 3/4)
# ---------------------------------------------------------------------------


class TestLeakage:
    def _params(self, circuit, w=None):
        if w is None:
            w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 8))
        return leakage.kernel_leak_params(
            w, LeakageConfig(circuit=circuit)), w

    def test_retention_ordering_c_beats_b_beats_a(self):
        """Fig 4a: config (c) ≻ (b) ≻ (a) in charge retention."""
        v0 = jnp.full((8,), 0.15)
        errs = {}
        for c in (CircuitConfig.BASIC, CircuitConfig.SWITCH,
                  CircuitConfig.NULLIFIED):
            p, _ = self._params(c)
            errs[c] = float(jnp.mean(leakage.retention_error(p, v0, 10.0)))
        assert errs[CircuitConfig.NULLIFIED] < errs[CircuitConfig.SWITCH]
        assert errs[CircuitConfig.SWITCH] < errs[CircuitConfig.BASIC]

    def test_config_c_holds_10ms(self):
        """The paper's co-design claim: (c) holds charge at T=10 ms."""
        p, _ = self._params(CircuitConfig.NULLIFIED)
        err = leakage.retention_error(p, jnp.full((8,), 0.2), 10.0)
        assert float(jnp.max(err)) < 0.01     # < 10 mV drift on 200 mV

    def test_config_a_saturates(self):
        """(a) drifts toward its kernel-dependent asymptote."""
        p, w = self._params(CircuitConfig.BASIC)
        v = jnp.zeros((8,))
        v_late = leakage.leak_step(v, p, 1000.0)
        np.testing.assert_allclose(np.asarray(v_late), np.asarray(p.v_inf),
                                   atol=1e-4)

    def test_config_a_direction_kernel_dependent(self):
        """All-positive kernels leak toward VDD, all-negative toward GND."""
        cfg = LeakageConfig(circuit=CircuitConfig.BASIC)
        w_pos = jnp.ones((3, 3, 2, 4)) * 0.5
        w_neg = -w_pos
        p_pos = leakage.kernel_leak_params(w_pos, cfg)
        p_neg = leakage.kernel_leak_params(w_neg, cfg)
        assert float(jnp.min(p_pos.v_inf)) > 0.3     # toward +rail
        assert float(jnp.max(p_neg.v_inf)) < -0.3    # toward ground

    def test_ideal_no_decay(self):
        p, _ = self._params(CircuitConfig.IDEAL)
        v = jnp.array([0.1, -0.2, 0.3, 0.0, 0.1, 0.1, 0.1, 0.1])
        np.testing.assert_allclose(
            np.asarray(leakage.leak_step(v, p, 1e6)), np.asarray(v))

    def test_exact_ode_integration(self):
        """leak_step(dt) twice == leak_step(2dt) — exact exponential."""
        p, _ = self._params(CircuitConfig.SWITCH)
        v = jnp.full((8,), 0.2)
        one = leakage.leak_step(leakage.leak_step(v, p, 3.0), p, 3.0)
        two = leakage.leak_step(v, p, 6.0)
        np.testing.assert_allclose(np.asarray(one), np.asarray(two), rtol=1e-6)


# ---------------------------------------------------------------------------
# the P²M layer
# ---------------------------------------------------------------------------


class TestP2MLayer:
    def _setup(self, mode="curvefit", circuit=CircuitConfig.NULLIFIED,
               t_intg=10.0, n_sub=4):
        cfg = P2MConfig(out_channels=6, t_intg_ms=t_intg, n_sub=n_sub,
                        mode=mode,
                        leak=LeakageConfig(circuit=circuit))
        params = p2m_init(jax.random.PRNGKey(0), cfg)
        ev = jax.random.poisson(jax.random.PRNGKey(1), 0.4,
                                (2, 3, n_sub, 12, 12, 2)).astype(jnp.float32)
        return cfg, params, ev

    def test_shapes(self):
        cfg, params, ev = self._setup()
        s, v = p2m_apply(params, ev, cfg)
        assert s.shape == (2, 3, 12, 12, 6)
        assert v.shape == s.shape
        assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}

    def test_scan_curvefit_agree_ideal(self):
        """With no leak and no nonlinearity the two paths are identical."""
        an = AnalogConfig(enable_nonlinearity=False,
                          enable_process_variation=False)
        cfg = P2MConfig(out_channels=4, n_sub=3, mode="scan",
                        analog=dataclasses.replace(an),
                        leak=LeakageConfig(circuit=CircuitConfig.IDEAL))
        params = p2m_init(jax.random.PRNGKey(2), cfg)
        params = {**params, "pv_gain": jnp.ones_like(params["pv_gain"]),
                  "pv_offset": jnp.zeros_like(params["pv_offset"])}
        ev = jax.random.poisson(jax.random.PRNGKey(3), 0.2,
                                (1, 2, 3, 10, 10, 2)).astype(jnp.float32)
        _, v_scan = p2m_forward_scan(params, ev, cfg)
        _, v_fit = p2m_forward_curvefit(params, ev, cfg)
        np.testing.assert_allclose(np.asarray(v_fit), np.asarray(v_scan),
                                   rtol=1e-4, atol=1e-5)

    def test_leak_degrades_with_t_intg(self):
        """Longer T_INTG at fixed circuit = more leak error vs ideal (Fig 4b-d)."""
        errs = []
        for t in (1.0, 10.0, 100.0):
            cfg, params, ev = self._setup(mode="scan",
                                          circuit=CircuitConfig.SWITCH,
                                          t_intg=t)
            _, v_leaky = p2m_forward_scan(params, ev, cfg)
            cfg_i = dataclasses.replace(
                cfg, leak=LeakageConfig(circuit=CircuitConfig.IDEAL))
            _, v_ideal = p2m_forward_scan(params, ev, cfg_i)
            errs.append(float(jnp.mean(jnp.abs(v_leaky - v_ideal))))
        assert errs[0] < errs[1] < errs[2]

    def test_gradients_flow(self):
        cfg, params, ev = self._setup(mode="curvefit")
        def loss(p):
            s, v = p2m_apply(p, ev, cfg)
            return jnp.sum(v ** 2)
        g = jax.grad(loss)(params)
        assert bool(jnp.all(jnp.isfinite(g["w"])))
        assert float(jnp.max(jnp.abs(g["w"]))) > 0.0

    def test_coarsen_spikes(self):
        s = jnp.ones((2, 8, 4, 4, 3))
        c = coarsen_spikes(s, 4)
        assert c.shape == (2, 2, 4, 4, 3)
        np.testing.assert_allclose(np.asarray(c), 4.0)

    def test_kernel_mode_matches_scan(self):
        cfg, params, ev = self._setup(mode="scan")
        s_scan, v_scan = p2m_apply(params, ev, cfg)
        cfg_k = dataclasses.replace(cfg, mode="kernel")
        s_k, v_k = p2m_apply(params, ev, cfg_k)
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_scan),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# energy / bandwidth model (paper Fig 2 directionality)
# ---------------------------------------------------------------------------


class TestEnergyModel:
    def test_p2m_beats_conventional(self):
        from repro.core import energy
        aux = {"synops/conv1": 1e6, "synops/fc0": 1e5}
        macs, spikes = 1e6, 2e4
        conv = energy.backend_energy_conventional(aux, macs)
        p2m = energy.backend_energy_p2m(aux, spikes, macs)
        assert conv / p2m > 2.0    # the paper's ≥2× claim

    def test_energy_improvement_grows_with_fewer_spikes(self):
        from repro.core import energy
        aux = {"synops/conv1": 1e6}
        macs = 1e6
        imp_many = energy.improvement(aux, 1e6, macs)
        imp_few = energy.improvement(aux, 1e3, macs)
        assert imp_few > imp_many
