"""File-backed dataset subsystem (repro.data.{formats,binning,cache,
sources,fixtures}): bit-exact parser round trips, streaming slot-binning
at multiple T_INTG, deterministic splits, the on-disk frame cache, the
EventSource contract against the synthetic path, and the end-to-end
``--dataset dvs128`` CLI sweep on an on-the-fly AEDAT fixture.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import binning, cache as cache_mod, fixtures, formats, sources

SRC = Path(__file__).resolve().parents[1] / "src"


def _random_events(rng, n, *, hw, t_max, sort=True):
    t = rng.integers(0, t_max, n)
    if sort:
        t = np.sort(t)
    return formats.EventChunk(
        t=t.astype(np.int64),
        x=rng.integers(0, hw, n).astype(np.int32),
        y=rng.integers(0, hw, n).astype(np.int32),
        p=rng.integers(0, 2, n).astype(np.int8))


def _assert_chunks_equal(a, b):
    for f in ("t", "x", "y", "p"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)


class TestFormats:
    """Writers are exact inverses of the parsers — bit-exact (t, x, y, p)."""

    def test_aedat31_round_trip(self, tmp_path):
        ev = _random_events(np.random.default_rng(0), 10_000, hw=128,
                            t_max=5_000_000)
        p = tmp_path / "rt.aedat"
        formats.write_aedat31(p, ev, events_per_packet=997)  # packet splits
        back = formats.concat_chunks(formats.read_aedat31(p))
        _assert_chunks_equal(back, ev)

    def test_aedat31_empty(self, tmp_path):
        p = tmp_path / "empty.aedat"
        formats.write_aedat31(p, formats.concat_chunks([]))
        assert len(formats.concat_chunks(formats.read_aedat31(p))) == 0

    def test_aedat31_t_stop_cuts_tail_packets(self, tmp_path):
        ev = _random_events(np.random.default_rng(1), 4000, hw=128,
                            t_max=1_000_000)
        p = tmp_path / "win.aedat"
        formats.write_aedat31(p, ev, events_per_packet=100)
        cut = formats.concat_chunks(formats.read_aedat31(
            p, t_stop_us=500_000))
        assert 0 < len(cut) < len(ev)
        # every pre-cut event present (packets stop once past the window)
        assert int(cut.t[0]) == int(ev.t[0])

    def test_aedat31_rejects_other_magic(self, tmp_path):
        p = tmp_path / "v2.aedat"
        p.write_bytes(b"#!AER-DAT2.0\r\n" + b"\x00" * 64)
        with pytest.raises(ValueError, match="AEDAT"):
            list(formats.read_aedat31(p))

    def test_aedat31_range_check(self, tmp_path):
        bad = formats.EventChunk(t=np.array([0], np.int64),
                                 x=np.array([1 << 15], np.int32),
                                 y=np.array([0], np.int32),
                                 p=np.array([1], np.int8))
        with pytest.raises(ValueError, match="range"):
            formats.write_aedat31(tmp_path / "bad.aedat", bad)

    def test_nmnist_bin_round_trip(self, tmp_path):
        ev = _random_events(np.random.default_rng(2), 7_531, hw=34,
                            t_max=(1 << 23) - 1, sort=False)
        p = tmp_path / "rt.bin"
        formats.write_nmnist_bin(p, ev)
        back = formats.concat_chunks(
            formats.read_nmnist_bin(p, chunk_events=512))  # chunk splits
        _assert_chunks_equal(back, ev)

    def test_nmnist_bin_range_check(self, tmp_path):
        bad = formats.EventChunk(t=np.array([1 << 23], np.int64),
                                 x=np.array([0], np.int32),
                                 y=np.array([0], np.int32),
                                 p=np.array([0], np.int8))
        with pytest.raises(ValueError, match="range"):
            formats.write_nmnist_bin(tmp_path / "bad.bin", bad)


class TestBinning:
    def test_frames_to_events_to_frames_exact(self):
        rng = np.random.default_rng(3)
        frames = rng.poisson(0.7, (16, 8, 8, 2)).astype(np.float32)
        ev = binning.frames_to_events(frames, 2000)
        back = binning.bin_chunks([ev], n_total=16, slot_us=2000,
                                  sensor_hw=(8, 8), out_hw=(8, 8))
        np.testing.assert_array_equal(back, frames)

    def test_rebin_at_coarser_t_intg_conserves_counts(self):
        """The same stream binned at two T_INTG values: totals identical,
        and the coarse histogram is the block-sum of the fine one."""
        rng = np.random.default_rng(4)
        frames = rng.poisson(0.5, (20, 8, 8, 2)).astype(np.float32)
        ev = binning.frames_to_events(frames, 1000)
        fine = binning.bin_chunks([ev], n_total=20, slot_us=1000,
                                  sensor_hw=(8, 8), out_hw=(8, 8))
        coarse = binning.bin_chunks([ev], n_total=4, slot_us=5000,
                                    sensor_hw=(8, 8), out_hw=(8, 8))
        assert coarse.sum() == fine.sum()
        np.testing.assert_array_equal(
            coarse, fine.reshape(4, 5, 8, 8, 2).sum(axis=1))

    def test_spatial_downscale_conserves_counts(self):
        rng = np.random.default_rng(5)
        ev = _random_events(rng, 5000, hw=128, t_max=10_000)
        full = binning.bin_chunks([ev], n_total=10, slot_us=1000,
                                  sensor_hw=(128, 128), out_hw=(128, 128))
        down = binning.bin_chunks([ev], n_total=10, slot_us=1000,
                                  sensor_hw=(128, 128), out_hw=(16, 16))
        assert down.shape == (10, 16, 16, 2)
        assert down.sum() == full.sum() == 5000
        # per-slot, per-polarity marginals survive the downscale
        np.testing.assert_array_equal(down.sum(axis=(1, 2)),
                                      full.sum(axis=(1, 2)))

    def test_polarity_channel_convention(self):
        """p=1 (ON) lands in channel 0, p=0 (OFF) in channel 1 — matching
        the synthetic generator's (ON, OFF) last axis."""
        ev = formats.EventChunk(t=np.array([10, 20], np.int64),
                                x=np.array([1, 2], np.int32),
                                y=np.array([3, 4], np.int32),
                                p=np.array([1, 0], np.int8))
        out = binning.bin_chunks([ev], n_total=1, slot_us=1000,
                                 sensor_hw=(8, 8), out_hw=(8, 8))
        assert out[0, 3, 1, 0] == 1.0 and out[0, 4, 2, 1] == 1.0
        assert out.sum() == 2.0

    def test_out_of_window_events_dropped(self):
        ev = formats.EventChunk(t=np.array([-5, 500, 99_999], np.int64),
                                x=np.zeros(3, np.int32),
                                y=np.zeros(3, np.int32),
                                p=np.ones(3, np.int8))
        out = binning.bin_chunks([ev], n_total=10, slot_us=1000,
                                 sensor_hw=(8, 8), out_hw=(8, 8))
        assert out.sum() == 1.0        # only t=500 is inside [0, 10ms)

    def test_slot_us_for_rejects_fractional(self):
        assert binning.slot_us_for(10.0, 2) == 5000
        with pytest.raises(ValueError, match="microsecond"):
            binning.slot_us_for(0.0005, 3)


class TestSplits:
    def test_split_of_deterministic_and_partitioned(self):
        ids = [f"user{u:02d}_led.aedat#{k}" for u in range(30)
               for k in range(12)]
        s1 = [sources.split_of(i) for i in ids]
        s2 = [sources.split_of(i) for i in ids]
        assert s1 == s2
        frac = s1.count("val") / len(s1)
        assert 0.08 < frac < 0.35      # ~VAL_PERCENT with hash noise
        assert set(s1) == {"train", "val"}

    def test_recording_level_split_via_split_id(self):
        """Windows of one recording never straddle splits: the hash runs
        on FileSample.split_id (the recording path for DVS128-Gesture)."""
        mk = lambda rec, k: sources.FileSample(            # noqa: E731
            f"{rec}#{k}", 0, lambda: iter([]), split_id=rec)
        samples = [mk(f"rec{r:02d}.aedat", k)
                   for r in range(40) for k in range(5)]
        srcs = {sp: sources.FileEventSource(
            "x", samples, sensor_hw=(8, 8), hw=8, n_classes=1,
            duration_ms=100.0, split=sp) for sp in ("train", "val")}
        recs = lambda s: {x.split_id for x in s.samples}   # noqa: E731
        assert not recs(srcs["train"]) & recs(srcs["val"])
        assert recs(srcs["train"]) | recs(srcs["val"]) == \
            {f"rec{r:02d}.aedat" for r in range(40)}
        # every window of a surviving recording survives with it
        for s in srcs.values():
            by_rec = {}
            for x in s.samples:
                by_rec.setdefault(x.split_id, []).append(x)
            assert all(len(v) == 5 for v in by_rec.values())

    def test_train_val_disjoint_and_exhaustive(self, tmp_path):
        root = fixtures.make_nmnist_fixture(tmp_path / "nm", n_per_class=3,
                                            duration_ms=200.0)
        tr = sources.NMNISTSource(root, duration_ms=1000.0, split="train")
        va = sources.NMNISTSource(root, duration_ms=1000.0, split="val")
        al = sources.NMNISTSource(root, duration_ms=1000.0, split="all")
        ids = lambda s: {x.sample_id for x in s.samples}  # noqa: E731
        assert ids(tr) | ids(va) == ids(al)
        assert not ids(tr) & ids(va)


@pytest.fixture(scope="module")
def dvs_root(tmp_path_factory):
    return fixtures.make_dvs128_fixture(
        tmp_path_factory.mktemp("dvs"), n_recordings=2,
        trials_per_recording=11, duration_ms=2000.0)


class TestFileSources:
    def test_event_source_contract_matches_synthetic(self, dvs_root):
        """File-backed batches carry the synthetic path's exact array
        contract: float32 [B, n_slots, n_sub, H, W, 2] counts + labels."""
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all")
        syn = sources.resolve_dataset("synthetic-gesture", hw=16)
        for s in (src, syn):
            ev, lab = s.sample_batch(jax.random.PRNGKey(0), 3, 500.0,
                                     n_sub=2)
            assert ev.shape == (3, 4, 2, 16, 16, 2)
            assert ev.dtype == jnp.float32
            assert lab.shape == (3,)
            assert float(ev.min()) >= 0.0 and float(ev.sum()) > 0.0
            assert int(lab.max()) < s.n_classes

    def test_two_t_intg_values_conserve_counts(self, dvs_root):
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all")
        k = jax.random.PRNGKey(1)
        ev_a, _ = src.sample_batch(k, 2, 200.0)
        ev_b, _ = src.sample_batch(k, 2, 1000.0)
        assert ev_a.shape[1] == 10 and ev_b.shape[1] == 2
        assert float(ev_a.sum()) == float(ev_b.sum())

    def test_deterministic_in_key(self, dvs_root):
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all")
        ev1, l1 = src.sample_batch(jax.random.PRNGKey(3), 4, 500.0)
        ev2, l2 = src.sample_batch(jax.random.PRNGKey(3), 4, 500.0)
        np.testing.assert_array_equal(np.asarray(ev1), np.asarray(ev2))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_sample_batch_with_labels(self, dvs_root):
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all")
        want = jnp.array([0, 3, 7])
        ev, lab = src.sample_batch_with_labels(jax.random.PRNGKey(4), want,
                                               500.0)
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(want))
        assert ev.shape[0] == 3

    def test_cache_hit_is_exact_and_reused(self, dvs_root, tmp_path):
        croot = tmp_path / "cache"
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all", cache_root=croot)
        k = jax.random.PRNGKey(5)
        ev1, _ = src.sample_batch(k, 2, 500.0)
        files = list(croot.rglob("*.npy"))
        assert files                      # miss path populated the cache
        mtimes = {f: f.stat().st_mtime_ns for f in files}
        ev2, _ = src.sample_batch(k, 2, 500.0)
        np.testing.assert_array_equal(np.asarray(ev1), np.asarray(ev2))
        assert all(f.stat().st_mtime_ns == mtimes[f] for f in files)

    def test_cache_keyed_by_t_intg(self, dvs_root, tmp_path):
        croot = tmp_path / "cache"
        c = cache_mod.FrameCache(croot, "dvs128")
        p1 = c.path("a#0", slot_us=1000, out_hw=(16, 16), n_total=10)
        p2 = c.path("a#0", slot_us=5000, out_hw=(16, 16), n_total=2)
        p3 = c.path("a#0", slot_us=1000, out_hw=(32, 32), n_total=10)
        assert len({p1, p2, p3}) == 3

    def test_gesture_fixture_labels_cover_all_classes(self, dvs_root):
        src = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                       split="all")
        assert {s.label for s in src.samples} == set(range(11))

    def test_window_end_clips_next_gesture(self, tmp_path):
        """A source duration longer than a labeled window must NOT pull
        the next gesture's events into this sample (binning clips at the
        CSV's endTime_usec)."""
        root = fixtures.make_dvs128_fixture(
            tmp_path / "dvs0", n_recordings=1, trials_per_recording=4,
            duration_ms=1000.0, gap_us=0)      # back-to-back windows
        src = sources.DVSGestureSource(root, hw=16, duration_ms=2000.0,
                                       split="all")
        ev, _ = src.sample_batch_with_labels(
            jax.random.PRNGKey(0), jnp.array([0]), 1000.0)   # 2 slots
        ev = np.asarray(ev)
        assert ev[0, 0].sum() > 0          # the labeled 1 s window
        assert ev[0, 1].sum() == 0         # next gesture's second: clipped

    def test_nmnist_default_duration_matches_recordings(self, tmp_path):
        root = fixtures.make_nmnist_fixture(tmp_path / "nm", n_per_class=1,
                                            duration_ms=300.0)
        src = sources.resolve_dataset("nmnist", data_root=str(root),
                                      split="all")
        assert src.duration_ms == 300.0   # not 2 s of ~85% zero padding

    def test_resolve_eval_dataset(self, dvs_root, tmp_path):
        # synthetic: no split notion
        assert sources.resolve_eval_dataset("synthetic-gesture") == \
            (None, None)
        # fixture recordings all hash to train → val empty → fallback
        src, split = sources.resolve_eval_dataset(
            "dvs128", hw=16, data_root=str(dvs_root))
        assert (src, split) == (None, "train")
        # nmnist fixture with Train/Test dirs → real held-out source
        root = fixtures.make_nmnist_fixture(tmp_path / "nm", n_per_class=1,
                                            duration_ms=200.0,
                                            train_test_dirs=True)
        src, split = sources.resolve_eval_dataset("nmnist",
                                                  data_root=str(root))
        assert split == "val"
        assert all(s.sample_id.startswith("Test/") for s in src.samples)

    def test_missing_root_raises(self, tmp_path):
        with pytest.raises(ValueError, match="no samples"):
            sources.DVSGestureSource(tmp_path / "nope", hw=16)
        with pytest.raises(ValueError, match="file-backed"):
            sources.resolve_dataset("dvs128")

    def test_nmnist_train_test_dirs_map_to_splits(self, tmp_path):
        root = fixtures.make_nmnist_fixture(tmp_path / "nm", n_per_class=1,
                                            duration_ms=200.0,
                                            train_test_dirs=True)
        tr = sources.NMNISTSource(root, duration_ms=1000.0, split="train")
        va = sources.NMNISTSource(root, duration_ms=1000.0, split="val")
        assert all(s.sample_id.startswith("Train/") for s in tr.samples)
        assert all(s.sample_id.startswith("Test/") for s in va.samples)
        ev, lab = tr.sample_batch(jax.random.PRNGKey(0), 2, 250.0, n_sub=2)
        assert ev.shape == (2, 4, 2, 16, 16, 2)


class TestEndToEndSweep:
    def test_cli_dvs128_fast_grid_artifact(self, dvs_root, tmp_path):
        """The acceptance path: `--dataset dvs128 --data-root <tmp>` on a
        generated AEDAT fixture emits a valid p2m-codesign-sweep/v3
        artifact whose records carry the synthetic path's schema."""
        from repro.core import sweep as engine  # noqa: F401 (import check)

        env = dict(os.environ, PYTHONPATH=str(SRC), JAX_PLATFORMS="cpu")
        out = tmp_path / "art"
        cmd = [sys.executable, "-m", "repro.launch.sweep",
               "--grid", "fast", "--protocol", "frozen",
               "--dataset", "dvs128", "--data-root", str(dvs_root),
               "--t-intg", "200", "1000", "--out", str(out)]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=900)
        assert proc.returncode == 0, proc.stderr
        art = json.loads((out / "codesign_grid_fast.json").read_text())
        assert art["schema"] == "p2m-codesign-sweep/v3"
        assert art["data"]["dataset"] == "dvs128"
        assert art["data"]["n_classes"] == 11
        assert art["data"]["eval_split"] in ("train", "val")
        # record contract identical to the synthetic path (the v1 keys
        # pinned by tests/test_sweep_protocols.py plus the v3 additions)
        keys = {"label", "circuit", "null_mismatch", "protocol", "t_intg_ms",
                "n_sub", "variant", "accuracy", "train_time_s",
                "train_time_per_step_s", "train_time_norm",
                "bandwidth_ratio", "bandwidth_norm",
                "backend_energy_conventional_j", "backend_energy_p2m_j",
                "energy_improvement", "sensor_energy_p2m_j",
                "layer1_spikes", "input_events", "retention_err_v",
                "retention_surface_v"}
        assert len(art["records"]) == 3 * 2        # 3 circuits × 2 T points
        for r in art["records"]:
            assert keys <= set(r), keys - set(r)
            assert 0.0 <= r["accuracy"] <= 1.0
            assert r["input_events"] > 0

    def test_run_grid_accepts_file_source_in_process(self, dvs_root):
        """Programmatic seam: run_grid on a FileEventSource (1 circuit,
        1 T point) produces normalized records."""
        from dataclasses import replace

        from repro.core import sweep as engine
        from repro.core.leakage import CircuitConfig

        data = sources.DVSGestureSource(dvs_root, hw=16, duration_ms=2000.0,
                                        split="all")
        _, model, sweep_cfg, _ = engine.paper_setup(fast=True)
        model = replace(model, backbone=replace(model.backbone,
                                                n_classes=data.n_classes))
        grid = engine.SweepGrid(circuits=(CircuitConfig.NULLIFIED,),
                                t_intg_grid_ms=(1000.0,))
        class CountingEval(sources.SyntheticSource):
            calls = 0

            def sample_batch(self, *a, **kw):
                CountingEval.calls += 1
                return super().sample_batch(*a, **kw)

        eval_src = CountingEval(sources.resolve_dataset(
            "synthetic-gesture", hw=16).cfg)
        res = engine.run_grid(data, model, sweep_cfg, grid,
                              log=lambda *_: None, protocol="frozen",
                              eval_data=eval_src)
        assert len(res.records) == 1
        r = res.records[0]
        assert r["bandwidth_norm"] == pytest.approx(1.0)
        assert r["input_events"] > 0
        # the held-out eval seam was actually used for the eval batches
        assert CountingEval.calls == sweep_cfg.eval_batches
