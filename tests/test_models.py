"""LM-family model tests: every family's forward / prefill / decode paths
agree, caches have the declared shapes, losses are finite, all 10 assigned
archs run a reduced train step (the per-arch smoke requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_variant
from repro.models import encdec, lm

ALL_ARCHS = list(ARCHS)


def _batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["img_embed"] = jax.random.normal(
            jax.random.fold_in(k, 1), (B, cfg.n_image_tokens, cfg.vision_dim),
            jnp.dtype(cfg.compute_dtype))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(k, 2), (B, S, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: reduced config, one forward + one grad step, no NaNs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = smoke_variant(get_config(arch))
    batch = _batch(cfg)
    if cfg.is_encdec:
        params = encdec.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = encdec.loss_fn
    else:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = lm.loss_fn
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert jnp.isfinite(loss), arch
    # gradients: finite and at least one non-zero leaf
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_full_config_published_numbers(arch):
    """The full (non-smoke) config carries the assignment's exact numbers."""
    cfg = get_config(arch)
    expected = {
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }[arch]
    L, D, H, KV, F, V = expected
    assert cfg.n_layers == L and cfg.d_model == D and cfg.vocab_size == V
    if H:
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == F
    # family extras
    if arch == "granite-moe-1b-a400m":
        assert cfg.n_experts == 32 and cfg.top_k == 8
    if arch == "grok-1-314b":
        assert cfg.n_experts == 8 and cfg.top_k == 2
    if arch == "mamba2-780m":
        assert cfg.ssm_state == 128
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64
    if arch == "qwen3-32b":
        assert cfg.qk_norm
    if arch == "gemma-7b":
        assert cfg.head_dim == 256 and cfg.act == "gelu"


# ---------------------------------------------------------------------------
# prefill + decode == full forward (the cache-correctness invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-780m", "zamba2-7b",
                                  "granite-moe-1b-a400m",
                                  "llama-3.2-vision-90b"])
def test_prefill_decode_matches_forward(arch):
    """Prefill S tokens then decode one more == forward over S+1 tokens."""
    cfg = smoke_variant(get_config(arch))
    # fp32 compute for a tight comparison; dropless MoE (serving semantics —
    # capacity drops depend on batch population, see serve_config)
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        capacity_factor=(cfg.n_experts / max(cfg.top_k, 1)
                         if cfg.n_experts else cfg.capacity_factor))
    B, S = 2, 12
    k = jax.random.PRNGKey(1)
    tokens = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    img = (jax.random.normal(jax.random.fold_in(k, 1),
                             (B, cfg.n_image_tokens, cfg.vision_dim),
                             jnp.float32)
           if cfg.family == "vlm" else None)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    # full forward over S+1: logits at position S (0-indexed last)
    logits_full, _ = lm.forward(params, tokens, cfg, img_embed=img)
    want = logits_full[:, S - 1]     # prediction after consuming token S-1

    # prefill S then check last-logits match
    last, cache = lm.prefill(params, tokens[:, :S], cfg, img_embed=img,
                             max_len=S + 4)
    np.testing.assert_allclose(np.asarray(last), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    # decode token S → logits must match forward position S
    logits_dec, cache = lm.decode_step(params, tokens[:, S:S + 1],
                                       jnp.asarray(S, jnp.int32), cache, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-4, atol=2e-4)


def test_encdec_prefill_decode_matches_forward():
    cfg = smoke_variant(get_config("seamless-m4t-large-v2"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    B, S = 2, 10
    k = jax.random.PRNGKey(2)
    tokens = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.fold_in(k, 1), (B, S, cfg.d_model),
                               jnp.float32)
    params = encdec.init_params(jax.random.PRNGKey(0), cfg)

    batch = {"tokens": tokens[:, :S], "labels": tokens[:, 1:S + 1],
             "frames": frames}
    loss, _ = encdec.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss)

    last, cache = encdec.prefill(params, frames, tokens[:, :S], cfg)
    logits_dec, _ = encdec.decode_step(params, tokens[:, S:S + 1],
                                       jnp.asarray(S, jnp.int32), cache, cfg)
    assert logits_dec.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits_dec)))


# ---------------------------------------------------------------------------
# decode over many steps stays consistent (cache indices don't corrupt)
# ---------------------------------------------------------------------------


def test_multi_step_decode_consistency():
    cfg = smoke_variant(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    B, S, extra = 1, 8, 4
    k = jax.random.PRNGKey(3)
    tokens = jax.random.randint(k, (B, S + extra), 0, cfg.vocab_size)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    logits_full, _ = lm.forward(params, tokens, cfg)
    _, cache = lm.prefill(params, tokens[:, :S], cfg, max_len=S + extra)
    for i in range(extra):
        pos = S + i
        logits, cache = lm.decode_step(params, tokens[:, pos:pos + 1],
                                       jnp.asarray(pos, jnp.int32), cache, cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(logits_full[:, pos]),
            rtol=3e-4, atol=3e-4, err_msg=f"step {i}")


# ---------------------------------------------------------------------------
# MoE specifics
# ---------------------------------------------------------------------------


class TestMoE:
    def _cfg(self):
        return smoke_variant(get_config("granite-moe-1b-a400m"))

    def test_aux_loss_positive_and_bounded(self):
        from repro.nn import moe as moe_mod
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y, aux = moe_mod.moe_apply(p, x, cfg)
        assert y.shape == x.shape
        lb = float(aux["lb_loss"])
        assert lb >= 1.0 - 1e-3   # ≥ 1 by Cauchy-Schwarz for softmax router

    def test_capacity_drops_tokens_gracefully(self):
        from repro.nn import moe as moe_mod
        cfg = dataclasses.replace(self._cfg(), capacity_factor=0.25)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y, aux = moe_mod.moe_apply(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(y)))
        # under tight capacity some tokens must be dropped
        assert float(aux["drop_frac"]) > 0.0

    def test_expert_utilization(self):
        """With random inputs the router spreads load (no expert collapse)."""
        from repro.nn import moe as moe_mod
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        _, aux = moe_mod.moe_apply(p, x, cfg)
        assert float(aux["drop_frac"]) < 0.5
