"""Unit tests for the shared continuous-batching slot manager
(repro.serve.slots) — the lane table both the LM decode server
(launch/serve.py) and the event-stream engine (repro.stream.engine)
batch on."""
from collections import deque

import pytest

from repro.serve.slots import SlotManager


class TestSlotManager:
    def test_admit_until_full(self):
        m = SlotManager(3)
        assert m.capacity == 3 and m.is_empty() and not m.is_full()
        assert [m.admit(f"r{i}") for i in range(3)] == [0, 1, 2]
        assert m.is_full() and m.n_free == 0 and m.n_occupied == 3
        assert m.admit("overflow") is None          # full → rejected
        assert m.active_mask() == [True, True, True]

    def test_release_frees_lowest_lane_for_reuse(self):
        m = SlotManager(2)
        m.admit("a"), m.admit("b")
        assert m.release(0) == "a"
        assert m.active_mask() == [False, True]
        assert m.admit("c") == 0                    # lowest free lane
        assert m.get(0) == "c" and m.get(1) == "b"

    def test_release_empty_lane_raises(self):
        m = SlotManager(2)
        with pytest.raises(ValueError, match="already free"):
            m.release(1)

    def test_admit_none_raises(self):
        with pytest.raises(ValueError, match="None"):
            SlotManager(1).admit(None)

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            SlotManager(0)

    def test_refill_pops_queue_in_order(self):
        m = SlotManager(2)
        queue = deque(["a", "b", "c"])
        placed = m.refill(queue)
        assert placed == [(0, "a"), (1, "b")]
        assert list(queue) == ["c"]                 # only admitted popped
        assert m.refill(queue) == []                # full → no-op
        m.release(1)
        assert m.refill(queue) == [(1, "c")] and not queue

    def test_refill_rejects_list_queue(self):
        """The queue contract is deque.popleft — a Python list's head pop
        is O(n) per admit, O(n²) over the long backlogs the saturation
        harness builds, so lists are rejected loudly instead of silently
        going quadratic."""
        m = SlotManager(2)
        with pytest.raises(TypeError, match="popleft"):
            m.refill(["a", "b"])

    def test_refill_deque_matches_old_list_semantics(self):
        """The deque-based refill places exactly the items in exactly the
        lanes the old list-head-pop implementation did, across admit /
        release / refill rounds."""
        items = [f"r{i}" for i in range(9)]

        def old_refill(m, q):                 # the pre-deque reference
            placed = []
            while q and not m.is_full():
                item = q.pop(0)
                slot = m.admit(item)
                placed.append((slot, item))
            return placed

        m_old, q_old = SlotManager(3), list(items)
        m_new, q_new = SlotManager(3), deque(items)
        for round_ in range(5):
            assert m_new.refill(q_new) == old_refill(m_old, q_old)
            assert list(q_new) == q_old
            assert m_new.active_mask() == m_old.active_mask()
            # release a varying subset each round
            for lane in [i for i, _ in m_old.occupied()][round_ % 2::2]:
                assert m_old.release(lane) == m_new.release(lane)

    def test_occupied_iterates_lane_order(self):
        m = SlotManager(3)
        m.admit("a"), m.admit("b"), m.admit("c")
        m.release(1)
        assert list(m.occupied()) == [(0, "a"), (2, "c")]

    def test_continuous_recycling(self):
        """More items than capacity complete via release+refill — the
        serving pattern both consumers run."""
        m = SlotManager(2)
        queue = deque(f"r{i}" for i in range(7))
        done = []
        steps = 0
        while queue or not m.is_empty():
            m.refill(queue)
            # every occupied lane "finishes" this step
            for lane, item in list(m.occupied()):
                done.append(m.release(lane))
            steps += 1
            assert steps < 20
        assert done == [f"r{i}" for i in range(7)]
