"""Unit tests for the shared continuous-batching slot manager
(repro.serve.slots) — the lane table both the LM decode server
(launch/serve.py) and the event-stream engine (repro.stream.engine)
batch on."""
import pytest

from repro.serve.slots import SlotManager


class TestSlotManager:
    def test_admit_until_full(self):
        m = SlotManager(3)
        assert m.capacity == 3 and m.is_empty() and not m.is_full()
        assert [m.admit(f"r{i}") for i in range(3)] == [0, 1, 2]
        assert m.is_full() and m.n_free == 0 and m.n_occupied == 3
        assert m.admit("overflow") is None          # full → rejected
        assert m.active_mask() == [True, True, True]

    def test_release_frees_lowest_lane_for_reuse(self):
        m = SlotManager(2)
        m.admit("a"), m.admit("b")
        assert m.release(0) == "a"
        assert m.active_mask() == [False, True]
        assert m.admit("c") == 0                    # lowest free lane
        assert m.get(0) == "c" and m.get(1) == "b"

    def test_release_empty_lane_raises(self):
        m = SlotManager(2)
        with pytest.raises(ValueError, match="already free"):
            m.release(1)

    def test_admit_none_raises(self):
        with pytest.raises(ValueError, match="None"):
            SlotManager(1).admit(None)

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            SlotManager(0)

    def test_refill_pops_queue_in_order(self):
        m = SlotManager(2)
        queue = ["a", "b", "c"]
        placed = m.refill(queue)
        assert placed == [(0, "a"), (1, "b")]
        assert queue == ["c"]                       # only admitted popped
        assert m.refill(queue) == []                # full → no-op
        m.release(1)
        assert m.refill(queue) == [(1, "c")] and queue == []

    def test_occupied_iterates_lane_order(self):
        m = SlotManager(3)
        m.admit("a"), m.admit("b"), m.admit("c")
        m.release(1)
        assert list(m.occupied()) == [(0, "a"), (2, "c")]

    def test_continuous_recycling(self):
        """More items than capacity complete via release+refill — the
        serving pattern both consumers run."""
        m = SlotManager(2)
        queue = [f"r{i}" for i in range(7)]
        done = []
        steps = 0
        while queue or not m.is_empty():
            m.refill(queue)
            # every occupied lane "finishes" this step
            for lane, item in list(m.occupied()):
                done.append(m.release(lane))
            steps += 1
            assert steps < 20
        assert done == [f"r{i}" for i in range(7)]
