"""Batched multi-circuit sweep engine: kernel/scan/curvefit parity across
the stacked circuit-config axis, retention monotonicity (paper Fig 4), and
the end-to-end grid artifact."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import leakage, p2m_layer
from repro.core import sweep as engine
from repro.core.leakage import CircuitConfig, LeakageConfig
from repro.core.p2m_layer import P2MConfig, p2m_init

CIRCUITS = (CircuitConfig.BASIC, CircuitConfig.SWITCH,
            CircuitConfig.NULLIFIED)


def _setup(n_sub=3, t_intg=10.0):
    cfg = P2MConfig(out_channels=6, t_intg_ms=t_intg, n_sub=n_sub)
    params = p2m_init(jax.random.PRNGKey(0), cfg)
    ev = jax.random.poisson(jax.random.PRNGKey(1), 0.4,
                            (2, 2, n_sub, 12, 12, 2)).astype(jnp.float32)
    leak_cfgs = tuple(LeakageConfig(circuit=c) for c in CIRCUITS)
    return cfg, params, ev, leak_cfgs


class TestStackedParity:
    """The batched multi-circuit paths must reproduce the per-config
    single-circuit paths bit-for-bit (up to float tolerance) — the engine
    may never change the physics, only batch it."""

    def test_kernel_matches_per_config_scan(self):
        cfg, params, ev, leak_cfgs = _setup()
        cfg_k = dataclasses.replace(cfg, mode="kernel")
        s_m, v_m = p2m_layer.p2m_apply_stacked(params, ev, cfg_k, leak_cfgs)
        assert v_m.shape[0] == len(leak_cfgs)
        for i, lc in enumerate(leak_cfgs):
            cfg_i = dataclasses.replace(cfg, mode="scan", leak=lc)
            s_i, v_i = p2m_layer.p2m_apply(params, ev, cfg_i)
            np.testing.assert_allclose(np.asarray(v_m[i]), np.asarray(v_i),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"circuit {lc.circuit.value}")
            np.testing.assert_array_equal(np.asarray(s_m[i]),
                                          np.asarray(s_i))

    def test_scan_stacked_matches_per_config_scan(self):
        cfg, params, ev, leak_cfgs = _setup()
        cfg_s = dataclasses.replace(cfg, mode="scan")
        _, v_m = p2m_layer.p2m_apply_stacked(params, ev, cfg_s, leak_cfgs)
        for i, lc in enumerate(leak_cfgs):
            cfg_i = dataclasses.replace(cfg_s, leak=lc)
            _, v_i = p2m_layer.p2m_apply(params, ev, cfg_i)
            np.testing.assert_allclose(np.asarray(v_m[i]), np.asarray(v_i),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"circuit {lc.circuit.value}")

    def test_curvefit_stacked_matches_per_config_curvefit(self):
        cfg, params, ev, leak_cfgs = _setup()
        _, v_m = p2m_layer.p2m_forward_curvefit_stacked(params, ev, cfg,
                                                        leak_cfgs)
        for i, lc in enumerate(leak_cfgs):
            cfg_i = dataclasses.replace(cfg, mode="curvefit", leak=lc)
            _, v_i = p2m_layer.p2m_apply(params, ev, cfg_i)
            np.testing.assert_allclose(np.asarray(v_m[i]), np.asarray(v_i),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"circuit {lc.circuit.value}")

    def test_multi_kernel_matches_multi_ref(self):
        from repro.kernels.p2m_conv import ops
        cfg, params, ev, leak_cfgs = _setup()
        s_k, v_k = ops.p2m_conv_multi(params, ev, cfg, leak_cfgs)
        s_r, v_r = ops.p2m_conv_multi(params, ev, cfg, leak_cfgs,
                                      use_ref=True)
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))

    def test_mismatch_axis_orders_nullified_retention(self):
        """Smaller nullifier mismatch → longer tau → less drift."""
        w = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 2, 8))
        cfgs = tuple(leakage.with_mismatch(
            LeakageConfig(circuit=CircuitConfig.NULLIFIED), m)
            for m in (0.01, 0.06, 0.2))
        surf = np.asarray(leakage.retention_surface(w, cfgs, (10.0,)))
        assert surf[0, 0] < surf[1, 0] < surf[2, 0]


class TestRetentionSurface:
    def test_shape(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 8))
        cfgs = leakage.paper_circuits()
        surf = leakage.retention_surface(w, cfgs, (1.0, 10.0, 100.0))
        assert surf.shape == (3, 3)

    @pytest.mark.parametrize("circuit", [CircuitConfig.BASIC,
                                         CircuitConfig.SWITCH])
    def test_retention_error_grows_with_t_intg(self, circuit):
        """Fig 4: for the leaky circuits (a) and (b) the retention error is
        strictly increasing in T_INTG."""
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 8))
        surf = np.asarray(leakage.retention_surface(
            w, (LeakageConfig(circuit=circuit),), (1.0, 3.0, 10.0, 30.0)))[0]
        assert np.all(np.diff(surf) > 0), surf


class TestGridExpansion:
    def test_mismatch_only_expands_nullified(self):
        grid = engine.SweepGrid(null_mismatch=(0.02, 0.06))
        cfgs = engine.expand_leak_configs(grid, LeakageConfig())
        labels = [engine.config_label(c) for c in cfgs]
        assert labels == ["a", "b", "c@m=0.02", "c@m=0.06"]

    def test_single_circuit(self):
        grid = engine.SweepGrid(circuits=(CircuitConfig.SWITCH,))
        cfgs = engine.expand_leak_configs(grid, LeakageConfig())
        assert len(cfgs) == 1 and cfgs[0].circuit == CircuitConfig.SWITCH

    def test_v_threshold_axis_expands_every_circuit(self):
        grid = engine.SweepGrid(null_mismatch=(), v_threshold=(0.01, 0.02))
        cfgs = engine.expand_leak_configs(grid, LeakageConfig())
        labels = [engine.config_label(c) for c in cfgs]
        # circuit (c) always prints its mismatch (the PR-1 label contract:
        # the un-swept base value still describes the variant's circuit)
        assert labels == ["a@vt=0.01", "a@vt=0.02", "b@vt=0.01", "b@vt=0.02",
                          "c@m=0.06@vt=0.01", "c@m=0.06@vt=0.02"]

    def test_combined_axes_expand_in_registry_order(self):
        """mismatch × v_threshold × sigma compose; mismatch still only
        multiplies circuit (c), and the label suffixes follow registry
        order (m, vt, s)."""
        grid = engine.SweepGrid(circuits=(CircuitConfig.NULLIFIED,),
                                null_mismatch=(0.02, 0.06),
                                v_threshold=(0.01,), sigma=(0.0, 0.1))
        cfgs = engine.expand_leak_configs(grid, LeakageConfig())
        labels = [engine.config_label(c) for c in cfgs]
        assert labels == ["c@m=0.02@vt=0.01", "c@m=0.02@vt=0.01@s=0.1",
                          "c@m=0.06@vt=0.01", "c@m=0.06@vt=0.01@s=0.1"]

    def test_sigma_zero_is_identity(self):
        """sigma = 0 must reproduce the unperturbed circuit EXACTLY: for the
        weight-independent SWITCH circuit the closed-form tau is the config
        constant tau_b_ms, so the sigma term must multiply by exactly 1.0
        (compared against the sigma-free closed form, not a second run of
        the same code path)."""
        w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 2, 8))
        base = LeakageConfig(circuit=CircuitConfig.SWITCH, sigma=0.0)
        lk = leakage.kernel_leak_params(w, base)
        np.testing.assert_array_equal(
            np.asarray(lk.tau_ms), np.full(8, base.tau_b_ms, np.float32))

    def test_sigma_spreads_taus_log_normally(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 2, 8))
        base = LeakageConfig(circuit=CircuitConfig.SWITCH)
        lk0 = leakage.kernel_leak_params(w, base)
        lks = leakage.kernel_leak_params(
            w, dataclasses.replace(base, sigma=0.3))
        ratio = np.asarray(lks.tau_ms) / np.asarray(lk0.tau_ms)
        assert not np.allclose(ratio, 1.0)          # taus actually move
        # shared frozen draw: doubling sigma squares each filter's ratio
        lks2 = leakage.kernel_leak_params(
            w, dataclasses.replace(base, sigma=0.6))
        np.testing.assert_allclose(np.asarray(lks2.tau_ms)
                                   / np.asarray(lk0.tau_ms),
                                   ratio ** 2, rtol=1e-5)

    def test_unknown_axis_raises(self):
        from repro.core import variant_grid
        with pytest.raises(KeyError):
            variant_grid.axis("not-an-axis")
        assert variant_grid.axis("v-threshold").name == "v_threshold"


@pytest.fixture(scope="module")
def grid_result():
    from repro.core.codesign import P2MModelConfig, SweepConfig
    from repro.core.snn import SpikingCNNConfig
    from repro.data import events as ev_mod

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=120.0),
        backbone=SpikingCNNConfig(channels=(8, 8, 8, 8), input_hw=(16, 16),
                                  fc_hidden=16, n_classes=5,
                                  first_layer_external=True),
        coarse_window_ms=120.0)
    data = ev_mod.EventStreamConfig(name="gesture", height=16, width=16,
                                    n_classes=5, duration_ms=240.0)
    sweep_cfg = SweepConfig(batch_size=2, pretrain_steps=2, finetune_steps=1,
                            eval_batches=1)
    grid = engine.SweepGrid(t_intg_grid_ms=(30.0, 120.0))
    return engine.run_grid(data, model, sweep_cfg, grid,
                           log=lambda *_: None)


class TestGridRun:
    def test_one_record_per_cell(self, grid_result):
        assert len(grid_result.records) == 3 * 2   # 3 circuits × 2 T
        cells = {(r["label"], r["t_intg_ms"]) for r in grid_result.records}
        assert len(cells) == 6

    def test_record_keys(self, grid_result):
        for r in grid_result.records:
            for k in ("label", "circuit", "null_mismatch", "t_intg_ms",
                      "accuracy", "train_time_s", "bandwidth_norm",
                      "backend_energy_p2m_j", "energy_improvement",
                      "retention_err_v", "train_time_norm"):
                assert k in r, k

    def test_normalization_per_config(self, grid_result):
        """Every circuit config's longest-T point is its own 1x reference."""
        for lab in grid_result.labels:
            rs = [r for r in grid_result.records if r["label"] == lab]
            base = max(rs, key=lambda r: r["t_intg_ms"])
            assert abs(base["bandwidth_norm"] - 1.0) < 1e-6
            assert abs(base["train_time_norm"] - 1.0) < 1e-6

    def test_artifact_schema_and_json(self, grid_result):
        art = grid_result.to_artifact()
        assert art["schema"] == engine.SCHEMA_V3
        assert art["grid"]["labels"] == list(grid_result.labels)
        assert set(art["retention"]["mean_abs_error_v"]) == set(
            grid_result.labels)
        json.dumps(art)   # must be serializable as-is

    def test_records_carry_variant_dict(self, grid_result):
        """v3: every record resolves every registered axis, including the
        v_threshold default and the outer-loop n_sub."""
        for r in grid_result.records:
            var = r["variant"]
            assert var["circuit"] == r["circuit"]
            assert var["null_mismatch"] == r["null_mismatch"]
            assert var["v_threshold"] == pytest.approx(
                leakage.DEFAULT_V_THRESHOLD)
            assert var["sigma"] == 0.0
            assert var["n_sub"] == r["n_sub"]

    def test_retention_ordering_in_records(self, grid_result):
        """Config (c) retains better than (b) better than (a) at 30 ms."""
        at_t = {r["label"]: r["retention_err_v"]
                for r in grid_result.records if r["t_intg_ms"] == 30.0}
        assert at_t["c@m=0.06"] < at_t["b"] < at_t["a"]
