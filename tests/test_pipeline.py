"""Pipeline parallelism: correctness vs the sequential model, via a
subprocess with 8 forced host devices (pipe=2/4 meshes need >1 device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, smoke_variant
    from repro.models import lm
    from repro.train import pipeline as pp

    cfg = smoke_variant(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, n_layers=4, compute_dtype="float32",
                              param_dtype="float32")
    mesh = jax.make_mesh((4, 2, 1), ("pipe", "data", "model"))

    key = jax.random.PRNGKey(0)
    params = pp.stage_params(key, cfg, n_stages=4)
    B, T = 8, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)

    with mesh:
        loss_pp = float(pp.pipeline_apply(params, tokens, labels, cfg, mesh,
                                          n_microbatches=4))

    # sequential reference: same params, unstacked
    params_seq = dict(params)
    params_seq["blocks"] = jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:]), params["blocks"])
    loss_ref, _ = lm.loss_fn(params_seq, {"tokens": tokens,
                                          "labels": labels}, cfg)
    loss_ref = float(loss_ref)
    print("PP", loss_pp, "REF", loss_ref)
    assert abs(loss_pp - loss_ref) / abs(loss_ref) < 1e-4, (loss_pp, loss_ref)

    # gradient flows through the schedule (AD through ppermute)
    with mesh:
        step = pp.build_pp_train_step(cfg, mesh, n_microbatches=4, lr=1e-2)
        p2, l1 = step(params, tokens, labels)
        _, l2 = step(p2, tokens, labels)
    print("L1", float(l1), "L2", float(l2))
    assert float(l2) < float(l1), (float(l1), float(l2))
    print("OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential_and_trains(tmp_path):
    script = tmp_path / "pp_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
