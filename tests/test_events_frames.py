"""Coverage for the frame-manipulation helpers in repro.data.events:
``events_to_frames`` (sub-slot collapse) and ``refine_slots`` (re-bin onto
a coarser T grid) — shapes, polarity/count conservation, and the
refine-then-rebin round trip the sweep engine's T_INTG semantics rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import events as ev_mod


@pytest.fixture(scope="module")
def batch():
    """[B=2, T=8, n_sub=3, H=12, W=12, 2] synthetic event counts."""
    cfg = ev_mod.dvs_gesture_like(12)
    ev, labels = ev_mod.sample_batch(jax.random.PRNGKey(7), cfg, 2, 250.0,
                                     n_sub=3)
    assert ev.shape == (2, 8, 3, 12, 12, 2)
    return ev


class TestEventsToFrames:
    def test_shape(self, batch):
        frames = ev_mod.events_to_frames(batch)
        assert frames.shape == (2, 8, 12, 12, 2)

    def test_counts_conserved_per_polarity(self, batch):
        """Collapsing sub-slots must conserve ON and OFF counts
        separately — polarity is a physical channel, not an average."""
        frames = ev_mod.events_to_frames(batch)
        for pol in (0, 1):
            np.testing.assert_allclose(
                np.asarray(frames[..., pol].sum()),
                np.asarray(batch[..., pol].sum()), rtol=1e-6)

    def test_pixelwise_sum(self, batch):
        np.testing.assert_allclose(np.asarray(ev_mod.events_to_frames(batch)),
                                   np.asarray(batch.sum(axis=2)), rtol=1e-6)


class TestRefineSlots:
    def test_shape(self, batch):
        out = ev_mod.refine_slots(batch, 4)
        # T 8→2, n_sub 3→12: same total fine slots, coarser T grid
        assert out.shape == (2, 2, 12, 12, 12, 2)

    def test_factor_must_divide(self, batch):
        with pytest.raises(AssertionError):
            ev_mod.refine_slots(batch, 3)      # 8 % 3 != 0

    def test_count_conserving(self, batch):
        out = ev_mod.refine_slots(batch, 2)
        np.testing.assert_allclose(float(out.sum()), float(batch.sum()),
                                   rtol=1e-6)

    def test_refine_then_rebin_round_trip(self, batch):
        """events_to_frames(refine_slots(ev, f))[b, i] must equal the sum
        of the original frames over slot block [i*f, (i+1)*f) — the same
        stream integrated at a longer T_INTG."""
        f = 4
        frames = np.asarray(ev_mod.events_to_frames(batch))     # [B, 8, ...]
        coarse = np.asarray(
            ev_mod.events_to_frames(ev_mod.refine_slots(batch, f)))
        expect = frames.reshape(frames.shape[0], frames.shape[1] // f, f,
                                *frames.shape[2:]).sum(axis=2)
        np.testing.assert_allclose(coarse, expect, rtol=1e-6)

    def test_identity_factor(self, batch):
        np.testing.assert_array_equal(
            np.asarray(ev_mod.refine_slots(batch, 1)), np.asarray(batch))
