"""Online streaming inference subsystem tests (repro.stream).

The load-bearing guarantee: replaying a fixture recording through the
online leak-aware accumulator and reading out at every T_INTG boundary
matches the offline path — ``data.binning.bin_chunks`` frames through
the offline batched forward (``repro.stream.deploy.offline_forward``) —
within tight tolerance, across ≥2 T_INTG values, ≥2 circuit variants,
and BOTH phase-2 protocols' deployed checkpoints."""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.leakage import CircuitConfig  # noqa: E402
from repro.data import fixtures, sources  # noqa: E402
from repro.data.binning import bin_chunks, slot_us_for  # noqa: E402
from repro.data.formats import concat_chunks  # noqa: E402
from repro.stream import deploy as deploy_mod  # noqa: E402
from repro.stream.engine import STATS_SCHEMA, StreamEngine  # noqa: E402

HW = 16


@pytest.fixture(scope="module")
def fixture_root(tmp_path_factory):
    root = tmp_path_factory.mktemp("dvs128-stream")
    fixtures.make_dvs128_fixture(root, n_recordings=1,
                                 trials_per_recording=4)
    return root


@pytest.fixture(scope="module")
def file_source(fixture_root):
    return sources.resolve_dataset("dvs128", hw=HW,
                                   data_root=str(fixture_root), split="all")


@pytest.fixture(scope="module")
def trained(fixture_root, tmp_path_factory):
    """One tiny sweep over 2 circuits × 2 T_INTG with keep_params, both
    protocols — the deployment menu every parity case slices from."""
    out = tmp_path_factory.mktemp("deploy")
    return deploy_mod.train_and_deploy(
        out, dataset="dvs128", data_root=str(fixture_root), hw=HW,
        protocols=("frozen", "unfrozen"), smoke=True,
        t_intg_grid_ms=(100.0, 1000.0),
        circuits=(CircuitConfig.BASIC, CircuitConfig.NULLIFIED),
        log=lambda *_: None)


def _offline_frames(source, index: int, t_intg_ms: float, n_sub: int
                    ) -> np.ndarray:
    """The OFFLINE binning of one recording: [n_slots, n_sub, H, W, 2]."""
    n_slots = source.n_slots(t_intg_ms)
    slot_us = slot_us_for(t_intg_ms, n_sub)
    frames = bin_chunks([source.sample_events(index)],
                        n_total=n_slots * n_sub, slot_us=slot_us,
                        sensor_hw=source.sensor_hw, out_hw=(HW, HW))
    return frames.reshape(n_slots, n_sub, HW, HW, 2)


class _PinnedSource:
    """Source wrapper replaying a FIXED sample sequence (round-robin) —
    so the parity tests know exactly which recording each serving lane
    streamed."""

    def __init__(self, src, indices):
        self._src = src
        self._indices = list(indices)
        self._i = 0
        for attr in ("name", "height", "width", "n_classes", "duration_ms",
                     "sensor_hw"):
            setattr(self, attr, getattr(src, attr))

    def n_slots(self, t_intg_ms):
        return self._src.n_slots(t_intg_ms)

    def iter_event_chunks(self, key, *, chunk_us, slot_us=None):
        idx = self._indices[self._i % len(self._indices)]
        self._i += 1
        return self._src.iter_event_chunks(key, chunk_us=chunk_us,
                                           slot_us=slot_us, index=idx)


# ---------------------------------------------------------------------------
# replay layer
# ---------------------------------------------------------------------------

class TestReplay:
    def test_file_replay_rebins_to_offline_frames(self, file_source):
        """Chunk-by-chunk re-binning of the replayed stream reproduces
        the offline binner's frames exactly (same slot grid, same
        sensor→model downscale)."""
        t_intg, n_sub = 100.0, 2
        slot_us = slot_us_for(t_intg, n_sub)
        chunk_us = slot_us  # one chunk per fine sub-slot
        label, chunks = file_source.iter_event_chunks(
            jax.random.PRNGKey(0), chunk_us=chunk_us, index=1)
        assert label == file_source.samples[1].label
        offline = _offline_frames(file_source, 1, t_intg, n_sub)
        n_total = offline.shape[0] * n_sub
        got = []
        for i, c in enumerate(chunks):
            got.append(bin_chunks([c], n_total=1, slot_us=slot_us,
                                  sensor_hw=file_source.sensor_hw,
                                  out_hw=(HW, HW), t0_us=i * chunk_us)[0])
        assert len(got) == n_total          # empty chunks yielded too
        np.testing.assert_array_equal(
            np.stack(got).reshape(offline.shape), offline)

    def test_file_replay_conserves_events(self, file_source):
        ev = file_source.sample_events(0)
        _, chunks = file_source.iter_event_chunks(
            jax.random.PRNGKey(0), chunk_us=50_000, index=0)
        replayed = concat_chunks(chunks)
        dur_us = int(file_source.duration_ms * 1000)
        in_window = int((ev.t < dur_us).sum())
        assert len(replayed) == in_window > 0
        assert (np.diff(replayed.t) >= 0).all()    # time-ordered replay

    def test_synthetic_replay_chunks(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        label, chunks = src.iter_event_chunks(
            jax.random.PRNGKey(3), chunk_us=100_000, slot_us=50_000)
        chunks = list(chunks)
        assert 0 <= label < src.n_classes
        assert len(chunks) == 20            # 2000 ms / 100 ms
        total = sum(len(c) for c in chunks)
        assert total > 0
        for i, c in enumerate(chunks):      # timestamps inside the chunk
            if len(c):
                assert c.t.min() >= i * 100_000
                assert c.t.max() < (i + 1) * 100_000

    def test_bad_chunk_width_raises(self, file_source):
        with pytest.raises(ValueError, match="does not divide"):
            file_source.iter_event_chunks(jax.random.PRNGKey(0),
                                          chunk_us=300_000)


# ---------------------------------------------------------------------------
# backbone streaming step parity (snn)
# ---------------------------------------------------------------------------

def test_backbone_stream_step_matches_batched():
    from repro.core import snn

    cfg = snn.SpikingCNNConfig(channels=(8, 16, 16, 16), input_hw=(HW, HW),
                               fc_hidden=32, n_classes=5,
                               first_layer_external=True)
    key = jax.random.PRNGKey(0)
    params, state = snn.spiking_cnn_init(key, cfg)
    B, T = 2, 6
    x = jax.random.poisson(jax.random.PRNGKey(1),
                           1.0, (B, T, HW // 2, HW // 2, 8)).astype(
                               jnp.float32)
    logits_ref, _, _ = snn.spiking_cnn_apply(params, state, x, cfg,
                                             train=False)
    mem = snn.spiking_cnn_stream_init(cfg, B)
    acc = jnp.zeros((B, cfg.n_classes))
    for t in range(T):
        lt, mem = snn.spiking_cnn_stream_step(params, state, mem,
                                              x[:, t], cfg)
        acc = acc + lt
    np.testing.assert_allclose(np.asarray(acc / T), np.asarray(logits_ref),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# streaming vs offline parity — the acceptance bar
# ---------------------------------------------------------------------------

class TestStreamingOfflineParity:
    def _parity_case(self, trained, file_source, tmp_path, protocol,
                     record, capacity=2, use_kernel=False):
        result = trained["results"][protocol]
        ckpt = tmp_path / f"ckpt_{protocol}_{record['label']}_" \
                          f"{record['t_intg_ms']:g}"
        deploy_mod.deploy_from_sweep(result, _model_of(trained), record,
                                     ckpt)
        dep = deploy_mod.load_deployment(ckpt)
        n_sub = dep.model_cfg.p2m.n_sub
        indices = [0, 1, 2]
        frames = np.stack([_offline_frames(file_source, i,
                                           record["t_intg_ms"], n_sub)
                           for i in indices])
        off = deploy_mod.offline_forward(dep, jnp.asarray(frames))
        off_logits = np.asarray(off["logits"])

        engine = StreamEngine(dep, capacity=capacity,
                              use_kernel=use_kernel)
        report = engine.serve(_PinnedSource(file_source, indices),
                              len(indices), seed=0)
        assert len(report.results) == len(indices)
        by_id = {r.stream_id: r for r in report.results}
        for k, idx in enumerate(indices):
            r = by_id[k]
            assert r.label == file_source.samples[idx].label
            np.testing.assert_allclose(
                np.asarray(r.logits), off_logits[k], rtol=1e-5, atol=1e-5,
                err_msg=f"{protocol} {record['label']} "
                        f"T={record['t_intg_ms']} stream {k}")
            assert r.prediction == int(np.argmax(off_logits[k]))
            assert r.n_readouts == file_source.n_slots(record["t_intg_ms"])

    @pytest.mark.parametrize("protocol", ["frozen", "unfrozen"])
    def test_parity_all_cells(self, trained, file_source, tmp_path,
                              protocol):
        """Every (circuit, T_INTG) record of the trained grid — 2
        circuits × 2 T_INTG — serves online with logits matching the
        offline batched forward."""
        records = trained["results"][protocol].records
        assert len(records) == 4
        assert {r["circuit"] for r in records} == {"a", "c"}
        assert {r["t_intg_ms"] for r in records} == {100.0, 1000.0}
        for record in records:
            self._parity_case(trained, file_source, tmp_path, protocol,
                              record)

    def test_parity_use_kernel_all_cells(self, trained, file_source,
                                         tmp_path):
        """The fused stream_fold kernel path (use_kernel=True) holds the
        SAME offline-parity contract across the full 2 circuits ×
        2 T_INTG grid — the kernel is bit-exact with the scan fold, so
        the telescoping to the offline curve-fit forward survives."""
        records = trained["results"]["frozen"].records
        for record in records:
            self._parity_case(trained, file_source, tmp_path / "kern",
                              "frozen", record, use_kernel=True)

    def test_parity_capacity_one_recycles(self, trained, file_source,
                                          tmp_path):
        """Sequential lane reuse (capacity 1 < streams) must not leak
        state across streams: parity still holds for every stream."""
        record = trained["results"]["frozen"].records[0]
        self._parity_case(trained, file_source, tmp_path / "c1", "frozen",
                          record, capacity=1)

    def test_spike_level_parity(self, trained, file_source, tmp_path):
        """Window-by-window layer-1 spike maps from the online readout
        equal the offline forward's bit-for-bit (one cell, driven through
        the low-level fold/readout steps)."""
        record = deploy_mod.select_record(
            trained["results"]["frozen"].records, t_intg_ms=100.0,
            label="c@m=0.06")
        ckpt = tmp_path / "spike_ckpt"
        deploy_mod.deploy_from_sweep(trained["results"]["frozen"],
                                     _model_of(trained), record, ckpt)
        dep = deploy_mod.load_deployment(ckpt)
        n_sub = dep.model_cfg.p2m.n_sub
        frames = _offline_frames(file_source, 0, 100.0, n_sub)
        off = deploy_mod.offline_forward(dep, jnp.asarray(frames[None]))
        off_spikes = np.asarray(off["spikes"][0])

        engine = StreamEngine(dep, capacity=2)
        fns = engine.fns
        state = fns.init_state()
        active = jnp.asarray([True, False])
        group = dep.model_cfg.coarsen_group()
        n_slots = frames.shape[0]
        on_spikes = []
        for t in range(n_slots):
            for c in range(engine.chunks_per_window):
                fr = np.zeros((2, engine.chunk_slots, HW, HW, 2),
                              np.float32)
                lo = c * engine.chunk_slots
                fr[0] = frames[t, lo:lo + engine.chunk_slots]
                state = fns.fold(state, jnp.asarray(fr), active)
            cm = jnp.asarray([(t + 1) % group == 0, False])
            state, out = fns.readout(state, active, cm)
            on_spikes.append(np.asarray(out["spikes"][0]))
        np.testing.assert_array_equal(np.stack(on_spikes), off_spikes)


def _model_of(trained) -> object:
    """The base model config the sweep trained (rebuild from any
    checkpoint's embedded config — cell fields are re-pinned by
    deploy_from_sweep)."""
    dep = deploy_mod.load_deployment(
        next(iter(trained["checkpoints"].values())))
    return dep.model_cfg


# ---------------------------------------------------------------------------
# deployment handshake
# ---------------------------------------------------------------------------

class TestDeployment:
    def test_checkpoint_roundtrip(self, trained):
        for proto, ckpt in trained["checkpoints"].items():
            dep = deploy_mod.load_deployment(ckpt, trained["artifact"])
            assert dep.protocol == proto
            assert dep.record == trained["records"][proto]
            v = dep.record["variant"]
            leak = dep.model_cfg.p2m.leak
            assert leak.circuit.value == v["circuit"]
            assert leak.v_threshold == v["v_threshold"]
            assert dep.model_cfg.p2m.t_intg_ms == dep.record["t_intg_ms"]

    def test_artifact_cross_check_rejects_foreign_record(self, trained,
                                                         tmp_path):
        import json
        art = json.loads(trained["artifact"].read_text())
        for r in art["records"]:
            r["t_intg_ms"] = 7.0          # no record matches anymore
        bad = tmp_path / "foreign.json"
        bad.write_text(json.dumps(art))
        ckpt = next(iter(trained["checkpoints"].values()))
        with pytest.raises(ValueError, match="different runs"):
            deploy_mod.load_deployment(ckpt, bad)

    def test_select_record_filters_and_ranks(self, trained):
        recs = trained["results"]["frozen"].records
        best = deploy_mod.select_record(recs)
        assert best["accuracy"] == max(r["accuracy"] for r in recs)
        only_t = deploy_mod.select_record(recs, t_intg_ms=1000.0)
        assert only_t["t_intg_ms"] == 1000.0
        with pytest.raises(ValueError, match="no sweep record"):
            deploy_mod.select_record(recs, t_intg_ms=123.0)

    def test_non_deploy_checkpoint_rejected(self, tmp_path):
        from repro.checkpoint import store
        store.save_checkpoint(tmp_path, 0, {"w": np.zeros(3)}, {})
        with pytest.raises(ValueError, match="not a streaming deployment"):
            deploy_mod.load_deployment(tmp_path)

    def test_select_record_deterministic_tie_break(self):
        """Equal-accuracy records must pick the SAME winner regardless of
        list order and of the variant-dict key order — registry compat
        keys (and the served weights) must be reproducible across
        processes with different dict orderings."""
        import random
        a = {"label": "c@m=0.06", "protocol": "frozen", "t_intg_ms": 100.0,
             "n_sub": 2, "accuracy": 0.5,
             "variant": {"circuit": "c", "mismatch": 0.06}}
        b = {"label": "a", "protocol": "frozen", "t_intg_ms": 100.0,
             "n_sub": 2, "accuracy": 0.5, "variant": {"circuit": "a"}}
        c = {"label": "a", "protocol": "unfrozen", "t_intg_ms": 100.0,
             "n_sub": 2, "accuracy": 0.5, "variant": {"circuit": "a"}}
        # same content, reversed variant-dict insertion order
        a2 = dict(a, variant={"mismatch": 0.06, "circuit": "c"})
        pools = [[a, b, c], [c, b, a], [b, a2, c], [c, a2, b]]
        winners = [deploy_mod.select_record(p) for p in pools]
        assert all(w["label"] == winners[0]["label"]
                   and w["protocol"] == winners[0]["protocol"]
                   for w in winners)
        # label then protocol break the tie: "a"/frozen sorts first
        assert winners[0]["label"] == "a"
        assert winners[0]["protocol"] == "frozen"
        # accuracy still dominates any tie-break field
        best = dict(b, accuracy=0.9)
        assert deploy_mod.select_record([a, best, c]) is best
        # untrained records (accuracy=None) sort without crashing
        untrained = [dict(a, accuracy=None), dict(b, accuracy=None)]
        random.Random(0).shuffle(untrained)
        assert deploy_mod.select_record(untrained)["label"] == "a"

    def _tamper(self, ckpt, mutate):
        """Rewrite the checkpoint's extras via ``mutate(extra) -> extra``
        (the on-disk corruption load_deployment must refuse)."""
        import json as json_mod
        from pathlib import Path
        (step_dir,) = [p for p in Path(ckpt).iterdir()
                       if p.name.startswith("step_") and p.is_dir()]
        idx = json_mod.loads((step_dir / "index.json").read_text())
        idx["extra"] = mutate(idx["extra"])
        (step_dir / "index.json").write_text(json_mod.dumps(idx))

    @pytest.fixture()
    def ckpt_copy(self, trained, tmp_path):
        import shutil
        src = trained["checkpoints"]["frozen"]
        dst = tmp_path / "ckpt_tampered"
        shutil.copytree(src, dst)
        return dst

    def test_load_rejects_missing_extras(self, ckpt_copy):
        self._tamper(ckpt_copy,
                     lambda e: {k: v for k, v in e.items() if k != "record"})
        with pytest.raises(ValueError, match="corrupt"):
            deploy_mod.load_deployment(ckpt_copy)

    def test_load_rejects_record_config_mismatch(self, ckpt_copy):
        def mutate(e):
            e["record"] = dict(e["record"], t_intg_ms=7.0)
            return e
        self._tamper(ckpt_copy, mutate)
        with pytest.raises(ValueError, match="mismatch"):
            deploy_mod.load_deployment(ckpt_copy)

    def test_load_rejects_variant_circuit_mismatch(self, ckpt_copy):
        def mutate(e):
            v = dict(e["record"]["variant"])
            v["circuit"] = "b" if v.get("circuit") != "b" else "a"
            e["record"] = dict(e["record"], variant=v)
            return e
        self._tamper(ckpt_copy, mutate)
        with pytest.raises(ValueError, match="wrong leak numerics"):
            deploy_mod.load_deployment(ckpt_copy)

    def test_load_rejects_malformed_model_config(self, ckpt_copy):
        def mutate(e):
            e["model_config"] = {"p2m": {"nonsense": True}}
            return e
        self._tamper(ckpt_copy, mutate)
        with pytest.raises(ValueError, match="malformed"):
            deploy_mod.load_deployment(ckpt_copy)

    def test_registry_meta_roundtrips(self, trained):
        """train_and_deploy stamps dataset/sensor_hw registry metadata
        into the checkpoint and load_deployment restores it."""
        for ckpt in trained["checkpoints"].values():
            dep = deploy_mod.load_deployment(ckpt)
            assert dep.meta["dataset"] == "dvs128"
            assert tuple(dep.meta["sensor_hw"]) == (128, 128)


# ---------------------------------------------------------------------------
# engine lifecycle + serving-stats artifact
# ---------------------------------------------------------------------------

class TestEngineLifecycle:
    def test_more_streams_than_lanes(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        dep = _fresh_dep(src)
        engine = StreamEngine(dep, capacity=2)
        report = engine.serve(src, 5, seed=0)
        assert len(report.results) == 5 > engine.capacity
        n_windows = src.n_slots(dep.t_intg_ms)
        assert all(r.n_readouts == n_windows for r in report.results)
        assert all(r.n_coarse_frames ==
                   n_windows // dep.model_cfg.coarsen_group()
                   for r in report.results)
        # continuous batching: later streams admitted at later windows
        assert max(r.admitted_window for r in report.results) > 0
        assert report.total_readouts == 5 * n_windows

    def test_stats_artifact_schema(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        dep = _fresh_dep(src)
        report = StreamEngine(dep, capacity=2).serve(src, 2, seed=1)
        art = report.to_artifact()
        assert art["schema"] == STATS_SCHEMA
        for key in ("deployed", "n_streams", "capacity", "accuracy",
                    "streams", "latency_ms", "throughput"):
            assert key in art
        assert {"readout_p50", "readout_p99", "readout_mean", "fold_p50",
                "fold_p99"} <= set(art["latency_ms"])
        assert {"wall_s", "events_per_s", "events_per_s_per_device",
                "readouts_per_s", "streams_per_s"} <= set(art["throughput"])
        # unsharded serve still carries the v3 sharding block (1 device)
        assert art["sharding"] == {"devices": 1, "bin_workers": 1,
                                   "padded_capacity": 2,
                                   "lanes_per_shard": 2,
                                   "per_shard_admitted": [2]}
        for s in art["streams"]:
            assert {"stream_id", "label", "prediction", "n_events",
                    "n_readouts", "logits", "entry", "entry_uid"} <= set(s)
            assert s["entry"] == "default"   # single-deployment engine
        # v4: single-deployment serving still emits the registry block —
        # one synthetic "default" entry whose ledger covers the fleet
        assert art["admission"]["n_rejected"] == 0
        reg = art["registry"]
        assert reg["max_entries"] == 1 and reg["compat"]
        (row,) = reg["entries"]
        assert row["name"] == "default"
        assert row["n_admitted"] == row["n_finished"] == 2
        assert art["throughput"]["events_per_s"] > 0

    def test_resolution_mismatch_rejected(self):
        src16 = sources.resolve_dataset("synthetic-gesture", hw=HW)
        src20 = sources.resolve_dataset("synthetic-gesture", hw=20)
        dep = _fresh_dep(src16)
        with pytest.raises(ValueError, match="resolution"):
            StreamEngine(dep, capacity=1).serve(src20, 1)

    def test_coarse_group_mismatch_rejected(self):
        """A stream too short for the deployed coarse window (its window
        count not a multiple of the coarsen group) must be rejected, not
        served to a vacuous all-zero prediction."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=600.0)
        dep = _fresh_dep(src)   # T_INTG=200 ms, coarse 1000 ms → group 5
        with pytest.raises(ValueError, match="coarse group"):
            StreamEngine(dep, capacity=1).serve(src, 1)

    def test_bad_chunks_per_window_rejected(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        with pytest.raises(ValueError, match="divide"):
            StreamEngine(_fresh_dep(src), capacity=1, chunks_per_window=3)

    def test_strided_p2m_deployment_serves(self):
        """The charge accumulator must live at the conv OUTPUT resolution
        — a stride-2 in-pixel layer (with the matching backbone
        first_stride) serves without shape errors."""
        from repro.core.codesign import P2MModelConfig
        from repro.core.leakage import LeakageConfig
        from repro.core.p2m_layer import P2MConfig
        from repro.core.snn import SpikingCNNConfig

        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        model = P2MModelConfig(
            p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=200.0,
                          stride=2,
                          leak=LeakageConfig(
                              circuit=CircuitConfig.NULLIFIED)),
            backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(HW, HW),
                                      fc_hidden=32, n_classes=src.n_classes,
                                      first_stride=2,
                                      first_layer_external=True),
            coarse_window_ms=1000.0)
        dep = deploy_mod.fresh_deployment(model, seed=0)
        report = StreamEngine(dep, capacity=2).serve(src, 2, seed=0)
        assert len(report.results) == 2
        assert all(r.n_coarse_frames == 2 for r in report.results)


def _fresh_dep(src):
    from repro.core.codesign import P2MModelConfig
    from repro.core.leakage import LeakageConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=200.0,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16, 16, 16),
                                  input_hw=(HW, HW), fc_hidden=64,
                                  n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)
    return deploy_mod.fresh_deployment(model, seed=0)


def _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0):
    """Small deployment with a short T_INTG so paced runs finish fast."""
    from repro.core.codesign import P2MModelConfig
    from repro.core.leakage import LeakageConfig
    from repro.core.p2m_layer import P2MConfig
    from repro.core.snn import SpikingCNNConfig

    model = P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=t_intg_ms,
                      leak=LeakageConfig(circuit=CircuitConfig.NULLIFIED)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(HW, HW),
                                  fc_hidden=32, n_classes=src.n_classes,
                                  first_layer_external=True),
        coarse_window_ms=coarse_ms)
    return deploy_mod.fresh_deployment(model, seed=0)


# ---------------------------------------------------------------------------
# admission control, pacing, and the v4 stats contract
# ---------------------------------------------------------------------------

def _check_stream_stats():
    import sys
    from pathlib import Path
    tools = Path(__file__).resolve().parents[1] / "tools"
    if str(tools) not in sys.path:
        sys.path.insert(0, str(tools))
    import check_stream_stats
    return check_stream_stats


class _CountingSource:
    """Source wrapper tracking how many replay iterators are OPEN
    (returned by iter_event_chunks and not yet fully consumed) — the
    lazy-admission regression: eager opening would put every offered
    stream live at once."""

    def __init__(self, src):
        self._src = src
        self.n_opened = 0
        self._live = 0
        self.max_live = 0
        for attr in ("name", "height", "width", "n_classes", "duration_ms",
                     "sensor_hw"):
            setattr(self, attr, getattr(src, attr))

    def n_slots(self, t_intg_ms):
        return self._src.n_slots(t_intg_ms)

    def iter_event_chunks(self, key, *, chunk_us, slot_us=None):
        label, chunks = self._src.iter_event_chunks(
            key, chunk_us=chunk_us, slot_us=slot_us)
        n_chunks = int(round(self.duration_ms * 1000 / chunk_us))
        self.n_opened += 1
        self._live += 1
        self.max_live = max(self.max_live, self._live)

        def tracked():
            for i, c in enumerate(chunks):
                if i + 1 == n_chunks:
                    self._live -= 1
                yield c

        return label, tracked()


class TestAdmissionControl:
    def test_lazy_admission_bounds_open_streams(self):
        """Streams are opened at ADMISSION, not offer: with 6 streams on
        2 lanes, at most 2 replay iterators are ever live (the eager bug
        opened all 6 up front)."""
        src = _CountingSource(sources.resolve_dataset(
            "synthetic-gesture", hw=HW, duration_ms=400.0))
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        engine = StreamEngine(dep, capacity=2)
        report = engine.serve(src, 6, seed=0)
        assert len(report.results) == 6
        assert src.n_opened == 6
        assert src.max_live <= engine.capacity
        assert report.max_open_streams <= engine.capacity

    def test_shed_and_deferred_accounting(self):
        """A bounded pending queue sheds offered load beyond
        capacity + max_pending and defers the rest; the artifact ledger
        balances (offered = admitted + shed, admitted all served)."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        engine = StreamEngine(dep, capacity=1)
        report = engine.serve(src, 5, seed=0, max_pending=1)
        # window 0: 1 admitted + 1 pending, the other 3 offers shed
        assert report.n_offered == 5
        assert report.n_admitted == 2
        assert report.n_shed == 3
        assert report.n_deferred == 1
        assert len(report.results) == 2
        art = report.to_artifact()
        assert _check_stream_stats().check(art, 2) == []
        deferred = [s for s in art["streams"]
                    if s["admitted_window"] > s["offered_window"]]
        assert len(deferred) == 1

    def test_offered_rate_staggers_offers(self):
        """offered_rate trickles offers on the replay clock: at 1 stream
        per T_INTG window, stream i is offered at window i; with an
        unbounded pending queue every late offer waits instead of being
        shed."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        rate = 10.0  # streams/s → exactly 1 offer per 100 ms window
        engine = StreamEngine(dep, capacity=2)
        report = engine.serve(src, 4, seed=0, offered_rate=rate)
        by_id = sorted(report.results, key=lambda r: r.stream_id)
        assert [r.offered_window for r in by_id] == [0, 1, 2, 3]
        assert all(r.admitted_window >= r.offered_window for r in by_id)
        assert report.n_shed == 0 and len(report.results) == 4

    def test_bad_admission_args_rejected(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src)
        engine = StreamEngine(dep, capacity=1)
        with pytest.raises(ValueError, match="offered_rate"):
            engine.serve(src, 1, offered_rate=0.0)
        with pytest.raises(ValueError, match="max_pending"):
            engine.serve(src, 1, max_pending=-1)


class TestPacedServing:
    def test_paced_predictions_bit_exact_vs_unpaced(self):
        """Pacing only inserts sleeps: a paced serve of the same seed
        produces bit-identical logits, predictions, event counts, and
        admission/finish windows as the unpaced replay."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        r_fast = StreamEngine(dep, capacity=2).serve(src, 3, seed=0)
        r_paced = StreamEngine(dep, capacity=2).serve(src, 3, seed=0,
                                                      paced=True)
        key = lambda r: r.stream_id  # noqa: E731
        for a, b in zip(sorted(r_fast.results, key=key),
                        sorted(r_paced.results, key=key)):
            assert a.label == b.label
            assert a.prediction == b.prediction
            assert a.n_events == b.n_events
            assert a.admitted_window == b.admitted_window
            assert a.finished_window == b.finished_window
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))
        # unpaced runs carry no deadlines; paced ones one per readout
        assert not r_fast.miss_margin_ms
        assert len(r_paced.miss_margin_ms) == r_paced.total_readouts
        # paced replay holds each window to the wall clock: 3 streams of
        # 4 windows on 2 lanes run windows 0..7, and window 7 cannot
        # start before t_start + 7·t_intg = 0.7 s
        assert r_paced.wall_s >= 7 * 0.1

    def test_paced_artifact_v4_schema_and_zero_misses_unloaded(self):
        """The paced stats artifact passes the v4 schema gate, and an
        UNLOADED run (2 lanes, 200 ms windows, trivial compute) misses no
        deadline."""
        css = _check_stream_stats()
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=200.0, coarse_ms=400.0)
        engine = StreamEngine(dep, capacity=2)
        # warm the per-stream event-generation jit outside the paced run
        engine.serve(src, 2, seed=0)
        report = engine.serve(src, 2, seed=0, paced=True)
        art = report.to_artifact()
        assert art["schema"] == STATS_SCHEMA == "p2m-stream-serving/v5"
        assert css.check(art, 2, paced=True, max_miss_rate=0.0) == []
        ddl = art["deadlines"]
        assert ddl["n_misses"] == 0 and ddl["miss_rate"] == 0.0
        assert ddl["n_deadlines"] == report.total_readouts > 0
        assert ddl["margin_ms"]["max"] <= 0.0
        assert sum(ddl["histogram"]["counts"]) == ddl["n_deadlines"]
        assert all(s["n_misses"] == 0 for s in art["streams"])
        assert all(s["miss_margin_max_ms"] <= 0.0 for s in art["streams"])

    def test_unpaced_artifact_passes_v4_schema(self):
        css = _check_stream_stats()
        src = sources.resolve_dataset("synthetic-gesture", hw=HW)
        dep = _fresh_dep(src)
        report = StreamEngine(dep, capacity=2).serve(src, 2, seed=1)
        art = report.to_artifact()
        assert css.check(art, 2) == []
        assert art["paced"] is False
        assert art["deadlines"]["n_deadlines"] == 0
        # paced gate must reject an unpaced artifact
        assert css.check(art, 2, paced=True) != []

    def test_prefetch_off_matches_prefetch_on(self):
        """The async host-binning worker is a pure pipeline change: the
        inline (prefetch=False) fold produces identical results."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        r_on = StreamEngine(dep, capacity=2).serve(src, 3, seed=0)
        r_off = StreamEngine(dep, capacity=2,
                             prefetch=False).serve(src, 3, seed=0)
        key = lambda r: r.stream_id  # noqa: E731
        for a, b in zip(sorted(r_on.results, key=key),
                        sorted(r_off.results, key=key)):
            assert a.prediction == b.prediction
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))


# ---------------------------------------------------------------------------
# multi-worker host binning pool: determinism + lifecycle
# ---------------------------------------------------------------------------

def _assert_reports_identical(ref, got):
    """Bit-for-bit serving parity: per-stream outcomes and the fleet
    ledger (the binning-pool / sharding determinism contract)."""
    key = lambda r: r.stream_id  # noqa: E731
    assert len(ref.results) == len(got.results)
    for a, b in zip(sorted(ref.results, key=key),
                    sorted(got.results, key=key)):
        assert a.label == b.label
        assert a.prediction == b.prediction
        assert a.n_events == b.n_events
        assert a.n_readouts == b.n_readouts
        assert a.offered_window == b.offered_window
        assert a.admitted_window == b.admitted_window
        assert a.finished_window == b.finished_window
        np.testing.assert_array_equal(np.asarray(a.logits),
                                      np.asarray(b.logits))
    for k in ("n_offered", "n_admitted", "n_shed", "n_deferred",
              "total_events", "total_readouts", "total_layer1_spikes"):
        assert getattr(ref, k) == getattr(got, k), k


class TestBinningPool:
    @pytest.mark.parametrize("paced", [False, True])
    def test_multi_worker_binning_bit_identical(self, paced):
        """2- and 4-worker binning pools produce bit-identical frames →
        predictions, logits, and admission ledger vs the single-worker
        pipeline AND vs the inline prefetch=False oracle, paced and
        unpaced."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        base = StreamEngine(dep, capacity=4).serve(src, 6, seed=0,
                                                   paced=paced)
        oracle = StreamEngine(dep, capacity=4, prefetch=False).serve(
            src, 6, seed=0, paced=paced)
        _assert_reports_identical(base, oracle)
        for workers in (2, 4):
            engine = StreamEngine(dep, capacity=4, bin_workers=workers)
            assert engine.bin_workers == workers
            got = engine.serve(src, 6, seed=0, paced=paced)
            _assert_reports_identical(base, got)
            _assert_reports_identical(oracle, got)
            assert got.to_artifact()["sharding"]["bin_workers"] == workers

    def test_worker_partition_is_contiguous_and_total(self):
        """Every lane is owned by exactly one worker, ownership is
        contiguous (a lane slice per worker), and all workers get lanes
        when capacity >= workers — the single-owner rule that keeps
        per-lane chunk order deterministic."""
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        engine = StreamEngine(dep, capacity=4, bin_workers=3)
        owners = [engine._worker_of(i) for i in range(4)]
        assert owners == sorted(owners)          # contiguous slices
        assert set(owners) == {0, 1, 2}          # no idle worker
        with_cap1 = StreamEngine(dep, capacity=1, bin_workers=4)
        assert with_cap1._worker_of(0) == 0

    def test_worker_threads_join_on_serve_exception(self):
        """A readout failure mid-serve must drain-and-join every bin
        worker on the way out (try/finally): no daemon thread may leak
        holding an open stream iterator."""
        import dataclasses
        import threading

        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        dep = _fast_dep(src, t_intg_ms=100.0, coarse_ms=200.0)
        engine = StreamEngine(dep, capacity=2, bin_workers=2)
        real_readout = engine.fns.readout
        calls = {"n": 0}

        def boom(state, active, coarse_mask):
            calls["n"] += 1
            if calls["n"] >= 2:   # let the warmup call through
                raise RuntimeError("injected readout failure")
            return real_readout(state, active, coarse_mask)

        engine.fns = dataclasses.replace(engine.fns, readout=boom)
        with pytest.raises(RuntimeError, match="injected readout"):
            engine.serve(src, 4, seed=0)
        leaked = [t for t in threading.enumerate()
                  if t.name.startswith("stream-bin-worker")]
        assert leaked == []

    def test_bad_bin_workers_rejected(self):
        src = sources.resolve_dataset("synthetic-gesture", hw=HW,
                                      duration_ms=400.0)
        with pytest.raises(ValueError, match="bin_workers"):
            StreamEngine(_fast_dep(src), capacity=2, bin_workers=0)


# ---------------------------------------------------------------------------
# CLI end-to-end (CI also drives this directly as the streaming smoke step)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_stream_cli_smoke(tmp_path):
    """`launch/stream.py --smoke` end-to-end: fixture generation → tiny
    train+deploy → serve → serving-stats artifact with the v1 schema."""
    import json
    import os
    import subprocess
    import sys
    from pathlib import Path

    src_dir = Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src_dir), JAX_PLATFORMS="cpu")
    out = tmp_path / "stream"
    cmd = [sys.executable, "-m", "repro.launch.stream", "--smoke",
           "--streams", "4", "--capacity", "2", "--out", str(out)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr
    art = json.loads((out / "stream_serving_dvs128.json").read_text())
    assert art["schema"] == STATS_SCHEMA
    assert art["n_streams"] == 4
    assert len(art["streams"]) == 4
    assert art["deployed"]["protocol"] == "frozen"
    assert (out / "deploy" / "ckpt_frozen").is_dir()


# ---------------------------------------------------------------------------
# keep_params seam (core/sweep.py)
# ---------------------------------------------------------------------------

def test_run_grid_keep_params_shapes(trained):
    for proto, result in trained["results"].items():
        assert set(result.final_params) == {(100.0, 2), (1000.0, 2)}
        G = len(result.labels)
        for cell in result.final_params.values():
            bb_leaf = jax.tree.leaves(cell["backbone"])[0]
            assert bb_leaf.shape[0] == G       # unpadded variant axis
            p2m_w = cell["p2m"]["w"]
            if proto == "unfrozen":
                assert p2m_w.shape[0] == G     # per-variant layer 1
            else:
                assert p2m_w.ndim == 4         # shared layer 1
