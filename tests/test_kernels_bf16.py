"""bf16 dtype sweeps for the Pallas kernels (TPU's native compute dtype) —
oracle comparisons at bf16-appropriate tolerances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


class TestFlashBf16:
    @pytest.mark.parametrize("sq,skv,causal", [(64, 64, True), (32, 96, False)])
    def test_matches_ref(self, sq, skv, causal):
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas)
        from repro.kernels.flash_attention.ref import attention_ref
        if causal and sq != skv:
            pytest.skip("causal needs square")
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (2, sq, 32), jnp.bfloat16)
        kk = jax.random.normal(jax.random.fold_in(k, 1), (2, skv, 32),
                               jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(k, 2), (2, skv, 32),
                              jnp.bfloat16)
        o_k = flash_attention_pallas(q, kk, v, causal=causal, block_q=32,
                                     block_k=32)
        o_r = attention_ref(q.astype(jnp.float32), kk.astype(jnp.float32),
                            v.astype(jnp.float32), causal=causal)
        assert o_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r), rtol=0.05, atol=0.05)


class TestSSDBf16:
    def test_matches_ref(self):
        from repro.kernels.ssd.ref import ssd_ref
        from repro.kernels.ssd.ssd import ssd_pallas
        k = jax.random.PRNGKey(3)
        ks = jax.random.split(k, 5)
        b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.bfloat16)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(
            jnp.bfloat16)
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, g, n), jnp.bfloat16)
        C = jax.random.normal(ks[4], (b, s, g, n), jnp.bfloat16)
        y_k, st_k = ssd_pallas(x, dt, A, B, C, chunk=16)
        y_r, st_r = ssd_ref(x.astype(jnp.float32), dt.astype(jnp.float32),
                            A, B.astype(jnp.float32), C.astype(jnp.float32))
        assert y_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_r), rtol=0.1, atol=0.1)


class TestLIFBf16:
    def test_matches_ref(self):
        from repro.kernels.lif.lif import lif_pallas
        from repro.kernels.lif.ref import lif_ref
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64),
                              jnp.bfloat16) * 2
        out_k = lif_pallas(x, block_n=32)
        out_r = lif_ref(x)
        # binary spikes: must agree exactly at matched dtype
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
