"""Deployment registry + multi-variant serving (repro.stream.registry +
the registry mode of repro.stream.engine).

The headline contract is bit-exactness: a mixed-variant serve must be
bit-identical PER STREAM to N single-variant serves of the same streams,
on one device and on a lane mesh — the stacked per-entry bundle and the
lax.map-then-gather execution must not change a single logit. Around
that: registry CRUD, compat-key matching, admission rejection of
no-match/ambiguous requests, and hot-swap residency (retire+register
mid-serve never perturbs lanes bound to other entries).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.codesign import P2MModelConfig  # noqa: E402
from repro.core.leakage import CircuitConfig, LeakageConfig  # noqa: E402
from repro.core.p2m_layer import P2MConfig  # noqa: E402
from repro.core.snn import SpikingCNNConfig  # noqa: E402
from repro.data import sources  # noqa: E402
from repro.stream import deploy as deploy_mod  # noqa: E402
from repro.stream.engine import EntryTableFull, StreamEngine  # noqa: E402
from repro.stream.registry import (Registry, compat_digest,  # noqa: E402
                                   compat_key, entry_meta)
from repro.stream.shard import make_lane_executor  # noqa: E402

HW = 16


@pytest.fixture(scope="module")
def src():
    return sources.resolve_dataset("synthetic-gesture", hw=HW)


def _model(circuit=CircuitConfig.BASIC, t_intg_ms=200.0, n_classes=4):
    return P2MModelConfig(
        p2m=P2MConfig(out_channels=8, n_sub=2, t_intg_ms=t_intg_ms,
                      leak=LeakageConfig(circuit=circuit)),
        backbone=SpikingCNNConfig(channels=(8, 16), input_hw=(HW, HW),
                                  fc_hidden=32, n_classes=n_classes,
                                  first_layer_external=True),
        coarse_window_ms=1000.0)


def _dep(circuit=CircuitConfig.BASIC, seed=0, **kw):
    return deploy_mod.fresh_deployment(_model(circuit, **kw), seed=seed)


@pytest.fixture(scope="module")
def dep_a(src):
    return _dep(CircuitConfig.BASIC, seed=0, n_classes=src.n_classes)


@pytest.fixture(scope="module")
def dep_b(src):
    return _dep(CircuitConfig.NULLIFIED, seed=1, n_classes=src.n_classes)


def _registry(dep_a, dep_b):
    reg = Registry()
    reg.register("a", dep_a)
    reg.register("b", dep_b)
    return reg


class TestRegistryCrud:
    def test_register_retire_lookup(self, dep_a, dep_b):
        reg = Registry()
        e = reg.register("a", dep_a)
        assert e.name == "a" and e.uid == 0
        assert len(reg) == 1 and "a" in reg
        assert reg.get("a") is e
        reg.register("b", dep_b)
        assert reg.names() == ["a", "b"]
        gone = reg.retire("a")
        assert gone is e and "a" not in reg and len(reg) == 1

    def test_uid_unique_per_registration(self, dep_a, dep_b):
        """Hot-swap identity: re-registering a retired name yields a NEW
        uid, so the engine can tell old weights from new."""
        reg = Registry()
        reg.register("a", dep_a)
        reg.retire("a")
        e2 = reg.register("a", dep_b)
        assert e2.uid == 1
        assert reg.version == 3          # each mutation bumps version

    def test_duplicate_name_rejected(self, dep_a, dep_b):
        reg = Registry()
        reg.register("a", dep_a)
        with pytest.raises(ValueError, match="already exists"):
            reg.register("a", dep_b)

    def test_empty_name_rejected(self, dep_a):
        with pytest.raises(ValueError, match="non-empty"):
            Registry().register("", dep_a)

    def test_retire_missing_raises(self):
        with pytest.raises(KeyError, match="no entry"):
            Registry().retire("nope")
        with pytest.raises(KeyError, match="no entry"):
            Registry().get("nope")

    def test_entry_is_self_describing(self, dep_a):
        e = Registry().register("a", dep_a, meta={"site": "lab-3"})
        assert e.meta["circuit"] == "a"          # variant splatted flat
        assert e.meta["variant"]["circuit"] == "a"
        assert e.meta["protocol"] == dep_a.protocol
        assert e.meta["site"] == "lab-3"         # caller meta overlays
        d = e.describe()
        assert d["name"] == "a" and d["uid"] == e.uid
        assert d["compat"] == compat_digest(e.compat)

    def test_register_checkpoint_roundtrip(self, dep_a, tmp_path):
        deploy_mod.save_deployment(tmp_path, dep_a)
        e = Registry().register_checkpoint("ck", tmp_path)
        assert e.compat == compat_key(dep_a)
        assert e.meta["t_intg_ms"] == dep_a.t_intg_ms


class TestCompatKey:
    def test_leak_variant_excluded(self, dep_a, dep_b):
        """The leak block is the variant axis — different circuits with
        the same replay geometry are co-servable."""
        assert compat_key(dep_a) == compat_key(dep_b)

    def test_geometry_changes_key(self, src, dep_a):
        other = _dep(t_intg_ms=100.0, n_classes=src.n_classes)
        assert compat_key(other) != compat_key(dep_a)

    def test_key_is_canonical_json(self, dep_a):
        key = compat_key(dep_a)
        import json
        d = json.loads(key)
        assert "leak" not in d["p2m"] and "v_threshold" not in d["p2m"]
        assert key == json.dumps(d, sort_keys=True, separators=(",", ":"))
        assert len(compat_digest(key)) == 12


class TestResolve:
    def test_by_name_and_default(self, dep_a, dep_b):
        reg = _registry(dep_a, dep_b)
        assert reg.resolve("b").name == "b"
        assert reg.resolve(None, default="b").name == "b"
        solo = Registry()
        solo.register("only", dep_a)
        assert solo.resolve(None).name == "only"

    def test_matcher_must_be_unique(self, dep_a, dep_b):
        reg = _registry(dep_a, dep_b)
        assert reg.resolve({"circuit": "c"}).name == "b"
        with pytest.raises(ValueError, match="ambiguous"):
            reg.resolve({"protocol": dep_a.protocol})
        with pytest.raises(LookupError, match="no registry entry"):
            reg.resolve({"circuit": "zz"})

    def test_no_match_and_no_default(self, dep_a, dep_b):
        reg = _registry(dep_a, dep_b)
        with pytest.raises(LookupError, match="no registry entry"):
            reg.resolve("nope")
        with pytest.raises(ValueError, match="ambiguous"):
            reg.resolve(None)            # two entries, no default
        with pytest.raises(TypeError, match="variant request"):
            reg.resolve(3.14)

    def test_compat_filter(self, src, dep_a, dep_b):
        reg = _registry(dep_a, dep_b)
        weird = _dep(t_intg_ms=100.0, n_classes=src.n_classes)
        reg.register("weird", weird)
        anchor = compat_key(dep_a)
        with pytest.raises(ValueError, match="incompatible"):
            reg.resolve("weird", compat=anchor)
        # matchers silently skip incompatible entries
        assert all(e.name != "weird"
                   for e in reg.match({"protocol": dep_a.protocol},
                                      compat=anchor))

    def test_entry_meta_fields(self, dep_a):
        m = entry_meta(dep_a)
        assert m["t_intg_ms"] == dep_a.t_intg_ms
        assert m["n_sub"] == dep_a.model_cfg.p2m.n_sub
        assert m["circuit"] == dep_a.record["variant"]["circuit"]


class TestRegistryServing:
    VARIANTS = ["a", "b", "a", "b", "b", "a"]
    N = 6

    @pytest.fixture(scope="class")
    def mixed(self, src, dep_a, dep_b):
        eng = StreamEngine(_registry(dep_a, dep_b), capacity=3)
        return eng.serve(src, self.N, seed=0, variants=list(self.VARIANTS))

    @pytest.fixture(scope="class")
    def singles(self, src, dep_a, dep_b):
        out = {}
        for name, dep in (("a", dep_a), ("b", dep_b)):
            rep = StreamEngine(dep, capacity=3).serve(src, self.N, seed=0)
            out[name] = {r.stream_id: r for r in rep.results}
        return out

    def test_mixed_bit_identical_to_singles(self, mixed, singles):
        """HEADLINE: per stream, the mixed-variant serve reproduces the
        single-variant serve of the entry it was bound to, bit for bit."""
        assert len(mixed.results) == self.N
        for r in mixed.results:
            assert r.entry == self.VARIANTS[r.stream_id]
            s = singles[r.entry][r.stream_id]
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(s.logits))
            assert r.prediction == s.prediction
            assert r.n_events == s.n_events
            assert r.n_readouts == s.n_readouts

    def test_artifact_registry_block(self, mixed):
        art = mixed.to_artifact()
        assert art["schema"] == "p2m-stream-serving/v5"
        assert art["admission"]["n_rejected"] == 0
        reg = art["registry"]
        assert reg["compat"] and reg["max_entries"] >= 2
        rows = {e["name"]: e for e in reg["entries"]}
        assert set(rows) == {"a", "b"}
        assert rows["a"]["n_admitted"] == self.VARIANTS.count("a")
        assert rows["b"]["n_admitted"] == self.VARIANTS.count("b")
        assert sum(e["n_finished"] for e in reg["entries"]) == self.N
        assert sum(e["n_readouts"] for e in reg["entries"]) == \
            mixed.total_readouts
        for s in art["streams"]:
            assert s["entry"] in rows
            assert s["entry_uid"] == rows[s["entry"]]["uid"]

    def test_paced_mixed_serve_bit_identical(self, src, dep_a, dep_b,
                                             singles):
        """The acceptance bar names the PACED serve: pacing decides when
        windows run, never what they compute, so the paced mixed serve
        is bit-identical per stream to the single-variant serves too."""
        paced = StreamEngine(_registry(dep_a, dep_b), capacity=3).serve(
            src, self.N, seed=0, paced=True, variants=list(self.VARIANTS))
        assert paced.to_artifact()["paced"]
        assert len(paced.results) == self.N
        for r in paced.results:
            s = singles[r.entry][r.stream_id]
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(s.logits))
            assert r.prediction == s.prediction

    def test_rejection_ledger(self, src, dep_a, dep_b):
        """Unknown names and ambiguous matchers are rejected at
        admission and accounted: offered = admitted + shed + rejected."""
        rep = StreamEngine(_registry(dep_a, dep_b), capacity=2).serve(
            src, 3, seed=0,
            variants=["a", "nope", {"protocol": dep_a.protocol}])
        assert len(rep.results) == 1
        assert rep.n_rejected == 2
        assert rep.n_offered == rep.n_admitted + rep.n_shed + rep.n_rejected
        art = rep.to_artifact()
        assert art["admission"]["n_rejected"] == 2

    def test_variants_require_registry(self, src, dep_a):
        eng = StreamEngine(dep_a, capacity=2)
        with pytest.raises(ValueError, match="registry"):
            eng.serve(src, 2, seed=0, variants=["a", "a"])

    def test_legacy_engine_rejects_registry_kwargs(self, dep_a):
        with pytest.raises(ValueError, match="registry"):
            StreamEngine(dep_a, capacity=2, max_entries=4)

    def test_empty_registry_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            StreamEngine(Registry(), capacity=2)

    def test_max_entries_floor(self, dep_a, dep_b):
        with pytest.raises(ValueError, match="max_entries"):
            StreamEngine(_registry(dep_a, dep_b), capacity=2, max_entries=1)


class TestHotSwap:
    def test_hot_swap_keeps_other_lanes_bit_identical(self, src, dep_a,
                                                      dep_b):
        """Retire+register mid-serve: the lane already bound to the old
        uid finishes on the old weights, the post-swap request resolves
        to the new entry, and lanes bound to 'a' are bit-identical to a
        single-variant serve — the swap never perturbs them."""
        reg = _registry(dep_a, dep_b)
        eng = StreamEngine(reg, capacity=2, max_entries=3,
                           default_entry="a")
        swapped = []

        def swap(window):
            if window == 2 and "b" in reg:
                old = reg.retire("b")
                new = reg.register(
                    "b2", _dep(CircuitConfig.NULLIFIED, seed=7,
                               n_classes=src.n_classes))
                swapped.append((old.uid, new.uid))

        rep = eng.serve(src, 4, seed=0, variants=["a", "b", "b2", None],
                        on_window=swap)
        assert swapped and swapped[0][0] != swapped[0][1]
        assert len(rep.results) == 4
        by_sid = {r.stream_id: r for r in rep.results}
        assert by_sid[1].entry == "b"     # admitted pre-swap, kept weights
        assert by_sid[2].entry == "b2"    # post-swap request resolves
        assert by_sid[0].entry == by_sid[3].entry == "a"
        single = StreamEngine(dep_a, capacity=2).serve(src, 4, seed=0)
        ref = {r.stream_id: r for r in single.results}
        for r in rep.results:
            if r.entry == "a":
                np.testing.assert_array_equal(np.asarray(r.logits),
                                              np.asarray(ref[r.stream_id].logits))
        rows = {e["name"]: e for e in rep.to_artifact()["registry"]["entries"]}
        assert rows["b"]["n_finished"] == 1
        assert rows["b2"]["n_finished"] == 1

    def test_entry_table_full_rejects(self, src, dep_a, dep_b):
        """With every entry slot pinned by resident lanes, a request for
        a freshly registered entry is REJECTED (EntryTableFull), not
        mis-deployed — and serving continues."""
        reg = Registry()
        reg.register("a", dep_a)
        # capacity 3 so the "b" stream is offered while both "a" lanes
        # are still resident — the sole entry slot is pinned (refs > 0)
        eng = StreamEngine(reg, capacity=3, max_entries=1)

        def swap(window):
            if window == 0 and "b" not in reg:
                reg.register("b", dep_b)

        rep = eng.serve(src, 3, seed=0, variants=["a", "a", "b"],
                        on_window=swap)
        assert rep.n_rejected == 1
        assert {r.entry for r in rep.results} == {"a"}
        assert len(rep.results) == 2

    def test_slot_reclaimed_after_release(self, src, dep_a, dep_b):
        """Once the last lane bound to a retired entry releases, its
        entry slot is reclaimed for new registrations (serially: serve
        'a' to completion, swap, then serve 'b' on the same engine)."""
        reg = Registry()
        reg.register("a", dep_a)
        eng = StreamEngine(reg, capacity=2, max_entries=1)
        r1 = eng.serve(src, 2, seed=0)
        assert all(r.entry == "a" for r in r1.results)
        reg.retire("a")
        reg.register("b", dep_b)
        r2 = eng.serve(src, 2, seed=0, variants=["b", "b"])
        assert all(r.entry == "b" for r in r2.results)
        assert r2.n_rejected == 0


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device (run under XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
class TestShardedRegistryServing:
    def test_sharded_mixed_serve_bit_identical(self, src, dep_a, dep_b):
        """Acceptance bar: the mixed-variant serve is bit-identical on a
        >=2-device lane mesh to the single-device serve — the stacked
        bundle is replicated (P_REP) while lane state shards."""
        n_dev = min(2, jax.device_count())
        variants = ["a", "b", "b", "a", "b", "a"]
        r1 = StreamEngine(_registry(dep_a, dep_b), capacity=4).serve(
            src, 6, seed=0, variants=list(variants))
        r2 = StreamEngine(_registry(dep_a, dep_b), capacity=4,
                          executor=make_lane_executor(n_dev)).serve(
            src, 6, seed=0, variants=list(variants))
        a1 = {r.stream_id: r for r in r1.results}
        a2 = {r.stream_id: r for r in r2.results}
        assert set(a1) == set(a2) == set(range(6))
        for sid in a1:
            np.testing.assert_array_equal(np.asarray(a1[sid].logits),
                                          np.asarray(a2[sid].logits))
            assert a1[sid].entry == a2[sid].entry
            assert a1[sid].prediction == a2[sid].prediction
        art = r2.to_artifact()
        assert art["sharding"]["devices"] == n_dev
        assert sum(e["n_admitted"] for e in art["registry"]["entries"]) == 6
        # and paced on the mesh: same streams, same bits
        r3 = StreamEngine(_registry(dep_a, dep_b), capacity=4,
                          executor=make_lane_executor(n_dev)).serve(
            src, 6, seed=0, paced=True, variants=list(variants))
        for r in r3.results:
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(a1[r.stream_id].logits))
            assert r.entry == a1[r.stream_id].entry
