"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

MAX_EXAMPLES = 25


# ---------------------------------------------------------------------------
# leakage ODE invariants
# ---------------------------------------------------------------------------


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dt1=st.floats(0.01, 50.0), dt2=st.floats(0.01, 50.0),
       circuit=st.sampled_from(["a", "b", "c"]))
def test_leak_semigroup_property(seed, dt1, dt2, circuit):
    """leak(dt1) ∘ leak(dt2) == leak(dt1+dt2) — exact exponential ODE."""
    from repro.core import leakage
    from repro.core.leakage import CircuitConfig, LeakageConfig
    cfg = LeakageConfig(circuit=CircuitConfig(circuit))
    w = jax.random.normal(jax.random.PRNGKey(seed), (3, 3, 2, 4))
    p = leakage.kernel_leak_params(w, cfg)
    v0 = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                           (4,)) * 0.3
    a = leakage.leak_step(leakage.leak_step(v0, p, dt1), p, dt2)
    b = leakage.leak_step(v0, p, dt1 + dt2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       mismatch=st.floats(0.0, 0.5),
       circuit=st.sampled_from(["a", "b", "c"]))
def test_leak_params_finite_and_differentiable(seed, mismatch, circuit):
    """The (differentiable) leak linearization must stay finite — values
    AND gradients w.r.t. the kernel weights — for any weights and any
    nullifier mismatch in [0, 0.5]. This is the seam the unfrozen phase-2
    protocol trains through."""
    from repro.core import leakage
    from repro.core.leakage import CircuitConfig, LeakageConfig
    cfg = LeakageConfig(circuit=CircuitConfig(circuit),
                        null_mismatch=mismatch)
    w = jax.random.normal(jax.random.PRNGKey(seed), (3, 3, 2, 4)) * 0.7
    p = leakage.kernel_leak_params(w, cfg)
    assert np.isfinite(np.asarray(p.v_inf)).all()
    assert np.isfinite(np.asarray(p.tau_ms)).all()
    assert (np.asarray(p.tau_ms) > 0).all()

    def f(w):
        lk = leakage.kernel_leak_params(w, cfg)
        # exp(-1/tau) keeps the readout finite for any tau in (0, inf]
        return jnp.sum(lk.v_inf) + jnp.sum(
            jnp.exp(-1.0 / jnp.maximum(lk.tau_ms, 1e-9)))

    g = jax.grad(f)(w)
    assert np.isfinite(np.asarray(g)).all()


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       mismatch=st.floats(0.0, 0.5),
       circuit=st.sampled_from(["a", "b", "c"]),
       ts=st.lists(st.floats(0.01, 2000.0), min_size=3, max_size=6))
def test_retention_error_monotone_in_t(seed, mismatch, circuit, ts):
    """|V(t) − V(0)| with no drive is non-decreasing in t for every
    circuit and mismatch — the Fig 4a surface can only get worse with a
    longer integration time."""
    from repro.core import leakage
    from repro.core.leakage import CircuitConfig, LeakageConfig
    cfg = LeakageConfig(circuit=CircuitConfig(circuit),
                        null_mismatch=mismatch)
    w = jax.random.normal(jax.random.PRNGKey(seed), (3, 3, 2, 4))
    p = leakage.kernel_leak_params(w, cfg)
    errs = [float(jnp.mean(leakage.retention_error(p, 0.2, t)))
            for t in sorted(ts)]
    assert all(b >= a - 1e-9 for a, b in zip(errs, errs[1:])), errs


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dt=st.floats(0.01, 1000.0))
def test_leak_contraction_toward_vinf(seed, dt):
    """|V(t) − V_inf| never grows — the ODE is a contraction."""
    from repro.core import leakage
    from repro.core.leakage import CircuitConfig, LeakageConfig
    cfg = LeakageConfig(circuit=CircuitConfig.BASIC)
    w = jax.random.normal(jax.random.PRNGKey(seed), (3, 3, 2, 4))
    p = leakage.kernel_leak_params(w, cfg)
    v0 = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 2),
                           (4,)) * 0.4
    v1 = leakage.leak_step(v0, p, dt)
    d0 = np.abs(np.asarray(v0 - p.v_inf))
    d1 = np.abs(np.asarray(v1 - p.v_inf))
    assert (d1 <= d0 + 1e-7).all()


# ---------------------------------------------------------------------------
# analog quantizer
# ---------------------------------------------------------------------------


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       levels=st.sampled_from([4, 8, 16, 32]))
def test_quantizer_error_bound(seed, levels):
    """|w − q(w)| ≤ step/2 inside the clip range."""
    from repro.core import analog
    from repro.core.analog import AnalogConfig
    cfg = AnalogConfig(weight_levels=levels)
    w = jax.random.uniform(jax.random.PRNGKey(seed), (64,), minval=-1.0,
                           maxval=1.0)
    q = analog.quantize_weights(w, cfg)
    step = cfg.w_clip / (levels // 2)
    assert float(jnp.max(jnp.abs(q - w))) <= step / 2 + 1e-6


# ---------------------------------------------------------------------------
# event pipeline conservation
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), factor=st.sampled_from([2, 4]))
def test_refine_slots_conserves_events(seed, factor):
    from repro.data import events as ev
    x = jax.random.poisson(jax.random.PRNGKey(seed), 0.5,
                           (2, 8, 2, 6, 6, 2)).astype(jnp.float32)
    y = ev.refine_slots(x, factor)
    assert y.shape[1] == 8 // factor
    np.testing.assert_allclose(float(jnp.sum(y)), float(jnp.sum(x)))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 700), block=st.sampled_from([32, 128, 256]),
       scale=st.floats(1e-3, 1e3))
def test_compression_roundtrip_any_shape(seed, n, block, scale):
    from repro.distributed import compress_int8, decompress_int8
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
    q, s, pad = compress_int8(g, block=block)
    back = decompress_int8(q, s, pad, g.shape)
    assert back.shape == g.shape
    per_block_bound = np.repeat(np.asarray(s) / 2, block)[:n]
    assert (np.abs(np.asarray(back - g)) <= per_block_bound + 1e-9).all()


# ---------------------------------------------------------------------------
# spike function / LIF
# ---------------------------------------------------------------------------


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_spikes_binary_and_monotone_in_drive(seed):
    """Spike counts are non-decreasing in input drive (LIF monotonicity)."""
    from repro.core.snn import LIFConfig, lif_over_time
    x = jax.random.uniform(jax.random.PRNGKey(seed), (12, 8), minval=0.0,
                           maxval=1.0)
    s1 = lif_over_time(x, LIFConfig())
    s2 = lif_over_time(x * 2.0, LIFConfig())
    assert set(np.unique(np.asarray(s1))) <= {0.0, 1.0}
    assert float(jnp.sum(s2)) >= float(jnp.sum(s1))


# ---------------------------------------------------------------------------
# elastic planner
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(chips=st.integers(1, 512),
       tp=st.sampled_from([1, 2, 4, 8, 16]),
       batch=st.sampled_from([32, 256, 1024]))
def test_elastic_plan_invariants(chips, tp, batch):
    from repro.ft import plan_remesh
    plan = plan_remesh(chips, tp=tp, global_batch=batch)
    data, model = plan.mesh_shape
    assert data * model <= chips              # never oversubscribe
    assert model >= 1 and data >= 1
    assert plan.dropped_chips >= 0
    # effective batch is restored: accum * data ≥ batch
    assert plan.grad_accum * data >= min(batch, data) \
        or batch % data == 0


# ---------------------------------------------------------------------------
# checkpoint round-trip with random trees
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       depth=st.integers(1, 3))
def test_checkpoint_roundtrip_random_trees(tmp_path_factory, seed, depth):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    rng = np.random.default_rng(seed)
    tmp = tmp_path_factory.mktemp(f"ck{seed}")

    def build(d):
        if d == 0:
            return rng.normal(size=rng.integers(1, 5, size=2)).astype(
                rng.choice([np.float32, np.float64]))
        return {f"k{i}": build(d - 1) for i in range(rng.integers(1, 3))}

    tree = {"root": build(depth)}
    save_checkpoint(tmp, 1, tree)
    got, _ = load_checkpoint(tmp)

    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(got)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# SSD numerical invariants
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([8, 16, 32]))
def test_ssd_chunk_size_invariance(seed, chunk):
    """The chunked algorithm's result is independent of chunk size."""
    from repro.nn.ssm import ssd_chunked
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    b, s, h, p, g, n = 1, 32, 2, 8, 1, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y1, st1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, st2 = ssd_chunked(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=2e-3, atol=2e-4)
