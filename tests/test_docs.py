"""Docs integrity: the link checker (tools/check_docs.py) passes on the
committed README.md + docs/*.md, and its failure modes actually fire."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
CHECKER = REPO / "tools" / "check_docs.py"

sys.path.insert(0, str(REPO / "tools"))
import check_docs  # noqa: E402


class TestChecker:
    def test_repo_docs_link_clean(self):
        proc = subprocess.run([sys.executable, str(CHECKER)],
                              capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr + proc.stdout

    def test_slugify_github_style(self):
        assert check_docs.slugify("The `EventSource` contract") == \
            "the-eventsource-contract"
        assert check_docs.slugify("Phase-2 protocols: frozen vs unfrozen") \
            == "phase-2-protocols-frozen-vs-unfrozen"

    def test_broken_path_detected(self, tmp_path, monkeypatch):
        md = tmp_path / "x.md"
        md.write_text("# T\n\nsee [gone](does/not/exist.md)\n")
        errs = check_docs.check_file(md)
        assert errs and "broken path link" in errs[0]

    def test_broken_anchor_detected(self, tmp_path):
        a = tmp_path / "a.md"
        b = tmp_path / "b.md"
        a.write_text("# Top\n\n[ok](b.md#real)\n[bad](b.md#fake)\n")
        b.write_text("# Real\n")
        errs = check_docs.check_file(a)
        assert len(errs) == 1 and "#fake" in errs[0]

    def test_code_blocks_ignored(self, tmp_path):
        md = tmp_path / "c.md"
        md.write_text("# T\n\n```md\n[not a link](missing.md)\n```\n"
                      "and `[inline](also/missing.md)` too\n")
        assert check_docs.check_file(md) == []


class TestDocstringRefs:
    """The .md-reference scan over Python docstrings (satellite of the
    BENCH/benchmarks work: benchmark docstrings rot quietly)."""

    def _py(self, tmp_path, doc):
        p = tmp_path / "mod.py"
        p.write_text(f'"""{doc}"""\n')
        return p

    def test_missing_md_detected(self, tmp_path):
        p = self._py(tmp_path, "Tables live in EXPERIMENTS.md here.")
        errs = check_docs.check_py_docstrings(p)
        assert len(errs) == 1 and "EXPERIMENTS.md" in errs[0]

    def test_existing_md_ok(self, tmp_path):
        p = self._py(tmp_path, "See docs/benchmarks.md and README.md.")
        assert check_docs.check_py_docstrings(p) == []

    def test_function_and_class_docstrings_scanned(self, tmp_path):
        p = tmp_path / "mod.py"
        p.write_text('def f():\n    """see gone/missing.md"""\n\n'
                     'class C:\n    """see also/absent.md §X"""\n')
        errs = check_docs.check_py_docstrings(p)
        assert len(errs) == 2

    def test_section_suffix_checked(self, tmp_path):
        ok = self._py(tmp_path, "See docs/sweep.md §Sharding for details.")
        assert check_docs.check_py_docstrings(ok) == []
        bad = self._py(tmp_path, "See docs/sweep.md §Roofline instead.")
        errs = check_docs.check_py_docstrings(bad)
        assert len(errs) == 1 and "no such heading" in errs[0]

    def test_code_literals_skipped(self, tmp_path):
        p = self._py(tmp_path, "Pass ``path.md#section`` as the target.")
        assert check_docs.check_py_docstrings(p) == []

    def test_repo_py_docstrings_clean(self):
        errs = [e for f in check_docs.py_files()
                for e in check_docs.check_py_docstrings(f)]
        assert errs == []
