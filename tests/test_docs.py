"""Docs integrity: the link checker (tools/check_docs.py) passes on the
committed README.md + docs/*.md, and its failure modes actually fire."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
CHECKER = REPO / "tools" / "check_docs.py"

sys.path.insert(0, str(REPO / "tools"))
import check_docs  # noqa: E402


class TestChecker:
    def test_repo_docs_link_clean(self):
        proc = subprocess.run([sys.executable, str(CHECKER)],
                              capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr + proc.stdout

    def test_slugify_github_style(self):
        assert check_docs.slugify("The `EventSource` contract") == \
            "the-eventsource-contract"
        assert check_docs.slugify("Phase-2 protocols: frozen vs unfrozen") \
            == "phase-2-protocols-frozen-vs-unfrozen"

    def test_broken_path_detected(self, tmp_path, monkeypatch):
        md = tmp_path / "x.md"
        md.write_text("# T\n\nsee [gone](does/not/exist.md)\n")
        errs = check_docs.check_file(md)
        assert errs and "broken path link" in errs[0]

    def test_broken_anchor_detected(self, tmp_path):
        a = tmp_path / "a.md"
        b = tmp_path / "b.md"
        a.write_text("# Top\n\n[ok](b.md#real)\n[bad](b.md#fake)\n")
        b.write_text("# Real\n")
        errs = check_docs.check_file(a)
        assert len(errs) == 1 and "#fake" in errs[0]

    def test_code_blocks_ignored(self, tmp_path):
        md = tmp_path / "c.md"
        md.write_text("# T\n\n```md\n[not a link](missing.md)\n```\n"
                      "and `[inline](also/missing.md)` too\n")
        assert check_docs.check_file(md) == []
